//! Quickstart: compute PageRank three ways on a small synthetic web —
//! (1) the classic synchronous power method, (2) the paper's
//! asynchronous iteration on the simulated cluster, (3) the
//! asynchronous iteration executing the AOT-compiled Pallas kernel via
//! PJRT (the full three-layer stack) — and check they agree.
//!
//!     make artifacts && cargo run --release --example quickstart

use std::sync::Arc;

use asyncpr::asynciter::{ArtifactBlockOp, BlockOperator, Mode, RunSpec, SimEngine};
use asyncpr::coordinator::Partitioner;
use asyncpr::graph::{generators, Csr, GraphStats};
use asyncpr::pagerank::{
    kendall_tau, normalize_l1, power_method, rank_of, PagerankProblem, PowerOptions,
};
use asyncpr::runtime::Engine;
use asyncpr::simnet::ClusterProfile;

fn main() -> anyhow::Result<()> {
    // ---- build a small web (1/100 Stanford shape) ----
    let el = generators::power_law_web(&generators::WebParams::scaled(2_800), 7);
    let csr = Csr::from_edgelist(&el)?;
    println!("graph: {}", GraphStats::compute(&csr).report());
    let problem = Arc::new(PagerankProblem::new(csr, 0.85));

    // ---- (1) synchronous power method (eq. 4) ----
    let pm = power_method(&problem, &PowerOptions::default());
    println!(
        "power method: {} iterations, residual {:.2e}",
        pm.iters, pm.residual
    );

    // ---- (2) asynchronous iteration on the simulated cluster ----
    let p = 3;
    let profile = ClusterProfile::paper_beowulf(p);
    let mut ops: Vec<Box<dyn BlockOperator>> = Partitioner::consecutive(problem.n(), p)
        .blocks()
        .into_iter()
        .map(|(lo, hi)| {
            Box::new(asyncpr::asynciter::NativeBlockOp::new(problem.clone(), lo, hi))
                as Box<dyn BlockOperator>
        })
        .collect();
    let m = SimEngine::new(&profile, &problem)
        .run(&mut ops, &RunSpec::paper_table1(Mode::Asynchronous));
    println!(
        "async (native ops): iters {:?}, virtual time {:.1}s, global residual {:.2e}",
        m.iters, m.total_time, m.final_global_residual
    );

    // ---- (3) asynchronous iteration through the PJRT artifacts ----
    let engine = Engine::new(asyncpr::runtime::default_artifacts_dir())?;
    let mut art_ops: Vec<Box<dyn BlockOperator>> = Partitioner::consecutive(problem.n(), p)
        .blocks()
        .into_iter()
        .map(|(lo, hi)| {
            Ok(Box::new(ArtifactBlockOp::new(&engine, problem.clone(), lo, hi, 16)?)
                as Box<dyn BlockOperator>)
        })
        .collect::<anyhow::Result<_>>()?;
    let ma = SimEngine::new(&profile, &problem)
        .run(&mut art_ops, &RunSpec::paper_table1(Mode::Asynchronous));
    println!(
        "async (pallas/PJRT ops): iters {:?}, global residual {:.2e}",
        ma.iters, ma.final_global_residual
    );

    // ---- agreement ----
    let mut a = pm.x.clone();
    let mut b = m.x.clone();
    let mut c = ma.x.clone();
    normalize_l1(&mut a);
    normalize_l1(&mut b);
    normalize_l1(&mut c);
    println!(
        "ranking agreement: tau(power, async-native) = {:.6}, tau(async-native, async-pjrt) = {:.6}",
        kendall_tau(&a, &b),
        kendall_tau(&b, &c)
    );
    let top = rank_of(&a);
    println!("top-5 pages: {:?}", &top[..5]);
    anyhow::ensure!(kendall_tau(&a, &b) > 0.999, "async diverged from power method");
    anyhow::ensure!(kendall_tau(&b, &c) > 0.9999, "pjrt diverged from native");
    println!("quickstart OK");
    Ok(())
}
