//! Evolving-web walkthrough: rank a living graph across churn epochs.
//!
//! ```text
//! cargo run --release --example evolving_web
//! ```
//!
//! Builds a small statistics-matched web, converges PageRank once by
//! residual push, then streams five crawl-like update batches through
//! it (page arrivals + link churn). After each batch the ranks are
//! repaired incrementally — cost proportional to the change — and
//! cross-checked against a from-scratch f64 power-method run. Finally
//! the same snapshot is ranked through the asynchronous DES cluster
//! using the push operator per UE (`PushBlockOp`).

use std::sync::Arc;

use asyncpr::asynciter::{BlockOperator, Mode, RunSpec, SimEngine};
use asyncpr::coordinator::Partitioner;
use asyncpr::graph::generators::{churn_batch, ChurnParams};
use asyncpr::pagerank::{kendall_tau, PagerankProblem};
use asyncpr::simnet::ClusterProfile;
use asyncpr::stream::{power_method_f64, DeltaGraph, PushBlockOp, PushState};
use asyncpr::util::Rng;

fn main() -> anyhow::Result<()> {
    let el = asyncpr::coordinator::load_edgelist("scaled:4000", 42)?;
    let mut g = DeltaGraph::from_edgelist(&el);
    println!("initial web: n={} m={} dangling={}", g.n(), g.m(), g.dangling_count());

    let tol = 1e-10;
    let mut state = PushState::new(g.n(), 0.85);
    state.begin_epoch();
    let cold = state.solve(&g, tol, u64::MAX);
    println!("cold build: {} pushes, residual {:.1e}\n", cold.pushes, cold.residual);

    let churn = ChurnParams::scaled_to(g.n(), g.m());
    let mut rng = Rng::new(7);
    for epoch in 1..=5 {
        let batch = churn_batch(&g, &churn, &mut rng);
        let delta = g.apply(&batch)?;
        state.begin_epoch();
        state.apply_batch(&g, &delta);
        let st = state.solve(&g, tol, u64::MAX);
        let (xref, _) = power_method_f64(&g, 0.85, tol, 100_000);
        let l1: f64 = state
            .ranks()
            .iter()
            .zip(&xref)
            .map(|(a, b)| (a - b).abs())
            .sum();
        println!(
            "epoch {epoch}: +{}n +{}e -{}e -> {} pushes ({}x cheaper than build), \
             L1 vs fresh power {l1:.1e}",
            batch.new_nodes,
            delta.inserted,
            delta.removed,
            st.pushes,
            cold.pushes / st.pushes.max(1),
        );
        anyhow::ensure!(l1 < 1e-8, "incremental ranks drifted: {l1}");
    }

    // same snapshot through the async simulated cluster, push op per UE
    let problem = Arc::new(PagerankProblem::new(g.to_csr()?, 0.85));
    let p = 3;
    let profile = ClusterProfile::test_profile(p);
    let mut ops: Vec<Box<dyn BlockOperator>> = Partitioner::consecutive(problem.n(), p)
        .blocks()
        .into_iter()
        .map(|(lo, hi)| {
            Box::new(PushBlockOp::new(problem.clone(), lo, hi)) as Box<dyn BlockOperator>
        })
        .collect();
    let m = SimEngine::new(&profile, &problem)
        .run(&mut ops, &RunSpec::paper_table1(Mode::Asynchronous));
    let x64: Vec<f32> = state.ranks().iter().map(|&v| v as f32).collect();
    let tau = kendall_tau(&m.x, &x64);
    println!(
        "\nasync cluster (push ops, p={p}): iters {:?}, global residual {:.1e}, \
         ranking tau vs incremental {tau:.6}",
        m.iters, m.final_global_residual
    );
    anyhow::ensure!(tau > 0.99, "cluster ranking diverged");
    println!("evolving web OK");
    Ok(())
}
