//! End-to-end driver: the paper's full experiment on the Stanford-Web-
//! scale synthetic graph (n = 281,903, nnz ≈ 2.31 M, 172 dangling).
//!
//! Reproduces, in one run:
//!   * Table 1 — sync vs async iterations/time/speedup at p ∈ {2, 4, 6};
//!   * Table 2 — the completed-imports matrix for the async p = 4 run;
//!   * §5.2  — the achieved global residual at local tol 1e-6, and the
//!     ranking agreement (Kendall-τ, top-100) against a tight reference.
//!
//! Results are printed in the paper's layout and written to
//! `reports/e2e_stanford.{md,json}`. Run with --quick for a 10×
//! scaled-down graph (CI-friendly).
//!
//!     cargo run --release --example e2e_stanford [-- --quick]

use asyncpr::config::RunConfig;
use asyncpr::coordinator::experiments::{self, ExperimentCtx};
use asyncpr::coordinator::Report;
use asyncpr::graph::GraphStats;
use asyncpr::metrics::{run_summary, table1_markdown, table2_markdown};
use asyncpr::termination::GlobalOracle;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let graph = if quick { "scaled:28190".to_string() } else { "stanford".to_string() };
    eprintln!("== asyncpr e2e driver (graph = {graph}) ==");

    let base = RunConfig { graph, ..Default::default() };
    let t0 = std::time::Instant::now();
    let ctx = ExperimentCtx::new(base)?;
    let stats = GraphStats::compute(&ctx.problem.csr);
    println!("graph: {}", stats.report());

    // ---- Table 1 ----
    let procs: &[usize] = &[2, 4, 6];
    let rows = experiments::table1(&ctx, procs)?;
    let t1_rows: Vec<_> = rows.iter().map(|(r, _, _)| r.clone()).collect();
    let t1 = table1_markdown(&t1_rows);
    println!("\nTable 1 — synchronous vs asynchronous (local tol 1e-6, pcMax 1):\n{t1}");
    println!("paper's shape: sync time GROWS with p; async wins ~2x at p=2, more at p=6\n");

    // ---- Table 2 ----
    let async4 = experiments::table2(&ctx, 4)?;
    let t2 = table2_markdown(&async4);
    println!("Table 2 — completed imports, async p=4:\n{t2}");
    println!("paper's shape: diagonals ~100+ local iterations, off-diagonal\nimports complete only ~28-45% of the time\n");

    // ---- §5.2 global residual + ranking ----
    let async_run = &rows[0].2;
    println!("§5.2 checks (p=2 async run): {}", run_summary(async_run));
    let oracle = GlobalOracle::new(&ctx.problem, 1e-9);
    let tau = oracle.ranking_tau(&async_run.x);
    let top100 = oracle.top_k(&async_run.x, 100);
    println!(
        "achieved global residual {:.2e} (paper: local 1e-6 => global ~5e-5)",
        async_run.final_global_residual
    );
    println!("ranking vs tight reference: kendall-tau {tau:.6}, top-100 overlap {top100:.2}");

    // ---- report ----
    std::fs::create_dir_all("reports")?;
    let mut rep = Report::new();
    rep.add_section("Graph", &stats.report());
    rep.add_section("Table 1", &t1);
    rep.add_section("Table 2", &t2);
    rep.add_section(
        "Global residual & ranking",
        &format!(
            "achieved global residual {:.3e}; kendall-tau {tau:.6}; top-100 {top100:.2}",
            async_run.final_global_residual
        ),
    );
    for (row, sync, asyn) in &rows {
        rep.add_run(&format!("sync_p{}", row.procs), sync);
        rep.add_run(&format!("async_p{}", row.procs), asyn);
        rep.add_json(&format!("table1_p{}", row.procs), row.to_json());
    }
    rep.add_run("async_p4_table2", &async4);
    rep.write("reports/e2e_stanford")?;
    eprintln!(
        "\nwrote reports/e2e_stanford.{{md,json}} ({}s wall)",
        t0.elapsed().as_secs()
    );
    Ok(())
}
