//! Parallel residual-push on an evolving web — the multicore face of
//! the stream subsystem.
//!
//! Builds a power-law web, cold-solves it on 4 worker threads (balanced-
//! nnz shards exchanging residual fragments over bounded channels),
//! then streams a few churn epochs through the *same* sharded machinery
//! warm-started from the previous fixed point: scatter the global push
//! state, drain in parallel, gather, and (if the termination monitor
//! cut early) polish sequentially. Run with:
//!
//! ```sh
//! cargo run --release --example parallel_push
//! ```

use asyncpr::asynciter::{run_threaded_push, PushThreadOptions};
use asyncpr::graph::generators::{self, churn_batch, ChurnParams};
use asyncpr::stream::{power_method_f64, DeltaGraph, PushState, ShardedPush};
use asyncpr::util::Rng;

fn main() -> anyhow::Result<()> {
    let threads = 4;
    let tol = 1e-10;
    let el = generators::power_law_web(&generators::WebParams::scaled(20_000), 42);
    let mut g = DeltaGraph::from_edgelist(&el);
    println!("web: n = {}, m = {}, solving on {threads} threads\n", g.n(), g.m());

    // cold build, fully parallel
    let mut sharded = ShardedPush::new(&g, 0.85, threads);
    let opts = PushThreadOptions { tol, ..Default::default() };
    let tm = run_threaded_push(&g, &mut sharded, &opts);
    println!(
        "cold solve: {:?} pushes/shard, {} fragments, {:.1} ms, residual {:.1e}",
        tm.shard_pushes,
        tm.fragments_sent.iter().sum::<u64>(),
        tm.wall.as_secs_f64() * 1e3,
        tm.residual
    );

    // adopt the parallel result as the persistent warm state
    let mut state = PushState::new(g.n(), 0.85);
    state.begin_epoch();
    sharded.gather_into(&mut state);
    if tm.residual >= tol {
        state.solve(&g, tol, u64::MAX);
    }

    // stream churn epochs through the same parallel path
    let churn = ChurnParams::scaled_to(g.n(), g.m());
    let mut rng = Rng::new(7);
    for epoch in 1..=3 {
        let batch = churn_batch(&g, &churn, &mut rng);
        let delta = g.apply(&batch)?;
        state.begin_epoch();
        state.apply_batch(&g, &delta);

        let mut sharded = ShardedPush::from_state(&state, &g, threads);
        let tm = run_threaded_push(&g, &mut sharded, &opts);
        let parallel_pushes: u64 = tm.shard_pushes.iter().sum();
        sharded.gather_into(&mut state);
        let polish = state.solve(&g, tol, u64::MAX);

        let (xref, _) = power_method_f64(&g, 0.85, 1e-11, 10_000);
        let l1: f64 = state
            .ranks()
            .iter()
            .zip(&xref)
            .map(|(a, b)| (a - b).abs())
            .sum();
        println!(
            "epoch {epoch}: +{}n +{}e -{}e -> {} parallel + {} polish pushes, \
             {:.1} ms parallel, L1 vs power {l1:.1e}",
            batch.new_nodes,
            delta.inserted,
            delta.removed,
            parallel_pushes,
            polish.pushes,
            tm.wall.as_secs_f64() * 1e3,
        );
    }
    println!("\nwarm epochs cost pushes proportional to the churn, not the graph —");
    println!("and the drain itself now runs on every core the host offers.");
    Ok(())
}
