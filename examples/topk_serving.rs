//! Serving-path walkthrough: maintain a certified top-10 over an
//! evolving web and stop each epoch's solve the moment the head is
//! provably final.
//!
//! What "certified" buys: every printed head comes with a machine-
//! checked proof — derived from the queued residual mass — that no
//! amount of further iteration can change the set (and, with
//! `order: true`, the order) of the pages served. The solver never
//! runs to full convergence unless the head is genuinely contested.
//! Run with:
//!
//! ```sh
//! cargo run --release --example topk_serving
//! ```

use asyncpr::graph::generators::{self, churn_batch, ChurnParams};
use asyncpr::stream::{
    interval_bounds_sharded, solve_certified_sharded, DeltaGraph, ShardedPush, TopKGoal,
    TopKTracker,
};
use asyncpr::util::Rng;

fn main() -> anyhow::Result<()> {
    let (k, shards, tol) = (10usize, 4usize, 1e-9f64);
    let el = generators::power_law_web(&generators::WebParams::scaled(20_000), 42);
    let mut g = DeltaGraph::from_edgelist(&el);
    println!(
        "web: n = {}, m = {} — serving a certified, ORDERED top-{k}\n",
        g.n(),
        g.m()
    );

    let mut sp = ShardedPush::new(&g, 0.85, shards);
    let mut tracker = TopKTracker::new(TopKGoal { k, order: true });
    let churn = ChurnParams::scaled_to(g.n(), g.m());
    let mut rng = Rng::new(7);

    for epoch in 0..=3 {
        if epoch > 0 {
            let batch = churn_batch(&g, &churn, &mut rng);
            let delta = g.apply(&batch)?;
            sp.begin_epoch();
            sp.apply_batch(&g, &delta);
        }
        // stop_when_topk_certified: the epoch ends at the proof, not at
        // residual_exact < tol
        let st = solve_certified_sharded(&mut sp, &g, &mut tracker, tol, u64::MAX, true);
        match st.pushes_to_cert {
            Some(at) => println!(
                "epoch {epoch}: head certified after {at} pushes \
                 (margin {:.1e}; full convergence would keep pushing)",
                st.cert.margin()
            ),
            None => println!(
                "epoch {epoch}: head contested (ties?) — ran to convergence, \
                 {} pushes",
                st.pushes
            ),
        }
        // what a results page would render: ranks with certified
        // enclosures — the intervals are disjoint across the boundary,
        // that is exactly what the certificate asserts
        let bounds = interval_bounds_sharded(&mut sp);
        for (pos, &page) in st.cert.head.iter().enumerate() {
            let (lo, hi) = bounds[page as usize];
            println!("    #{:<2} page {:<6} rank in [{lo:.3e}, {hi:.3e}]", pos + 1, page);
        }
    }
    println!("\nevery head above is provably the true top-{k} of its snapshot —");
    println!("no converged reference needed at serving time, the residual is the proof.");
    Ok(())
}
