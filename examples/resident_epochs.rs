//! Epoch-resident sharded push — churn injected into live shards.
//!
//! Where `examples/parallel_push.rs` scatters a global push state into
//! shards every epoch and gathers it back, this loop builds ONE
//! `ShardedPush` and keeps it resident: each churn batch is injected
//! directly into the owning shards (`ShardedPush::apply_batch`), the
//! shard bounds re-balance once arrivals skew the degree distribution
//! (`ShardedPush::rebalance`, here via the threaded driver's
//! `rebalance_factor`), and the CSR snapshot consumed by the static
//! stack is spliced incrementally (`DeltaGraph::merge_csr`) instead of
//! rebuilt. Run with:
//!
//! ```sh
//! cargo run --release --example resident_epochs
//! ```

use asyncpr::asynciter::{run_threaded_push, PushThreadOptions};
use asyncpr::graph::generators::{self, churn_batch, ChurnParams};
use asyncpr::stream::{power_method_f64, DeltaGraph, ShardedPush};
use asyncpr::util::Rng;

fn main() -> anyhow::Result<()> {
    let threads = 4;
    let tol = 1e-10;
    let el = generators::power_law_web(&generators::WebParams::scaled(20_000), 42);
    let mut g = DeltaGraph::from_edgelist(&el);
    println!(
        "web: n = {}, m = {}, {threads} epoch-resident shards\n",
        g.n(),
        g.m()
    );

    // the one sharded state the whole run lives in
    let mut sharded = ShardedPush::new(&g, 0.85, threads);
    let opts = PushThreadOptions {
        tol,
        rebalance_factor: Some(2.0),
        ..Default::default()
    };
    let tm = run_threaded_push(&g, &mut sharded, &opts);
    if !tm.converged {
        sharded.solve(&g, tol, u64::MAX);
    }
    println!(
        "cold build: {} pushes, {:.1} ms, residual {:.1e}",
        sharded.total_pushes(),
        tm.wall.as_secs_f64() * 1e3,
        tm.residual
    );

    // splice-chain baseline for the static stack's CSR snapshot
    let mut csr = g.to_csr()?;
    let churn = ChurnParams::scaled_to(g.n(), g.m());
    let mut rng = Rng::new(7);
    for epoch in 1..=3 {
        let batch = churn_batch(&g, &churn, &mut rng);
        let delta = g.apply(&batch)?;
        sharded.begin_epoch();
        // inject in place: corrections route to their owning shards as
        // residual fragments — no scatter, no gather, no global state
        let p0 = sharded.total_pushes();
        sharded.apply_batch(&g, &delta);
        let (next, ms) = g.merge_csr(&csr)?;
        csr = next;
        let tm = run_threaded_push(&g, &mut sharded, &opts);
        if !tm.converged {
            sharded.solve(&g, tol, u64::MAX);
        }

        let (xref, _) = power_method_f64(&g, 0.85, 1e-11, 10_000);
        let l1: f64 = sharded
            .ranks()
            .iter()
            .zip(&xref)
            .map(|(a, b)| (a - b).abs())
            .sum();
        println!(
            "epoch {epoch}: +{}n +{}e -{}e -> {} pushes, {} touched rows, \
             {} CSR rows spliced (of {}), rebalanced: {}, L1 vs power {l1:.1e}",
            batch.new_nodes,
            delta.inserted,
            delta.removed,
            sharded.total_pushes() - p0,
            sharded.touched(),
            ms.dirty_rows,
            g.n(),
            tm.rebalanced,
        );
    }
    println!("\nno epoch ever paid the O(n) scatter/gather or the O(n+m) CSR");
    println!("rebuild — the state stays resident, the work stays churn-sized.");
    Ok(())
}
