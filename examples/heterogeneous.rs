//! Heterogeneous cluster: the motivating scenario of the paper's
//! introduction — "the elimination of the synchronizing phases is
//! expected to be advantageous on heterogeneous platforms."
//!
//! One node of the cluster is progressively slowed down. Synchronous
//! execution degrades with the slowest node (the barrier waits for
//! it); asynchronous execution lets fast nodes keep iterating, so it
//! degrades far more gracefully.
//!
//!     cargo run --release --example heterogeneous

use std::sync::Arc;

use asyncpr::asynciter::{BlockOperator, Mode, NativeBlockOp, RunSpec, SimEngine};
use asyncpr::coordinator::Partitioner;
use asyncpr::graph::{generators, Csr};
use asyncpr::pagerank::PagerankProblem;
use asyncpr::simnet::ClusterProfile;
use asyncpr::util::Table;

fn ops_for(
    problem: &Arc<PagerankProblem>,
    p: usize,
) -> Vec<Box<dyn BlockOperator>> {
    Partitioner::consecutive(problem.n(), p)
        .blocks()
        .into_iter()
        .map(|(lo, hi)| {
            Box::new(NativeBlockOp::new(problem.clone(), lo, hi)) as Box<dyn BlockOperator>
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    let el = generators::power_law_web(&generators::WebParams::scaled(28_190), 11);
    let problem = Arc::new(PagerankProblem::new(Csr::from_edgelist(&el)?, 0.85));
    let p = 4;

    let mut table = Table::new(&[
        "slowdown of node 3",
        "sync t (s)",
        "async t_max (s)",
        "async advantage",
    ]);
    println!("p = {p}, one straggler node, local tol 1e-6\n");
    for slow in [1.0f64, 2.0, 4.0, 8.0] {
        let profile = ClusterProfile::paper_beowulf(p).with_slow_node(p - 1, slow);
        let sim_problem = problem.clone();
        let run = |mode: Mode| {
            let mut ops = ops_for(&sim_problem, p);
            SimEngine::new(&profile, &sim_problem).run(&mut ops, &RunSpec::paper_table1(mode))
        };
        let sync = run(Mode::Synchronous);
        let asyn = run(Mode::Asynchronous);
        let (_, a_tmax) = asyn.time_range();
        table.row(&[
            format!("{slow}x"),
            format!("{:.1}", sync.total_time),
            format!("{:.1}", a_tmax),
            format!("{:.2}x", sync.total_time / a_tmax),
        ]);
    }
    println!("{}", table.to_markdown());
    println!(
        "sync time tracks the slowest node (barrier); async degrades gracefully\n\
         (fast nodes keep iterating on stale data, straggler catches up on import)"
    );
    Ok(())
}
