//! Intra-epoch work stealing — an idle shard adopts a loaded peer's
//! hottest rows mid-drain.
//!
//! The between-epoch re-balancer (`ShardedPush::rebalance`) fixes
//! *durable* skew: churn moved the nnz distribution, so the bounds
//! move at the epoch boundary. This example shows the *transient* skew
//! it cannot fix: a churn burst confined to one shard's row range
//! leaves that shard draining a deep residual queue while its peers
//! idle-spin their quiet windows. With `--steal` semantics
//! (`PushThreadOptions { steal: true, .. }`) the idle workers request
//! rows over the same bounded channels the residual fragments ride,
//! ownership migrates losslessly, and the makespan (max per-shard
//! pushes) drops. Run with:
//!
//! ```sh
//! cargo run --release --example work_stealing
//! ```

use asyncpr::asynciter::{run_threaded_push, PushThreadOptions};
use asyncpr::stream::{power_method_f64, DeltaGraph, ShardedPush, UpdateBatch};
use asyncpr::util::Rng;

fn main() -> anyhow::Result<()> {
    let shards = 4;
    let tol = 1e-10;
    let el = asyncpr::graph::generators::power_law_web(
        &asyncpr::graph::generators::WebParams::scaled(20_000),
        42,
    );
    let mut g = DeltaGraph::from_edgelist(&el);
    println!("web: n = {}, m = {}, {shards} shards\n", g.n(), g.m());

    // converge once, so the only remaining work is what the burst injects
    let mut warm = ShardedPush::new(&g, 0.85, shards);
    let st = warm.solve(&g, tol, u64::MAX);
    println!("cold build: {} pushes (converged: {})", st.pushes, st.converged);

    // a churn burst confined to the LAST shard's row range: every unit
    // of injected residual is owned by one shard
    let bounds = warm.partitioner().bounds().to_vec();
    let (blo, bhi) = (bounds[bounds.len() - 2], bounds[bounds.len() - 1]);
    let mut rng = Rng::new(7);
    let mut batch = UpdateBatch::default();
    for _ in 0..2_000 {
        batch
            .insert
            .push((rng.range(blo, bhi) as u32, rng.range(blo, bhi) as u32));
    }
    let delta = g.apply(&batch)?;
    warm.begin_epoch();
    warm.apply_batch(&g, &delta);
    println!(
        "burst: {} inserts confined to rows [{blo}, {bhi}) — all residual lands on shard {}\n",
        delta.inserted,
        shards - 1
    );

    // identical warm states through both policies
    for steal in [false, true] {
        let mut sp = warm.clone();
        let opts = PushThreadOptions { tol, steal, steal_batch: 64, ..Default::default() };
        let tm = run_threaded_push(&g, &mut sp, &opts);
        if !tm.converged {
            sp.solve(&g, tol, u64::MAX);
        }
        let makespan = tm.shard_pushes.iter().copied().max().unwrap_or(0);
        println!(
            "{}: per-shard pushes {:?} (makespan {makespan}), idle rounds {:?}",
            if steal { "steal " } else { "static" },
            tm.shard_pushes,
            tm.idle_rounds,
        );
        if steal {
            println!(
                "        {} rows changed owner across {} grants; owner map contiguous \
                 again: {}",
                tm.stolen_rows.iter().sum::<u64>(),
                tm.steal_grants.iter().sum::<u64>(),
                // the run leaves ownership displaced; the next epoch
                // boundary (apply_batch / rebalance / gather) folds it
                sp.owner_map().is_contiguous(),
            );
            // fold it explicitly and prove nothing moved
            let x0 = sp.ranks();
            sp.repatriate();
            let x1 = sp.ranks();
            let drift: f64 = x0.iter().zip(&x1).map(|(a, b)| (a - b).abs()).sum();
            println!("        repatriated: owner map contiguous, rank drift {drift:.1e}");
        }
        // every policy lands on the same fixed point
        let (xref, _) = power_method_f64(&g, 0.85, 1e-12, 10_000);
        let l1: f64 = sp.ranks().iter().zip(&xref).map(|(a, b)| (a - b).abs()).sum();
        println!("        L1 vs power reference: {l1:.1e}\n");
    }
    Ok(())
}
