//! Figure-1 protocol trace: drive the termination state machines
//! through the exact scenario the paper's pseudocode describes and
//! print every transition — a runnable version of Figure 1, plus the
//! tree-based decentralized detector of §4.2/§6 side by side.
//!
//!     cargo run --release --example termination_trace

use asyncpr::termination::tree::TreeNode;
use asyncpr::termination::{MonitorTermination, TermMsg, WorkerTermination};

fn main() {
    println!("=== centralized protocol (Figure 1), p = 3, pcMax worker=2 monitor=1 ===\n");
    let p = 3;
    let mut workers: Vec<WorkerTermination> =
        (0..p).map(|_| WorkerTermination::new(2)).collect();
    let mut monitor = MonitorTermination::new(p, 1);

    // residual script per UE per iteration (true = locally converged)
    let script: [&[bool]; 3] = [
        &[false, true, true, true, true, true],
        &[false, false, true, true, false, true, true, true],
        &[false, true, true, false, true, true, true, true],
    ];
    let mut stopped = false;
    for step in 0..8 {
        for ue in 0..p {
            let Some(&conv) = script[ue].get(step) else { continue };
            if let Some(msg) = workers[ue].on_iteration(conv) {
                println!("t={step}: UE{ue} -> monitor: {msg:?} (pc hit pcMax)");
                if monitor.on_message(ue, msg) {
                    println!(
                        "t={step}: monitor: all {p} UEs logged CONVERGE, pc reached pcMax -> STOP to all"
                    );
                    stopped = true;
                }
            } else {
                println!(
                    "t={step}: UE{ue} iter: locally_converged={conv} pc={} (silent)",
                    workers[ue].pc()
                );
            }
            if stopped {
                break;
            }
        }
        if stopped {
            break;
        }
    }
    assert!(stopped, "script should reach STOP");

    println!("\n=== decentralized tree detector (p = 7 binary tree, pcMax(root)=1 ===\n");
    let p = 7;
    let mut nodes: Vec<TreeNode> = (0..p).map(|i| TreeNode::new(i, p, 1)).collect();
    let mut queue: Vec<(usize, usize, asyncpr::termination::tree::TreeMsg)> = Vec::new();
    for ue in (0..p).rev() {
        let fx = nodes[ue].on_local(true);
        for (dst, msg) in fx.send {
            println!("UE{ue} -> UE{dst}: {msg:?}");
            queue.push((ue, dst, msg));
        }
    }
    while let Some((src, dst, msg)) = queue.pop() {
        let fx = nodes[dst].on_message(src, msg);
        for (d2, m2) in fx.send {
            println!("UE{dst} -> UE{d2}: {m2:?}");
            queue.push((dst, d2, m2));
        }
    }
    assert!(nodes.iter().all(|n| n.stopped()));
    println!("\nall {p} nodes stopped via tree flood — no central monitor required");
}
