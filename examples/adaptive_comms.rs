//! Adaptive communication (§6 future work, implemented here): "if
//! message sending/receiving tasks fail to complete within a number of
//! local iterations, reduce the rate of message exchanges with this
//! not well 'responding' node."
//!
//! The controller keeps a per-peer send period; every cancelled send
//! doubles it (up to 16 iterations), every delivered send decays it by
//! one. On a saturated wire this sheds exactly the traffic that would
//! have been cancelled anyway, freeing capacity for the messages that
//! do fit.
//!
//!     cargo run --release --example adaptive_comms

use std::sync::Arc;

use asyncpr::asynciter::{BlockOperator, Mode, NativeBlockOp, RunSpec, SimEngine, StopRule};
use asyncpr::coordinator::Partitioner;
use asyncpr::graph::{generators, Csr};
use asyncpr::pagerank::PagerankProblem;
use asyncpr::simnet::ClusterProfile;
use asyncpr::util::Table;

fn main() -> anyhow::Result<()> {
    let el = generators::power_law_web(&generators::WebParams::scaled(28_190), 13);
    let problem = Arc::new(PagerankProblem::new(Csr::from_edgelist(&el)?, 0.85));
    let p = 6; // the most wire-saturated configuration of the paper
    // 1/10-scale graph: shrink the wire so the paper's saturation
    // (demand/capacity) ratio is preserved at p=6
    let bw_scale = ClusterProfile::demand_matched_scale(28_190, 6);

    let mut table = Table::new(&[
        "scheme",
        "t to global 1e-4 (s)",
        "iters_max",
        "sends attempted",
        "cancelled",
        "wire queue wait (s)",
        "global resid",
    ]);
    for (name, adaptive) in [("every-step (paper)", false), ("adaptive (§6)", true)] {
        let mut profile = ClusterProfile::paper_beowulf(p);
        profile.bandwidth *= bw_scale;
        let mut ops: Vec<Box<dyn BlockOperator>> = Partitioner::consecutive(problem.n(), p)
            .blocks()
            .into_iter()
            .map(|(lo, hi)| {
                Box::new(NativeBlockOp::new(problem.clone(), lo, hi)) as Box<dyn BlockOperator>
            })
            .collect();
        // race both schemes to the SAME true global residual so the
        // comparison is accuracy-fair (under extreme saturation the
        // local protocol would stop early on frozen data)
        let spec = RunSpec {
            mode: Mode::Asynchronous,
            stop: StopRule::GlobalThreshold { tol: 1e-4 },
            adaptive,
            seed: 42,
            max_total_iters: 2_000_000,
        };
        let m = SimEngine::new(&profile, &problem).run(&mut ops, &spec);
        let (_, imax) = m.iters_range();
        table.row(&[
            name.to_string(),
            format!("{:.1}", m.total_time),
            imax.to_string(),
            m.sends_attempted.iter().sum::<u64>().to_string(),
            m.wire_cancelled.to_string(),
            format!("{:.1}", m.wire_queue_wait),
            format!("{:.1e}", m.final_global_residual),
        ]);
    }
    println!("{}", table.to_markdown());
    println!(
        "adaptive rate control sheds the sends the wire would cancel anyway;\n\
         the surviving fragments flow sooner, so the same global accuracy is\n\
         reached faster with a fraction of the traffic — the §6 prescription."
    );
    Ok(())
}
