"""Shape buckets shared between the AOT compiler and the rust runtime.

The rust coordinator executes one PJRT artifact per (N, B, K) bucket:

  N  -- padded length of the global PageRank iterate x (power of two,
        >= number of physical rows + virtual rows after ELL splitting)
  B  -- padded number of rows in one UE's block (ELL rows, incl. virtual)
  K  -- ELL width: padded slots per row; rows with outdegree > K are
        split into virtual rows by the rust side (graph::ell), so the
        kernel never needs a CSR fallback.

Buckets are chosen so that every experiment in DESIGN.md §5 has an exact
artifact: quickstart graphs, mid-size synthetic webs, and the
Stanford-Web-like graph (n = 281,903 -> N = 2^19 after virtual rows).

The manifest (artifacts/manifest.json) records, for every emitted
artifact, the argument order and shapes so the rust loader can validate
at startup instead of failing inside PJRT.
"""

from dataclasses import dataclass, asdict


@dataclass(frozen=True)
class Bucket:
    """One AOT shape bucket. All dims static (HLO requires it)."""

    name: str
    n: int  # padded global vector length
    b: int  # padded block rows (ELL rows incl. virtual rows)
    k: int  # ELL width (padded slots per row)

    def artifact_name(self, kernel: str) -> str:
        return f"{kernel}_n{self.n}_b{self.b}_k{self.k}"

    def to_dict(self) -> dict:
        return asdict(self)


#: The buckets `make artifacts` compiles. Keep this list small -- every
#: bucket costs one jax lowering at build time -- but complete enough
#: that DESIGN.md's experiment table never falls back to native SpMV
#: when it intends to exercise the artifact path.
BUCKETS: tuple[Bucket, ...] = (
    # quickstart / unit-test scale
    Bucket("tiny", n=1 << 10, b=1 << 9, k=8),
    # examples / integration-test scale
    Bucket("small", n=1 << 12, b=1 << 11, k=16),
    # mid-size synthetic web (ablations)
    Bucket("mid", n=1 << 15, b=1 << 13, k=16),
    # Stanford-Web-like: 281,903 rows + virtual rows < 2^19
    Bucket("stanford", n=1 << 19, b=1 << 17, k=16),
)

#: Kernels emitted per bucket; order of args is part of the ABI with rust.
KERNELS = ("pagerank_step",)

#: Argument order for the pagerank_step artifact (ABI with rust/runtime):
#:   vals      f32[B, K]   ELL values of this UE's row block (alpha NOT folded)
#:   cols      i32[B, K]   ELL column indices (padded slots point at 0 with val 0)
#:   x         f32[N]      current global iterate snapshot
#:   bias      f32[B]      (1 - alpha) * v restricted to the block rows
#:   dang      f32[1]      alpha * (d . x) / n  (dangling mass, precomputed)
#:   alpha     f32[1]      relaxation parameter
#: returns (y f32[B], resid f32[1]) where resid = sum |y - x_block_old|;
#: x_block_old is x[row_offset : row_offset + B] -- passed separately:
#:   xold      f32[B]
ARG_ORDER = ("vals", "cols", "x", "xold", "bias", "dang", "alpha")


def bucket_by_name(name: str) -> Bucket:
    for bkt in BUCKETS:
        if bkt.name == name:
            return bkt
    raise KeyError(f"unknown shape bucket: {name!r}")


def smallest_bucket(n_rows: int, block_rows: int, width: int) -> Bucket:
    """Smallest bucket that fits a (n_rows, block_rows, width) problem."""
    for bkt in sorted(BUCKETS, key=lambda b: (b.n, b.b, b.k)):
        if bkt.n >= n_rows and bkt.b >= block_rows and bkt.k >= width:
            return bkt
    raise ValueError(
        f"no shape bucket fits n={n_rows} b={block_rows} k={width}; "
        f"largest is {BUCKETS[-1]}"
    )
