"""L2: the JAX compute graph the rust coordinator executes via PJRT.

The model is the paper's per-UE update, eq. (6):

    x_{i}(t+1) = G_i [x_{1}(tau) ... x_{p}(tau)]^T

realised as `block_step`: a fused Pallas SpMV + dangling + teleport +
L1-residual over the UE's ELLPACK row block. `aot.py` lowers
`block_step` once per shape bucket (compile.shapes.BUCKETS) to HLO text;
after that Python never runs again.

Everything here is shape-generic; static shapes are pinned only at
lowering time by aot.py.
"""

import jax
import jax.numpy as jnp

from .kernels import pagerank_step
from .kernels.ref import spmv_ell_ref


def block_step(vals, cols, x, xold, bias, dang, alpha, *, tile_r=None):
    """One asynchronous PageRank update for a row block (eq. 6) plus the
    local L1 residual used by the Figure-1 termination protocol.

    ABI documented in compile.shapes.ARG_ORDER; returns (y, resid).

    tile_r picks the Pallas row-tile schedule. None keeps the kernel
    default (the TPU-oriented streaming tile); the CPU AOT path lowers
    with tile_r = block rows (a single tile) because interpret-mode
    grids execute as an XLA while-loop whose per-tile overhead dwarfs
    the arithmetic — see EXPERIMENTS.md §Perf (123x).
    """
    if tile_r is None:
        return pagerank_step(vals, cols, x, xold, bias, dang, alpha)
    return pagerank_step(vals, cols, x, xold, bias, dang, alpha, tile_r=tile_r)


def block_step_ref(vals, cols, x, xold, bias, dang, alpha):
    """Pure-jnp twin of `block_step` (no pallas). Lowered alongside the
    kernel version so rust benches can A/B the artifact paths."""
    y = alpha[0] * spmv_ell_ref(vals, cols, x) + dang[0] + bias
    resid = jnp.sum(jnp.abs(y - xold), keepdims=True)
    return y, resid


def power_steps(vals, cols, x, bias, dang_mask, alpha, *, steps: int):
    """`steps` synchronous power iterations over the FULL matrix
    (single-UE case, eq. 4), scan-fused so XLA sees one loop.

    Used by the quickstart artifact and by L2 tests; `dang_mask` is the
    f32 indicator of dangling rows.
    """
    n = x.shape[0]
    inv_n = jnp.float32(1.0) / jnp.float32(n)

    def body(carry, _):
        xi = carry
        dang = alpha[0] * jnp.dot(dang_mask, xi) * inv_n
        y, _ = pagerank_step(
            vals, cols, xi, xi, bias, dang[None], alpha
        )
        return y, None

    out, _ = jax.lax.scan(body, x, None, length=steps)
    return out


def block_step_v2(vals, cols, x, xold, bias, dang_mask, alpha):
    """Variant ABI: the dangling correction is computed INSIDE the
    artifact from the dangling indicator vector, so the rust hot loop
    never touches the snapshot before executing.

    Args match block_step except `dang` (scalar) is replaced by
    `dang_mask`: f32[N] with 1.0 at dangling pages. Returns (y, resid).
    """
    n = x.shape[0]
    dang = alpha[0] * jnp.dot(dang_mask, x) / jnp.float32(n)
    return pagerank_step(vals, cols, x, xold, bias, dang[None], alpha)
