"""AOT compiler: lower the L2 model to HLO-text artifacts for rust.

Emits, for every shape bucket in compile.shapes.BUCKETS:

    artifacts/pagerank_step_n{N}_b{B}_k{K}.hlo.txt

plus artifacts/manifest.json recording the ABI (argument order, shapes,
dtypes, output arity) the rust runtime validates at startup.

Interchange format is HLO **text**, not a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import json
import pathlib

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .shapes import ARG_ORDER, BUCKETS, Bucket


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def arg_specs(bucket: Bucket) -> dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStructs for one bucket, keyed by ABI argument name."""
    n, b, k = bucket.n, bucket.b, bucket.k
    return {
        "vals": jax.ShapeDtypeStruct((b, k), jnp.float32),
        "cols": jax.ShapeDtypeStruct((b, k), jnp.int32),
        "x": jax.ShapeDtypeStruct((n,), jnp.float32),
        "xold": jax.ShapeDtypeStruct((b,), jnp.float32),
        "bias": jax.ShapeDtypeStruct((b,), jnp.float32),
        "dang": jax.ShapeDtypeStruct((1,), jnp.float32),
        "alpha": jax.ShapeDtypeStruct((1,), jnp.float32),
    }


def lower_bucket(bucket: Bucket, fn=None) -> str:
    specs = arg_specs(bucket)
    args = [specs[name] for name in ARG_ORDER]
    if fn is None:
        # CPU schedule: one Pallas tile over the whole block (the
        # interpret-mode grid loop costs ~100x at streaming tile sizes)
        fn = lambda *a: model.block_step(*a, tile_r=bucket.b)
    lowered = jax.jit(fn).lower(*args)
    return to_hlo_text(lowered)


def manifest_entry(bucket: Bucket, kernel: str, path: str) -> dict:
    specs = arg_specs(bucket)
    return {
        "kernel": kernel,
        "bucket": bucket.to_dict(),
        "path": path,
        "args": [
            {
                "name": name,
                "shape": list(specs[name].shape),
                "dtype": str(specs[name].dtype),
            }
            for name in ARG_ORDER
        ],
        "outputs": [
            {"name": "y", "shape": [bucket.b], "dtype": "float32"},
            {"name": "resid", "shape": [1], "dtype": "float32"},
        ],
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts",
                    help="artifact output directory")
    ap.add_argument("--buckets", default="",
                    help="comma-separated bucket names (default: all)")
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    wanted = set(filter(None, args.buckets.split(","))) or {
        b.name for b in BUCKETS
    }

    entries = []
    for bucket in BUCKETS:
        if bucket.name not in wanted:
            continue
        name = bucket.artifact_name("pagerank_step")
        text = lower_bucket(bucket)
        path = out_dir / f"{name}.hlo.txt"
        path.write_text(text)
        entries.append(manifest_entry(bucket, "pagerank_step", path.name))
        print(f"wrote {path} ({len(text)} chars, bucket={bucket.name})")

    manifest = {
        "version": 1,
        "arg_order": list(ARG_ORDER),
        "artifacts": entries,
    }
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    print(f"wrote {out_dir / 'manifest.json'} ({len(entries)} artifacts)")


if __name__ == "__main__":
    main()
