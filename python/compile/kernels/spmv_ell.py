"""L1 Pallas kernel: tiled ELLPACK SpMV.

The compute hot spot of the paper's PageRank iteration -- the sparse
matrix-vector product y = M x for one UE's row block -- expressed as a
Pallas kernel over the padded ELLPACK layout (see DESIGN.md
§Hardware-Adaptation for why ELL and not CSR on a TPU-shaped target).

Tiling:
  grid = (B // TILE_R,)
  vals/cols stream through VMEM one (TILE_R, K) row tile at a time;
  the dense iterate x stays VMEM-resident across the whole grid
  (n * 4 bytes <= ~2 MB for every bucket in shapes.py, far below the
  16 MB VMEM budget), so the gather x[cols] never touches HBM twice.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret-mode lowers the kernel to plain HLO
(gather/multiply/reduce inside a loop), which both pytest and the rust
runtime can run. Structural VMEM/MXU estimates for a real TPU are in
DESIGN.md / EXPERIMENTS.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Row-tile height. 512 rows x K=16 slots x (4B val + 4B idx) = 64 KiB of
#: streaming VMEM per step -- small against the resident x, large enough
#: to amortize the grid-loop overhead. Revisited in the perf pass.
DEFAULT_TILE_R = 512


def _spmv_ell_kernel(vals_ref, cols_ref, x_ref, y_ref):
    """One (TILE_R, K) tile: y = sum_k vals * x[cols]."""
    vals = vals_ref[...]            # (TILE_R, K)  f32
    cols = cols_ref[...]            # (TILE_R, K)  i32
    x = x_ref[...]                  # (N,)         f32, VMEM-resident
    gathered = x[cols]              # (TILE_R, K) gather from the iterate
    y_ref[...] = jnp.sum(vals * gathered, axis=1)


@functools.partial(jax.jit, static_argnames=("tile_r",))
def spmv_ell(vals, cols, x, *, tile_r: int = DEFAULT_TILE_R):
    """y = M x with M in padded ELLPACK form.

    Args:
      vals: f32[B, K] -- B divisible by tile_r; padded slots are 0.0.
      cols: i32[B, K] -- padded slots point at column 0.
      x:    f32[N]    -- dense iterate.
      tile_r: row-tile height (static).

    Returns: f32[B].
    """
    b, k = vals.shape
    tile_r = min(tile_r, b)  # small blocks: single tile
    if b % tile_r != 0:
        raise ValueError(f"block rows {b} not divisible by tile_r {tile_r}")
    n = x.shape[0]
    grid = (b // tile_r,)
    return pl.pallas_call(
        _spmv_ell_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_r, k), lambda i: (i, 0)),   # stream row tiles
            pl.BlockSpec((tile_r, k), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),            # x resident
        ],
        out_specs=pl.BlockSpec((tile_r,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), vals.dtype),
        interpret=True,
    )(vals, cols, x)
