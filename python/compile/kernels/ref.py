"""Pure-jnp oracles for the Pallas kernels.

These are the correctness signal for the whole stack: L1 kernels must
match these (allclose), and the rust native SpMV is cross-checked
against artifact outputs that were themselves checked against these.

All functions take the same ELL-block arguments as the kernels
(see compile.shapes.ARG_ORDER) and are written with plain jnp ops only,
in the most obvious way possible -- no tiling, no tricks.
"""

import jax.numpy as jnp


def spmv_ell_ref(vals: jnp.ndarray, cols: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """y[i] = sum_k vals[i, k] * x[cols[i, k]].

    Padded slots must carry vals == 0 (their col index is then
    irrelevant; the convention is col = 0).
    """
    return jnp.sum(vals * x[cols], axis=1)


def pagerank_step_ref(
    vals: jnp.ndarray,
    cols: jnp.ndarray,
    x: jnp.ndarray,
    xold: jnp.ndarray,
    bias: jnp.ndarray,
    dang: jnp.ndarray,
    alpha: jnp.ndarray,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One PageRank update for a row block, eq. (6) of the paper.

    y = alpha * (P^T x)_block + alpha * (d.x)/n + (1-alpha) * v_block
      =: alpha * spmv + dang + bias

    where `dang` and `bias` are precomputed by the caller (rust L3 or
    the L2 model), plus the L1 residual against the previous block
    iterate `xold`.
    """
    y = alpha[0] * spmv_ell_ref(vals, cols, x) + dang[0] + bias
    resid = jnp.sum(jnp.abs(y - xold), keepdims=True)
    return y, resid


def power_iterate_ref(vals, cols, x, bias, dang_mask, alpha, steps: int):
    """Reference synchronous power iteration over a FULL matrix in ELL
    form (block == whole matrix). Used by model tests only.

    dang_mask: f32[N] with 1.0 at dangling rows (outdegree 0).
    bias: (1-alpha) * v (full length).
    """
    n = x.shape[0]
    for _ in range(steps):
        dang = alpha * jnp.dot(dang_mask, x) / n
        x = alpha * spmv_ell_ref(vals, cols, x) + dang + bias
    return x
