# L1: Pallas kernels for the paper's compute hot-spot.
from .spmv_ell import spmv_ell, DEFAULT_TILE_R
from .pagerank_step import pagerank_step
from . import ref

__all__ = ["spmv_ell", "pagerank_step", "ref", "DEFAULT_TILE_R"]
