"""L1 Pallas kernel: fused PageRank block update (eq. 6 of the paper).

One kernel invocation computes, for a UE's row block,

    y = alpha * (M x)_block + dang + bias          (fused with the SpMV)

and a per-tile partial L1 residual |y - xold| that the surrounding L2
model reduces to the scalar local-convergence signal of the paper's
termination protocol (Figure 1).

Fusion rationale (DESIGN.md §Hardware-Adaptation): the paper's per-step
work is ONE pass over the block's nonzeros; splitting SpMV / scale /
teleport / residual into separate ops would re-read y three times from
HBM. The fused kernel writes y once and keeps the residual reduction in
registers/VMEM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .spmv_ell import DEFAULT_TILE_R


def _pagerank_step_kernel(
    vals_ref, cols_ref, x_ref, xold_ref, bias_ref, dang_ref, alpha_ref,
    y_ref, partial_ref,
):
    """One (TILE_R, K) tile of the fused update + residual partial."""
    vals = vals_ref[...]             # (TILE_R, K) f32
    cols = cols_ref[...]             # (TILE_R, K) i32
    x = x_ref[...]                   # (N,)        f32, resident
    xold = xold_ref[...]             # (TILE_R,)
    bias = bias_ref[...]             # (TILE_R,)
    dang = dang_ref[0]               # scalar: alpha * (d.x) / n
    alpha = alpha_ref[0]             # scalar

    spmv = jnp.sum(vals * x[cols], axis=1)          # (TILE_R,)
    y = alpha * spmv + dang + bias
    y_ref[...] = y
    partial_ref[0] = jnp.sum(jnp.abs(y - xold))     # per-tile L1 partial


@functools.partial(jax.jit, static_argnames=("tile_r",))
def pagerank_step(vals, cols, x, xold, bias, dang, alpha,
                  *, tile_r: int = DEFAULT_TILE_R):
    """Fused PageRank block step. See compile.shapes.ARG_ORDER for ABI.

    Args:
      vals:  f32[B, K]  ELL values (row-stochastic P^T entries, alpha NOT folded).
      cols:  i32[B, K]  ELL column indices.
      x:     f32[N]     global iterate snapshot.
      xold:  f32[B]     previous local block iterate (residual baseline).
      bias:  f32[B]     (1 - alpha) * v over the block rows.
      dang:  f32[1]     alpha * (d . x) / n.
      alpha: f32[1]     relaxation parameter.

    Returns: (y f32[B], resid f32[1]) with resid = sum_i |y_i - xold_i|.
    """
    b, k = vals.shape
    tile_r = min(tile_r, b)  # small blocks: single tile
    if b % tile_r != 0:
        raise ValueError(f"block rows {b} not divisible by tile_r {tile_r}")
    n = x.shape[0]
    tiles = b // tile_r
    y, partials = pl.pallas_call(
        _pagerank_step_kernel,
        grid=(tiles,),
        in_specs=[
            pl.BlockSpec((tile_r, k), lambda i: (i, 0)),   # vals: stream
            pl.BlockSpec((tile_r, k), lambda i: (i, 0)),   # cols: stream
            pl.BlockSpec((n,), lambda i: (0,)),            # x: resident
            pl.BlockSpec((tile_r,), lambda i: (i,)),       # xold: stream
            pl.BlockSpec((tile_r,), lambda i: (i,)),       # bias: stream
            pl.BlockSpec((1,), lambda i: (0,)),            # dang: scalar
            pl.BlockSpec((1,), lambda i: (0,)),            # alpha: scalar
        ],
        out_specs=[
            pl.BlockSpec((tile_r,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),            # one partial/tile
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b,), vals.dtype),
            jax.ShapeDtypeStruct((tiles,), vals.dtype),
        ],
        interpret=True,
    )(vals, cols, x, xold, bias, dang, alpha)
    resid = jnp.sum(partials, keepdims=True)       # final reduce in XLA
    return y, resid
