"""AOT pipeline: lowering, manifest ABI, and compiled-executable numerics.

The text-level round trip (HLO text -> xla crate -> PJRT) is exercised
by `cargo test` on the rust side; here we pin down everything we can
check from python: the lowered computation compiles and matches the
oracle on concrete inputs, the manifest records the exact ABI rust
expects, and the emitted text is well-formed HLO.
"""

import json
import pathlib

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import aot, model
from compile.shapes import ARG_ORDER, BUCKETS, Bucket, bucket_by_name, smallest_bucket
from compile.kernels.ref import pagerank_step_ref

TINY = bucket_by_name("tiny")


def concrete_inputs(bucket: Bucket, seed=0):
    rng = np.random.default_rng(seed)
    n, b, k = bucket.n, bucket.b, bucket.k
    mask = rng.random((b, k)) < 0.4
    vals = np.where(mask, rng.random((b, k)), 0.0).astype(np.float32)
    cols = np.where(mask, rng.integers(0, n, (b, k)), 0).astype(np.int32)
    x = rng.random(n, dtype=np.float32)
    xold = x[:b].copy()
    bias = np.full(b, 0.15 / n, np.float32)
    dang = np.array([0.001], np.float32)
    alpha = np.array([0.85], np.float32)
    return dict(vals=vals, cols=cols, x=x, xold=xold, bias=bias,
                dang=dang, alpha=alpha)


class TestLowering:
    def test_hlo_text_wellformed(self):
        text = aot.lower_bucket(TINY)
        assert "ENTRY" in text and "HloModule" in text
        # gather (the SpMV x[cols]) must be present -- the hot spot
        assert "gather" in text

    def test_compiled_matches_ref(self):
        """jit-compiled block_step at bucket shapes == oracle."""
        ins = concrete_inputs(TINY)
        args = [ins[name] for name in ARG_ORDER]
        y, r = jax.jit(model.block_step)(*args)
        y_ref, r_ref = pagerank_step_ref(*args)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(r), np.asarray(r_ref), rtol=1e-4)

    def test_kernel_and_ref_model_agree(self):
        """The pallas path and the pure-jnp L2 twin lower to the same
        numbers (what the rust A/B bench relies on)."""
        ins = concrete_inputs(TINY, seed=9)
        args = [ins[name] for name in ARG_ORDER]
        y1, r1 = jax.jit(model.block_step)(*args)
        y2, r2 = jax.jit(model.block_step_ref)(*args)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(r1), np.asarray(r2), rtol=1e-4)


class TestShapes:
    def test_buckets_sorted_and_unique(self):
        names = [b.name for b in BUCKETS]
        assert len(set(names)) == len(names)
        for b in BUCKETS:
            assert b.n >= b.b, "block cannot exceed vector length"
            assert b.n & (b.n - 1) == 0, "n must be a power of two"

    def test_smallest_bucket_selection(self):
        assert smallest_bucket(1000, 500, 8).name == "tiny"
        assert smallest_bucket(1025, 500, 8).name == "small"
        assert smallest_bucket(300_000, 100_000, 16).name == "stanford"

    def test_smallest_bucket_overflow_raises(self):
        with pytest.raises(ValueError):
            smallest_bucket(10**9, 1, 1)

    def test_artifact_name_stable(self):
        assert TINY.artifact_name("pagerank_step") == "pagerank_step_n1024_b512_k8"


class TestManifest:
    def test_manifest_entry_abi(self):
        entry = aot.manifest_entry(TINY, "pagerank_step", "x.hlo.txt")
        assert [a["name"] for a in entry["args"]] == list(ARG_ORDER)
        shapes = {a["name"]: a["shape"] for a in entry["args"]}
        assert shapes["vals"] == [TINY.b, TINY.k]
        assert shapes["cols"] == [TINY.b, TINY.k]
        assert shapes["x"] == [TINY.n]
        assert shapes["dang"] == [1]
        dtypes = {a["name"]: a["dtype"] for a in entry["args"]}
        assert dtypes["cols"] == "int32"
        assert dtypes["vals"] == "float32"
        assert entry["outputs"][0]["shape"] == [TINY.b]

    def test_emitted_manifest_if_present(self):
        """If `make artifacts` has run, the manifest on disk must match
        the current ABI (guards against stale artifacts)."""
        p = pathlib.Path(__file__).resolve().parents[2] / "artifacts" / "manifest.json"
        if not p.exists():
            pytest.skip("artifacts not built")
        m = json.loads(p.read_text())
        assert m["arg_order"] == list(ARG_ORDER)
        for e in m["artifacts"]:
            assert (p.parent / e["path"]).exists()
