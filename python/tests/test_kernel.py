"""L1 correctness: Pallas kernels vs pure-jnp oracle.

This is the CORE correctness signal for the stack -- the rust runtime
executes HLO lowered from these kernels, so kernel==ref here plus
artifact==kernel in test_aot.py gives rust==ref transitively.

hypothesis sweeps shapes (tile counts, ELL widths, vector lengths) and
value regimes; fixed seeds keep CI deterministic.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import spmv_ell, pagerank_step
from compile.kernels.ref import spmv_ell_ref, pagerank_step_ref


def make_ell(rng, b, k, n, density=0.5, dtype=np.float32):
    """Random padded-ELL block: padded slots carry val=0, col=0."""
    mask = rng.random((b, k)) < density
    vals = np.where(mask, rng.random((b, k)), 0.0).astype(dtype)
    cols = np.where(mask, rng.integers(0, n, (b, k)), 0).astype(np.int32)
    return vals, cols


# ---------------------------------------------------------------- spmv

class TestSpmvEll:
    def test_matches_ref_basic(self):
        rng = np.random.default_rng(1)
        vals, cols = make_ell(rng, 1024, 8, 2048)
        x = rng.random(2048, dtype=np.float32)
        np.testing.assert_allclose(
            spmv_ell(vals, cols, x), spmv_ell_ref(vals, cols, x), rtol=1e-6
        )

    def test_matches_dense_matmul(self):
        """ELL SpMV == dense A @ x built from the same entries."""
        rng = np.random.default_rng(2)
        b = k = 16
        n = 32
        vals, cols = make_ell(rng, b, k, n, density=0.4)
        x = rng.random(n, dtype=np.float32)
        dense = np.zeros((b, n), np.float32)
        for i in range(b):
            for j in range(k):
                dense[i, cols[i, j]] += vals[i, j]
        np.testing.assert_allclose(
            spmv_ell(vals, cols, x, tile_r=16), dense @ x, rtol=1e-5
        )

    def test_zero_matrix(self):
        vals = np.zeros((512, 8), np.float32)
        cols = np.zeros((512, 8), np.int32)
        x = np.ones(1024, np.float32)
        assert float(np.abs(spmv_ell(vals, cols, x)).max()) == 0.0

    def test_identity_permutation(self):
        """One slot per row pointing at row i with val 1 => y == x[:b]."""
        b, n = 512, 512
        vals = np.zeros((b, 4), np.float32)
        cols = np.zeros((b, 4), np.int32)
        vals[:, 0] = 1.0
        cols[:, 0] = np.arange(b)
        x = np.random.default_rng(3).random(n).astype(np.float32)
        np.testing.assert_allclose(spmv_ell(vals, cols, x), x[:b], rtol=1e-7)

    def test_rejects_indivisible_tile(self):
        vals = np.zeros((100, 4), np.float32)
        cols = np.zeros((100, 4), np.int32)
        x = np.zeros(128, np.float32)
        with pytest.raises(ValueError, match="not divisible"):
            spmv_ell(vals, cols, x, tile_r=64)

    @settings(max_examples=20, deadline=None)
    @given(
        tiles=st.integers(1, 4),
        tile_r=st.sampled_from([8, 32, 128]),
        k=st.integers(1, 24),
        n_log=st.integers(4, 12),
        density=st.floats(0.05, 1.0),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_sweep(self, tiles, tile_r, k, n_log, density, seed):
        rng = np.random.default_rng(seed)
        b, n = tiles * tile_r, 1 << n_log
        vals, cols = make_ell(rng, b, k, n, density)
        x = (rng.random(n, dtype=np.float32) - 0.5) * 2.0
        got = spmv_ell(vals, cols, x, tile_r=tile_r)
        want = spmv_ell_ref(vals, cols, x)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


# ------------------------------------------------------- pagerank step

class TestPagerankStep:
    def _inputs(self, rng, b, k, n):
        vals, cols = make_ell(rng, b, k, n)
        x = rng.random(n, dtype=np.float32)
        xold = rng.random(b, dtype=np.float32)
        bias = rng.random(b, dtype=np.float32) * 0.15
        dang = np.array([rng.random() * 0.01], np.float32)
        alpha = np.array([0.85], np.float32)
        return vals, cols, x, xold, bias, dang, alpha

    def test_matches_ref_basic(self):
        rng = np.random.default_rng(4)
        args = self._inputs(rng, 1024, 8, 2048)
        y1, r1 = pagerank_step(*args)
        y2, r2 = pagerank_step_ref(*args)
        np.testing.assert_allclose(y1, y2, rtol=1e-5)
        np.testing.assert_allclose(r1, r2, rtol=1e-4)

    def test_residual_zero_at_fixed_point(self):
        """If y == xold exactly, resid must be exactly 0."""
        b, k, n = 512, 4, 512
        vals = np.zeros((b, k), np.float32)
        cols = np.zeros((b, k), np.int32)
        x = np.zeros(n, np.float32)
        bias = np.full(b, 0.25, np.float32)
        dang = np.array([0.0], np.float32)
        alpha = np.array([0.85], np.float32)
        xold = np.full(b, 0.25, np.float32)  # == alpha*0 + 0 + bias
        y, r = pagerank_step(vals, cols, x, xold, bias, dang, alpha)
        np.testing.assert_allclose(y, xold, atol=0)
        assert float(r[0]) == 0.0

    def test_stochastic_mass_preserved(self):
        """Full-matrix block on a column-stochastic M with uniform v:
        sum(y) == 1 when sum(x) == 1 (the paper's no-normalization
        property of eq. 4)."""
        rng = np.random.default_rng(5)
        n = 512
        k = 4
        # build a column-stochastic matrix in ELL form: each column j
        # distributes x_j equally to k random rows
        cols_per_row = [[] for _ in range(n)]
        for j in range(n):
            for tgt in rng.integers(0, n, k):
                cols_per_row[tgt].append((j, 1.0 / k))
        width = max(len(c) for c in cols_per_row)
        width = max(width, 1)
        vals = np.zeros((n, width), np.float32)
        cols = np.zeros((n, width), np.int32)
        for i, entries in enumerate(cols_per_row):
            for s, (j, v) in enumerate(entries):
                vals[i, s] = v
                cols[i, s] = j
        x = rng.random(n).astype(np.float32)
        x /= x.sum()
        alpha = np.array([0.85], np.float32)
        bias = np.full(n, (1 - 0.85) / n, np.float32)
        dang = np.array([0.0], np.float32)
        y, _ = pagerank_step(vals, cols, x, x, bias, dang, alpha, tile_r=n)
        assert abs(float(y.sum()) - 1.0) < 1e-4

    @settings(max_examples=15, deadline=None)
    @given(
        tiles=st.integers(1, 3),
        tile_r=st.sampled_from([16, 64]),
        k=st.integers(1, 12),
        n_log=st.integers(5, 11),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_sweep(self, tiles, tile_r, k, n_log, seed):
        rng = np.random.default_rng(seed)
        b, n = tiles * tile_r, 1 << n_log
        args = self._inputs(rng, b, k, n)
        y1, r1 = pagerank_step(*args, tile_r=tile_r)
        y2, r2 = pagerank_step_ref(*args)
        np.testing.assert_allclose(y1, y2, rtol=1e-4, atol=1e-6)
        np.testing.assert_allclose(r1, r2, rtol=1e-3, atol=1e-5)
