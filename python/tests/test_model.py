"""L2 semantics: the jax model preserves the paper's mathematical facts.

- eq. (4) needs no per-step normalization: ||x(t)||_1 is invariant under
  the full Google-matrix update (stochasticity of G).
- power_steps converges to the dominant eigenvector; residual decreases
  geometrically ~ alpha per step (classic PageRank bound).
- block decomposition: p block updates assembled == one full update
  (eq. 6 is exactly eq. 4 rows, independent of asynchrony).
"""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels.ref import spmv_ell_ref


def random_web_ell(rng, n, max_deg, dangling_frac=0.05):
    """Random web-like matrix in ELL form: P^T with column-stochastic
    semantics. Returns (vals, cols, dang_mask)."""
    vals = np.zeros((n, max_deg), np.float32)
    cols = np.zeros((n, max_deg), np.int32)
    dang = np.zeros(n, np.float32)
    slots = [0] * n
    for j in range(n):  # source page j
        if rng.random() < dangling_frac:
            dang[j] = 1.0
            continue
        deg = int(rng.integers(1, max_deg))
        tgts = rng.choice(n, size=deg, replace=False)
        w = 1.0 / deg
        for t in tgts:
            if slots[t] < max_deg:
                vals[t, slots[t]] = w
                cols[t, slots[t]] = j
                slots[t] += 1
            else:  # overflow: drop edge, give mass to dangling instead
                dang[j] = dang[j]  # keep semantics simple for the test
    # renormalize columns so each non-dangling column sums to <= 1; for
    # exactness rebuild column sums and declare any shortfall dangling-ish
    return vals, cols, dang


class TestPowerSteps:
    def _setup(self, seed=0, n=256, max_deg=6):
        rng = np.random.default_rng(seed)
        vals, cols, dang = random_web_ell(rng, n, max_deg)
        alpha = np.array([0.85], np.float32)
        bias = np.full(n, (1 - 0.85) / n, np.float32)
        x0 = np.full(n, 1.0 / n, np.float32)
        return vals, cols, dang, alpha, bias, x0

    def test_mass_conservation(self):
        """||x||_1 stays 1 when columns are exactly stochastic."""
        n = 128
        rng = np.random.default_rng(1)
        # exact column-stochastic: every column j sends 1/deg to deg rows
        deg = 4
        vals = np.zeros((n, 16), np.float32)
        cols = np.zeros((n, 16), np.int32)
        slots = [0] * n
        for j in range(n):
            for t in rng.choice(n, size=deg, replace=False):
                vals[t, slots[t]] = 1.0 / deg
                cols[t, slots[t]] = j
                slots[t] += 1
        assert max(slots) <= 16
        dangm = np.zeros(n, np.float32)
        alpha = np.array([0.85], np.float32)
        bias = np.full(n, 0.15 / n, np.float32)
        x = np.full(n, 1.0 / n, np.float32)
        out = model.power_steps(vals, cols, x, bias, dangm, alpha, steps=10)
        assert abs(float(np.sum(out)) - 1.0) < 1e-4

    def test_convergence_to_fixed_point(self):
        vals, cols, dang, alpha, bias, x0 = self._setup()
        x30 = np.asarray(model.power_steps(vals, cols, x0, bias, dang, alpha, steps=30))
        x60 = np.asarray(model.power_steps(vals, cols, x0, bias, dang, alpha, steps=60))
        x90 = np.asarray(model.power_steps(vals, cols, x0, bias, dang, alpha, steps=90))
        d1 = float(np.abs(x60 - x30).sum())
        d2 = float(np.abs(x90 - x60).sum())
        # geometric contraction: 30 extra steps shrink the gap ~alpha^30
        assert d1 < 5e-3
        assert d2 < d1 * (0.85**30) * 10 + 1e-7  # generous slack on fp32

    def test_fixed_point_satisfies_equation(self):
        """x* = alpha*M x* + alpha*(d.x*)/n + (1-alpha)v."""
        vals, cols, dang, alpha, bias, x0 = self._setup(seed=3)
        n = x0.shape[0]
        xs = np.asarray(
            model.power_steps(vals, cols, x0, bias, dang, alpha, steps=120)
        )
        rhs = (
            0.85 * np.asarray(spmv_ell_ref(vals, cols, xs))
            + 0.85 * float(dang @ xs) / n
            + bias
        )
        np.testing.assert_allclose(xs, rhs, rtol=1e-4, atol=1e-7)

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 10_000), alpha_f=st.sampled_from([0.5, 0.85, 0.95]))
    def test_geometric_residual_decay(self, seed, alpha_f):
        """Residuals r(t)=||x(t+1)-x(t)||_1 must satisfy r(t+5) <=
        alpha^5 * r(t) * (1+eps) -- the contraction bound of eq. (7)."""
        rng = np.random.default_rng(seed)
        n = 128
        vals, cols, dang = random_web_ell(rng, n, 5)
        alpha = np.array([alpha_f], np.float32)
        bias = np.full(n, (1 - alpha_f) / n, np.float32)
        x = np.full(n, 1.0 / n, np.float32)
        xs = [x]
        for _ in range(12):
            xs.append(
                np.asarray(
                    model.power_steps(vals, cols, xs[-1], bias, dang, alpha, steps=1)
                )
            )
        r = [float(np.abs(xs[i + 1] - xs[i]).sum()) for i in range(12)]
        if r[4] > 1e-9:
            assert r[9] <= (alpha_f**5) * r[4] * 1.05


class TestBlockDecomposition:
    def test_blocks_equal_full_update(self):
        """Assembling p block_step outputs == full-matrix update,
        independently of how rows are partitioned (eq. 6 == rows of eq. 4)."""
        rng = np.random.default_rng(7)
        n, k, p = 256, 6, 4
        vals, cols, dang = random_web_ell(rng, n, k)
        alpha = np.array([0.85], np.float32)
        bias = np.full(n, 0.15 / n, np.float32)
        x = rng.random(n).astype(np.float32)
        x /= x.sum()
        dmass = np.array([0.85 * float(dang @ x) / n], np.float32)

        full, _ = model.block_step(vals, cols, x, x, bias, dmass, alpha)
        full = np.asarray(full)

        blk = n // p
        assembled = np.zeros(n, np.float32)
        for i in range(p):
            lo, hi = i * blk, (i + 1) * blk
            y, _ = model.block_step(
                vals[lo:hi], cols[lo:hi], x, x[lo:hi], bias[lo:hi], dmass, alpha
            )
            assembled[lo:hi] = np.asarray(y)
        np.testing.assert_allclose(assembled, full, rtol=1e-5, atol=1e-7)

    def test_block_residual_sums_to_full(self):
        rng = np.random.default_rng(8)
        n, k, p = 128, 4, 2
        vals, cols, dang = random_web_ell(rng, n, k)
        alpha = np.array([0.85], np.float32)
        bias = np.full(n, 0.15 / n, np.float32)
        x = rng.random(n).astype(np.float32)
        dmass = np.array([0.0], np.float32)
        _, r_full = model.block_step(vals, cols, x, x, bias, dmass, alpha)
        blk = n // p
        parts = 0.0
        for i in range(p):
            lo, hi = i * blk, (i + 1) * blk
            _, r = model.block_step(
                vals[lo:hi], cols[lo:hi], x, x[lo:hi], bias[lo:hi], dmass, alpha
            )
            parts += float(r[0])
        assert abs(parts - float(r_full[0])) < 1e-3


class TestBlockStepV2:
    def test_v2_matches_v1_with_host_dangling(self):
        import numpy as np
        rng = np.random.default_rng(11)
        n, k = 256, 4
        vals, cols, dang_mask = random_web_ell(rng, n, k)
        x = rng.random(n).astype(np.float32)
        alpha = np.array([0.85], np.float32)
        bias = np.full(n, 0.15 / n, np.float32)
        dang = np.array([0.85 * float(dang_mask @ x) / n], np.float32)
        y1, r1 = model.block_step(vals, cols, x, x, bias, dang, alpha)
        y2, r2 = model.block_step_v2(vals, cols, x, x, bias, dang_mask, alpha)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(r1), np.asarray(r2), rtol=1e-4)

    def test_v2_zero_mask_means_no_correction(self):
        import numpy as np
        rng = np.random.default_rng(12)
        n, k = 128, 4
        vals, cols, _ = random_web_ell(rng, n, k, dangling_frac=0.0)
        x = rng.random(n).astype(np.float32)
        alpha = np.array([0.85], np.float32)
        bias = np.zeros(n, np.float32)
        zero = np.array([0.0], np.float32)
        y1, _ = model.block_step(vals, cols, x, x, bias, zero, alpha)
        y2, _ = model.block_step_v2(vals, cols, x, x, bias, np.zeros(n, np.float32), alpha)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-6)
