//! Bench S1: evolving-graph epochs — incremental warm-start push vs.
//! from-scratch recomputation.
//!
//! The subsystem's claim is "recompute cost ∝ change size, not graph
//! size": after a crawl-sized churn batch (~0.5 % of edges), the
//! warm-started Gauss–Southwell solve should cost a small fraction of a
//! cold solve's pushes AND wall time, while landing on the same ranks.
//! This bench measures both the operation counts (deterministic) and
//! timed medians for (a) one update epoch solved incrementally, (b) the
//! same snapshot solved from scratch by push, and (c) the f64 power
//! method baseline.

use asyncpr::coordinator::experiments::{self, StreamOptions};
use asyncpr::graph::generators::{churn_batch, ChurnParams};
use asyncpr::stream::{power_method_f64, DeltaGraph, PushState};
use asyncpr::util::{Bench, Rng, Table};

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("BENCH_FAST").ok().as_deref() == Some("1");
    let graph = if quick { "scaled:8000" } else { "scaled:28190" };
    println!("== bench stream (graph = {graph}) ==\n");

    // ---- operation counts over a full epoch run (deterministic) ----
    let opts = StreamOptions { epochs: if quick { 4 } else { 8 }, ..Default::default() };
    let rep = experiments::stream_epochs(graph, &opts)?;
    println!("{}", asyncpr::metrics::stream_markdown(&rep.rows));
    println!(
        "update epochs: {} inc pushes vs {} scratch pushes ({:.1}x), final L1 vs power {:.1e}\n",
        rep.update_inc_pushes,
        rep.update_scratch_pushes,
        rep.update_scratch_pushes as f64 / rep.update_inc_pushes.max(1) as f64,
        rep.final_l1_vs_power,
    );

    // ---- wall-clock per epoch style: warm vs cold vs power ----
    let el = asyncpr::coordinator::load_edgelist(graph, 42)?;
    let base = DeltaGraph::from_edgelist(&el);
    let churn = ChurnParams::scaled_to(base.n(), base.m());
    let tol = 1e-10;

    let bench = Bench::default();
    let mut t = Table::new(&["solver", "mean", "pushes / iters"]);

    // pre-build one churned snapshot + a converged pre-churn state
    let mut warm0 = PushState::new(base.n(), 0.85);
    warm0.begin_epoch();
    warm0.solve(&base, tol, u64::MAX);
    let mut g1 = base.clone();
    let delta = g1.apply(&churn_batch(&base, &churn, &mut Rng::new(7)))?;

    let mut warm_pushes = 0u64;
    let s_warm = bench.run("incremental (warm push)", || {
        let mut s = warm0.clone();
        s.begin_epoch();
        s.apply_batch(&g1, &delta);
        let st = s.solve(&g1, tol, u64::MAX);
        warm_pushes = st.pushes;
    });
    let mut cold_pushes = 0u64;
    let s_cold = bench.run("from-scratch (cold push)", || {
        let mut s = PushState::new(g1.n(), 0.85);
        s.begin_epoch();
        let st = s.solve(&g1, tol, u64::MAX);
        cold_pushes = st.pushes;
    });
    let mut power_iters = 0usize;
    let s_power = bench.run("from-scratch (f64 power)", || {
        let (_, it) = power_method_f64(&g1, 0.85, tol, 100_000);
        power_iters = it;
    });

    t.row(&[
        "incremental (warm push)".into(),
        format!("{:?}", s_warm.mean),
        format!("{warm_pushes} pushes"),
    ]);
    t.row(&[
        "from-scratch (cold push)".into(),
        format!("{:?}", s_cold.mean),
        format!("{cold_pushes} pushes"),
    ]);
    t.row(&[
        "from-scratch (f64 power)".into(),
        format!("{:?}", s_power.mean),
        format!("{power_iters} iters"),
    ]);
    println!("{}", t.to_markdown());
    println!(
        "one ~0.5% churn epoch: warm/cold push ratio {:.3} (time), {:.3} (pushes)",
        s_warm.mean.as_secs_f64() / s_cold.mean.as_secs_f64(),
        warm_pushes as f64 / cold_pushes.max(1) as f64,
    );
    Ok(())
}
