//! Bench K1: kernel-level hot path — the PJRT artifact (L1 Pallas SpMV
//! via L2 jax, AOT-lowered) vs the native rust CSR/ELL SpMV, plus the
//! roofline context used by EXPERIMENTS.md §Perf.
//!
//! Reported per variant: time per PageRank block step, effective
//! nonzeros/s, and bytes/s against the memory-bandwidth roofline
//! (each nnz touches 4 B value + 4 B index + a 4 B gather from x).

use std::sync::Arc;

use asyncpr::asynciter::{ArtifactBlockOp, BlockOperator, NativeBlockOp};
use asyncpr::graph::{generators, Csr, Ell};
use asyncpr::pagerank::PagerankProblem;
use asyncpr::runtime::Engine;
use asyncpr::util::Bench;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("BENCH_FAST").ok().as_deref() == Some("1");
    let n = if quick { 28_190 } else { 281_903 };
    println!("== bench kernel (n = {n}) ==\n");
    let el = generators::power_law_web(&generators::WebParams::scaled(n), 17);
    let problem = Arc::new(PagerankProblem::new(Csr::from_edgelist(&el)?, 0.85));
    // bench one UE's block (p = 4), the actual hot-path unit
    let blk_hi = problem.n() / 4;
    let nnz: usize = (0..blk_hi).map(|i| problem.csr.row_len(i)).sum();
    let x = problem.uniform_start();
    let mut out = vec![0.0f32; blk_hi];
    let bench = Bench::default();

    // ---- native CSR (the coordinator's scalable path) ----
    let mut native = NativeBlockOp::new(problem.clone(), 0, blk_hi);
    let s_native = bench.run("native CSR block step (p=4 block)", || {
        native.update(&x, &mut out);
    });

    // ---- native ELL (the kernel's layout, on host) ----
    let ell = Ell::from_csr_range(&problem.csr, 0, blk_hi, 16);
    let mut vy = vec![0.0f32; ell.virtual_rows()];
    let s_ell = bench.run("native ELL spmv (virtual rows)", || {
        ell.spmv_virtual(&x, &mut vy);
    });

    // ---- PJRT artifact (L1 pallas kernel through the runtime) ----
    let engine = Engine::new(asyncpr::runtime::default_artifacts_dir())?;
    let mut art = ArtifactBlockOp::new(&engine, problem.clone(), 0, blk_hi, 16)?;
    let s_art = bench.run("PJRT artifact block step (pallas L1)", || {
        art.update(&x, &mut out);
    });

    println!("\n{}", s_native.report());
    println!("{}", s_ell.report());
    println!("{}", s_art.report());

    let gnnz = |d: std::time::Duration| nnz as f64 / d.as_secs_f64() / 1e9;
    let roofline_bytes = (nnz * 12) as f64; // val + idx + gather per nnz
    println!("\nthroughput: native CSR {:.3} Gnnz/s | native ELL {:.3} | artifact {:.3}",
        gnnz(s_native.mean), gnnz(s_ell.mean), gnnz(s_art.mean));
    println!(
        "memory traffic (roofline basis): {:.1} MB per step; native CSR streams {:.2} GB/s",
        roofline_bytes / 1e6,
        roofline_bytes / s_native.mean.as_secs_f64() / 1e9
    );
    println!(
        "\nartifact/native ratio: {:.1}x (PJRT buffer upload dominates; the ELL\n\
         padding also does {:.2}x the logical nonzero work — see EXPERIMENTS.md §Perf)",
        s_art.mean.as_secs_f64() / s_native.mean.as_secs_f64(),
        ell.vals().len() as f64 / nnz as f64,
    );
    Ok(())
}
