//! Bench: certified top-k early stop vs. full convergence on the
//! evolving stream — the serving-path payoff.
//!
//! Two identical 10-epoch churn runs (cloned graph, same rng seed) over
//! one epoch-resident sharded push state:
//!
//! * **certified**: each epoch's solve ends the moment the top-k head
//!   certifies (`stop_when_topk_certified` semantics via
//!   `solve_certified_sharded`), falling back to full convergence only
//!   when the head cannot certify;
//! * **full**: each epoch runs the classic `residual_exact < τ` drain.
//!
//! The metric is pushes (the work unit the whole stream subsystem
//! accounts in); the acceptance criterion is that the certified run
//! needs STRICTLY fewer — the run bails otherwise. A soundness postlude
//! audits every certified head against a fresh power-method reference.

use std::time::Instant;

use asyncpr::graph::generators::{churn_batch, ChurnParams};
use asyncpr::stream::{
    power_method_f64, solve_certified_sharded, DeltaGraph, ShardedPush, TopKGoal, TopKTracker,
};
use asyncpr::util::{Json, Rng};

fn jobj(pairs: &[(&str, Json)]) -> Json {
    Json::Obj(pairs.iter().map(|(k, v)| (k.to_string(), v.clone())).collect())
}

/// Machine-readable bench output: set `ASYNCPR_BENCH_JSON_DIR=benches`
/// to refresh the committed `benches/BENCH_topk_stream.json` trajectory
/// file (see benches/README.md). No-op otherwise.
fn write_bench_json(doc: &Json) -> anyhow::Result<()> {
    if let Ok(dir) = std::env::var("ASYNCPR_BENCH_JSON_DIR") {
        if !dir.is_empty() {
            let path = format!("{dir}/BENCH_topk_stream.json");
            std::fs::write(&path, doc.to_string_compact())?;
            eprintln!("wrote {path}");
        }
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("BENCH_FAST").ok().as_deref() == Some("1");
    let graph = if quick { "scaled:6000" } else { "scaled:20000" };
    let epochs = if quick { 4 } else { 10 };
    let (k, shards, tol) = (32usize, 4usize, 1e-9f64);
    println!(
        "== bench topk_stream (graph = {graph}, k = {k}, {epochs} churn epochs, \
         {shards} shards, tol = {tol:.0e}) ==\n"
    );

    let el = asyncpr::coordinator::load_edgelist(graph, 42)?;
    let g0 = DeltaGraph::from_edgelist(&el);
    println!("n = {}, m = {}\n", g0.n(), g0.m());
    let churn = ChurnParams::scaled_to(g0.n(), g0.m());
    let seed = 777u64;
    let goal = TopKGoal { k, order: false };

    // ---- certified early-stop run --------------------------------
    let t0 = Instant::now();
    let (cert_pushes, cert_epochs, heads) = {
        let mut g = g0.clone();
        let mut rng = Rng::new(seed);
        let mut sp = ShardedPush::new(&g, 0.85, shards);
        let mut tracker = TopKTracker::new(goal);
        let mut total = 0u64;
        let mut certified = 0usize;
        // (epoch, certified head, graph snapshot for the audit)
        let mut heads: Vec<(usize, Vec<u32>, DeltaGraph)> = Vec::new();
        for epoch in 0..=epochs {
            if epoch > 0 {
                let batch = churn_batch(&g, &churn, &mut rng);
                let delta = g.apply(&batch)?;
                sp.begin_epoch();
                sp.apply_batch(&g, &delta);
            }
            let st = solve_certified_sharded(&mut sp, &g, &mut tracker, tol, u64::MAX, true);
            anyhow::ensure!(
                st.pushes_to_cert.is_some() || st.converged,
                "epoch {epoch}: neither certified nor converged"
            );
            total += st.pushes;
            if st.cert.set_certified {
                certified += 1;
                heads.push((epoch, st.cert.head.clone(), g.clone()));
            }
            println!(
                "  epoch {epoch}: {} pushes, cert@{:?}, residual {:.1e}",
                st.pushes, st.pushes_to_cert, st.residual
            );
        }
        (total, certified, heads)
    };
    let cert_wall = t0.elapsed().as_secs_f64() * 1e3;

    // ---- full-convergence run ------------------------------------
    let t0 = Instant::now();
    let full_pushes = {
        let mut g = g0.clone();
        let mut rng = Rng::new(seed);
        let mut sp = ShardedPush::new(&g, 0.85, shards);
        let mut total = 0u64;
        for epoch in 0..=epochs {
            if epoch > 0 {
                let batch = churn_batch(&g, &churn, &mut rng);
                let delta = g.apply(&batch)?;
                sp.begin_epoch();
                sp.apply_batch(&g, &delta);
            }
            let st = sp.solve(&g, tol, u64::MAX);
            anyhow::ensure!(st.converged, "epoch {epoch}: full run did not converge");
            total += st.pushes;
        }
        total
    };
    let full_wall = t0.elapsed().as_secs_f64() * 1e3;

    println!(
        "\ncertified early stop: {cert_pushes} pushes ({cert_wall:.0} ms), \
         head certified in {cert_epochs}/{} epochs",
        epochs + 1
    );
    println!("full convergence:     {full_pushes} pushes ({full_wall:.0} ms)");
    println!(
        "push saving: {:.1}x fewer pushes on the serving path",
        full_pushes as f64 / cert_pushes.max(1) as f64
    );

    // ---- soundness audit -----------------------------------------
    // every certified head must equal the fresh power reference's
    // top-k on that epoch's snapshot
    for (epoch, head, g) in &heads {
        let (xref, _) = power_method_f64(g, 0.85, 1e-12, 10_000);
        let mut want = asyncpr::pagerank::top_k_ids(&xref, k);
        let mut got = head.clone();
        want.sort_unstable();
        got.sort_unstable();
        anyhow::ensure!(
            got == want,
            "epoch {epoch}: certified head disagrees with the power reference"
        );
    }
    println!("audit: all {} certified heads exact vs the power reference", heads.len());

    anyhow::ensure!(
        cert_pushes < full_pushes,
        "certified early stop must need strictly fewer pushes \
         ({cert_pushes} vs {full_pushes})"
    );

    write_bench_json(&jobj(&[
        ("schema", Json::Num(1.0)),
        ("bench", Json::Str("topk_stream".to_string())),
        ("graph", Json::Str(graph.to_string())),
        ("quick", Json::Bool(quick)),
        ("epochs", Json::Num((epochs + 1) as f64)),
        ("k", Json::Num(k as f64)),
        (
            "certified",
            jobj(&[
                ("pushes", Json::Num(cert_pushes as f64)),
                ("epochs_certified", Json::Num(cert_epochs as f64)),
                ("wall_ms", Json::Num(cert_wall)),
            ]),
        ),
        (
            "full",
            jobj(&[
                ("pushes", Json::Num(full_pushes as f64)),
                ("wall_ms", Json::Num(full_wall)),
            ]),
        ),
        (
            "push_saving",
            Json::Num(full_pushes as f64 / cert_pushes.max(1) as f64),
        ),
    ]))?;
    Ok(())
}
