//! Bench: sustained PPR query serving under churn — warm LRU cache
//! (incremental invalidation) vs cold per-query solves.
//!
//! Two identical runs (cloned graph, same churn and query streams)
//! through [`ServeTier`]:
//!
//! * **warm**: normal tier — source states stay cached across queries
//!   and absorb each churn delta incrementally, so a repeat query pays
//!   only for the residual the churn actually injected;
//! * **cold**: `cache_cap = 0` — every query builds and solves a fresh
//!   personalized state, the no-cache baseline.
//!
//! The metric is pushes (the work unit the stream subsystem accounts
//! in); the acceptance criterion is that the warm run needs STRICTLY
//! fewer — the run bails otherwise. Per-query wall-clock latency is
//! reported as p50/p99 alongside the cache hit rate: that triple is
//! the serving-tier headline (sustained QPS under churn).

use std::time::Instant;

use asyncpr::graph::generators::{churn_batch, ChurnParams};
use asyncpr::stream::{DeltaGraph, ServeOptions, ServeTier};
use asyncpr::util::{Json, Rng};

fn jobj(pairs: &[(&str, Json)]) -> Json {
    Json::Obj(pairs.iter().map(|(k, v)| (k.to_string(), v.clone())).collect())
}

/// Machine-readable bench output: set `ASYNCPR_BENCH_JSON_DIR=benches`
/// to refresh the committed `benches/BENCH_ppr_serve.json` trajectory
/// file (see benches/README.md). No-op otherwise.
fn write_bench_json(doc: &Json) -> anyhow::Result<()> {
    if let Ok(dir) = std::env::var("ASYNCPR_BENCH_JSON_DIR") {
        if !dir.is_empty() {
            let path = format!("{dir}/BENCH_ppr_serve.json");
            std::fs::write(&path, doc.to_string_compact())?;
            eprintln!("wrote {path}");
        }
    }
    Ok(())
}

fn pct(sorted_us: &[f64], p: f64) -> f64 {
    let i = ((sorted_us.len() as f64 - 1.0) * p).round() as usize;
    sorted_us[i]
}

/// One serving run over the churn trajectory. Both sides replay the
/// exact same graph evolution and query sequence (cloned graph, fixed
/// seeds); only the cache capacity differs.
fn run_side(
    g0: &DeltaGraph,
    churn: &ChurnParams,
    pool: &[Vec<u32>],
    rounds: usize,
    queries_per_round: usize,
    cache_cap: usize,
    tol: f64,
) -> anyhow::Result<(u64, f64, Vec<f64>, f64)> {
    let mut g = g0.clone();
    let mut churn_rng = Rng::new(4242);
    let mut query_rng = Rng::new(8484);
    let mut tier = ServeTier::new(ServeOptions { tol, cache_cap, topk: 16, ..Default::default() });
    let mut lat_us = Vec::with_capacity((rounds + 1) * queries_per_round);
    let t0 = Instant::now();
    for round in 0..=rounds {
        if round > 0 {
            let batch = churn_batch(&g, churn, &mut churn_rng);
            let delta = g.apply(&batch)?;
            tier.apply_batch(&g, &delta);
        }
        for _ in 0..queries_per_round {
            let q = &pool[query_rng.range(0, pool.len())];
            let tq = Instant::now();
            let ans = tier.query(&g, q)?;
            lat_us.push(tq.elapsed().as_secs_f64() * 1e6);
            anyhow::ensure!(
                ans.residual < tol,
                "round {round}: answer returned unconverged at {:.2e}",
                ans.residual
            );
        }
    }
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    lat_us.sort_by(f64::total_cmp);
    let st = tier.stats();
    Ok((st.pushes, st.hit_rate(), lat_us, wall_ms))
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("BENCH_FAST").ok().as_deref() == Some("1");
    let graph = if quick { "scaled:6000" } else { "scaled:20000" };
    let (rounds, queries_per_round) = if quick { (3usize, 24usize) } else { (6, 64) };
    let (pool_size, sources_per_query, tol) = (16usize, 2usize, 1e-10f64);
    println!(
        "== bench ppr_serve (graph = {graph}, {rounds} churn rounds x \
         {queries_per_round} queries, pool {pool_size} x {sources_per_query} sources, \
         tol = {tol:.0e}) ==\n"
    );

    let el = asyncpr::coordinator::load_edgelist(graph, 42)?;
    let g0 = DeltaGraph::from_edgelist(&el);
    println!("n = {}, m = {}\n", g0.n(), g0.m());
    let churn = ChurnParams::scaled_to(g0.n(), g0.m());
    let mut pool_rng = Rng::new(1717);
    let pool: Vec<Vec<u32>> = (0..pool_size)
        .map(|_| {
            pool_rng
                .sample_distinct(g0.n(), sources_per_query)
                .into_iter()
                .map(|u| u as u32)
                .collect()
        })
        .collect();

    // ---- warm run (LRU cache, incremental invalidation) ----------
    let (warm_pushes, hit_rate, warm_lat, warm_wall) =
        run_side(&g0, &churn, &pool, rounds, queries_per_round, 64, tol)?;
    // ---- cold run (cache disabled — per-query solves) ------------
    let (cold_pushes, cold_hit, cold_lat, cold_wall) =
        run_side(&g0, &churn, &pool, rounds, queries_per_round, 0, tol)?;
    anyhow::ensure!(cold_hit == 0.0, "cache_cap = 0 must disable caching, hit rate {cold_hit}");

    let queries = ((rounds + 1) * queries_per_round) as f64;
    println!(
        "warm (cached): {warm_pushes} pushes, hit rate {hit_rate:.2}, \
         p50 {:.0} us, p99 {:.0} us, {:.0} q/s",
        pct(&warm_lat, 0.50),
        pct(&warm_lat, 0.99),
        queries / (warm_wall / 1e3)
    );
    println!(
        "cold (no cache): {cold_pushes} pushes, p50 {:.0} us, p99 {:.0} us, {:.0} q/s",
        pct(&cold_lat, 0.50),
        pct(&cold_lat, 0.99),
        queries / (cold_wall / 1e3)
    );
    println!(
        "push saving: {:.1}x fewer pushes with the warm cache",
        cold_pushes as f64 / warm_pushes.max(1) as f64
    );

    anyhow::ensure!(
        warm_pushes < cold_pushes,
        "warm serving must need strictly fewer pushes ({warm_pushes} vs {cold_pushes})"
    );
    anyhow::ensure!(
        hit_rate > 0.0,
        "the query mix repeats source sets, so the cache must have fired"
    );

    write_bench_json(&jobj(&[
        ("schema", Json::Num(1.0)),
        ("bench", Json::Str("ppr_serve".to_string())),
        ("graph", Json::Str(graph.to_string())),
        ("quick", Json::Bool(quick)),
        ("rounds", Json::Num((rounds + 1) as f64)),
        ("queries", Json::Num(queries)),
        (
            "warm",
            jobj(&[
                ("pushes", Json::Num(warm_pushes as f64)),
                ("hit_rate", Json::Num(hit_rate)),
                ("p50_us", Json::Num(pct(&warm_lat, 0.50))),
                ("p99_us", Json::Num(pct(&warm_lat, 0.99))),
                ("wall_ms", Json::Num(warm_wall)),
            ]),
        ),
        (
            "cold",
            jobj(&[
                ("pushes", Json::Num(cold_pushes as f64)),
                ("p50_us", Json::Num(pct(&cold_lat, 0.50))),
                ("p99_us", Json::Num(pct(&cold_lat, 0.99))),
                ("wall_ms", Json::Num(cold_wall)),
            ]),
        ),
        (
            "push_saving",
            Json::Num(cold_pushes as f64 / warm_pushes.max(1) as f64),
        ),
    ]))?;
    Ok(())
}
