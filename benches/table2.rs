//! Bench T2: regenerate Table 2 — the completed-imports matrix of the
//! asynchronous p=4 run.
//!
//! Paper:
//!
//! | Receiver | id=0 | id=1 | id=2 | id=3 | Completed Imports (%) |
//! |----------|------|------|------|------|-----------------------|
//! | id = 0   | 109  | 46   | 23   | 26   | 29                    |
//! | id = 1   | 40   | 107  | 22   | 27   | 28                    |
//! | id = 2   | 35   | 37   | 111  | 66   | 41                    |
//! | id = 3   | 27   | 30   | 54   | 82   | 45                    |
//!
//! Shape to match: diagonals ≈ local iteration counts (tens to ~100+),
//! off-diagonals strictly smaller, import percentages well below 100 %
//! (the wire cannot carry every-step all-to-all fragments).

use asyncpr::config::RunConfig;
use asyncpr::coordinator::experiments::{self, ExperimentCtx};
use asyncpr::metrics::table2_markdown;
use asyncpr::util::Bench;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("BENCH_FAST").ok().as_deref() == Some("1");
    let graph = if quick { "scaled:28190" } else { "stanford" };
    let bw_scale = if quick {
        asyncpr::simnet::ClusterProfile::demand_matched_scale(28_190, 4)
    } else {
        1.0
    };
    println!("== bench table2 (graph = {graph}, async p=4) ==\n");
    let ctx = ExperimentCtx::new(RunConfig { graph: graph.into(), bandwidth_scale: bw_scale, ..Default::default() })?;

    let m = experiments::table2(&ctx, 4)?;
    println!("{}", table2_markdown(&m));
    println!("paper: diagonals 82-111, off-diagonals 22-66, import pct 28-45%\n");

    // shape assertions
    for i in 0..4 {
        for j in 0..4 {
            if i != j {
                assert!(
                    m.imports[i][j] < m.imports[i][i],
                    "off-diagonal [{i}][{j}] must be below the diagonal"
                );
            }
        }
        assert!(
            m.import_pct[i] < 100.0,
            "async imports must be incomplete (receiver {i}: {}%)",
            m.import_pct[i]
        );
    }
    let cancelled: u64 = m.wire_cancelled;
    assert!(cancelled > 0, "the saturated wire must cancel sends");
    println!(
        "shape check PASSED: diagonals dominate, imports incomplete ({} sends cancelled)",
        cancelled
    );

    let bench = Bench::default();
    let stats = bench.run("simulate table2 run (async p=4)", || {
        let _ = experiments::table2(&ctx, 4).unwrap();
    });
    println!("\n{}", stats.report());
    Ok(())
}
