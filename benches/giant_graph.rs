//! Bench: the giant-graph memory tier end-to-end.
//!
//! The claim under test is that a ~10⁸-edge synthetic web fits through
//! the whole pipeline in CI-sized RAM: an R-MAT edge stream is written
//! straight to the binary edge format without ever materializing in
//! memory ([`save_edgelist_bin_iter`] over [`rmat_edges`]), the CSR is
//! built from that file by the chunked two-pass loader
//! ([`stream_csr_from_bin`]) whose peak footprint is the CSR arrays
//! plus O(n) counters — never the 2× edge-list spike of the
//! materialize-then-build route — and the resulting row pointers land
//! in the compact u32 tier, strictly smaller than the wide layout.
//! The graph then goes epoch-resident: churn batches inject into a
//! live [`ShardedPush`] drained by the threaded backend with work
//! stealing on, so the rank vector follows the evolving giant without
//! a rebuild.
//!
//! Acceptance (a bail is a regression, see benches/README.md): the
//! compact CSR must be strictly smaller than its wide-layout
//! equivalent, every drain must converge with rank mass pinned to
//! 1e-9, and — at the full (non `--quick`) shape — the process
//! peak RSS must stay below the dense-layout estimate (wide CSR plus
//! a materialized edge list, what the old route paid). The quick
//! shape skips the RSS gate only because at small scales the binary
//! and runtime baseline dominate VmHWM; everything else is checked
//! identically.
//!
//! Shape knobs: `ASYNCPR_RMAT_SCALE` (default 24 full / 18 quick;
//! n = 2^scale, m = 8n requested before dedup) and the usual
//! `--quick` / `BENCH_FAST=1`.
//!
//! [`save_edgelist_bin_iter`]: asyncpr::graph::io::save_edgelist_bin_iter
//! [`rmat_edges`]: asyncpr::graph::generators::rmat_edges
//! [`stream_csr_from_bin`]: asyncpr::graph::io::stream_csr_from_bin
//! [`ShardedPush`]: asyncpr::stream::ShardedPush

use std::time::{Duration, Instant};

use asyncpr::asynciter::{run_threaded_push, PushThreadOptions, TermMode};
use asyncpr::graph::generators::{churn_batch, rmat_edges, ChurnParams, RMAT_WEB_PROBS};
use asyncpr::graph::io::{save_edgelist_bin_iter, stream_csr_from_bin, StreamCsrOptions};
use asyncpr::stream::{power_method_f64, DeltaGraph, ShardedPush};
use asyncpr::util::{Json, Rng};

fn jobj(pairs: &[(&str, Json)]) -> Json {
    Json::Obj(pairs.iter().map(|(k, v)| (k.to_string(), v.clone())).collect())
}

/// Machine-readable bench output: set `ASYNCPR_BENCH_JSON_DIR=benches`
/// to refresh the committed `benches/BENCH_giant_graph.json` trajectory
/// file (see benches/README.md). No-op otherwise.
fn write_bench_json(doc: &Json) -> anyhow::Result<()> {
    if let Ok(dir) = std::env::var("ASYNCPR_BENCH_JSON_DIR") {
        if !dir.is_empty() {
            let path = format!("{dir}/BENCH_giant_graph.json");
            std::fs::write(&path, doc.to_string_compact())?;
            eprintln!("wrote {path}");
        }
    }
    Ok(())
}

/// Process peak resident set (`VmHWM`), bytes. `None` off Linux —
/// the RSS gate then degrades to report-only.
fn peak_rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with("VmHWM:"))?;
    let kb: u64 = line
        .trim_start_matches("VmHWM:")
        .trim()
        .trim_end_matches("kB")
        .trim()
        .parse()
        .ok()?;
    Some(kb * 1024)
}

fn mb(bytes: u64) -> f64 {
    bytes as f64 / (1024.0 * 1024.0)
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("BENCH_FAST").ok().as_deref() == Some("1");
    let scale: u32 = match std::env::var("ASYNCPR_RMAT_SCALE") {
        Ok(s) => s.parse()?,
        Err(_) => {
            if quick {
                18
            } else {
                24
            }
        }
    };
    anyhow::ensure!((1..=28).contains(&scale), "scale {scale} out of the supported 1..=28");
    let edge_factor = 8usize;
    let n = 1usize << scale;
    let m = n * edge_factor;
    let threads = 4usize;
    let tol = 1e-9;
    let epochs = if quick { 2 } else { 3 };
    println!(
        "== bench giant_graph (rmat scale {scale}: n = {n}, m = {m} requested, \
         {threads} shards, {epochs} churn epochs) ==\n"
    );

    // ---- stage 1: stream the R-MAT web straight to disk -------------
    // The edge stream never materializes: generator → 8-byte records.
    let bin = std::env::temp_dir().join(format!("asyncpr_giant_rmat_{scale}.bin"));
    let t0 = Instant::now();
    save_edgelist_bin_iter(&bin, n, m as u64, rmat_edges(scale, m, RMAT_WEB_PROBS, 42))?;
    let write_ms = t0.elapsed().as_secs_f64() * 1e3;
    let edgelist_bytes = (m as u64) * 8;
    println!(
        "write:  {} edges -> {} ({:.0} MiB) in {write_ms:.0} ms",
        m,
        bin.display(),
        mb(edgelist_bytes)
    );

    // ---- stage 2: two-pass streaming CSR build ----------------------
    let t0 = Instant::now();
    let csr = stream_csr_from_bin(&bin, &StreamCsrOptions::default())?;
    let build_ms = t0.elapsed().as_secs_f64() * 1e3;
    let nnz = csr.nnz();
    let heap = csr.heap_bytes() as u64;
    let heap_wide = csr.heap_bytes_wide() as u64;
    let rss = peak_rss_bytes();
    println!(
        "build:  n = {}, nnz = {nnz} (dedup of {m}) in {build_ms:.0} ms; \
         CSR heap {:.0} MiB compact vs {:.0} MiB wide",
        csr.n(),
        mb(heap),
        mb(heap_wide)
    );

    // the tier's reason to exist: the compact row pointers must be a
    // strict win over the wide layout
    anyhow::ensure!(
        csr.rowptr_is_compact(),
        "nnz {nnz} fits u32 but the streaming build kept wide row pointers"
    );
    anyhow::ensure!(
        heap < heap_wide,
        "compact CSR ({heap} B) is not strictly smaller than the wide layout ({heap_wide} B)"
    );

    // what the materialize-then-build route pays at peak: the full
    // edge list resident next to a wide-rowptr CSR
    let dense_estimate = heap_wide + edgelist_bytes;
    match rss {
        Some(r) => {
            println!(
                "rss:    peak {:.0} MiB vs dense-layout estimate {:.0} MiB",
                mb(r),
                mb(dense_estimate)
            );
            // only gate at the giant shape: at quick scales the binary
            // and runtime baseline dominate VmHWM and the comparison
            // measures the toolchain, not the loader
            if !quick && r >= dense_estimate {
                anyhow::bail!(
                    "streaming build peaked at {r} B, not below the dense-layout \
                     estimate {dense_estimate} B"
                );
            }
        }
        None => println!("rss:    VmHWM unavailable on this platform (gate skipped)"),
    }

    // ---- stage 3: go epoch-resident -----------------------------
    let t0 = Instant::now();
    let g = DeltaGraph::from_csr(&csr);
    let adopt_ms = t0.elapsed().as_secs_f64() * 1e3;
    drop(csr); // churn only needs the overlay
    println!("adopt:  CSR -> DeltaGraph in {adopt_ms:.0} ms\n");

    let mut sp = ShardedPush::new(&g, 0.85, threads);
    let opts = PushThreadOptions {
        tol,
        term: TermMode::Protocol,
        steal: true,
        timeout: Duration::from_secs(if quick { 300 } else { 3600 }),
        ..Default::default()
    };
    let churn = ChurnParams::scaled_to(g.n(), g.m());
    let mut rng = Rng::new(7);
    let mut total_pushes = 0u64;
    let mut total_wall = 0.0f64;
    for epoch in 0..=epochs {
        if epoch > 0 {
            let batch = churn_batch(&g, &churn, &mut rng);
            let delta = g.apply(&batch)?;
            sp.apply_batch(&g, &delta);
        }
        let tm = run_threaded_push(&g, &mut sp, &opts);
        anyhow::ensure!(
            tm.converged,
            "epoch {epoch}: drain stopped unconverged ({}) at residual {:.3e}",
            tm.stop_cause.name(),
            tm.residual
        );
        let pushes: u64 = tm.shard_pushes.iter().sum();
        let wall = tm.wall.as_secs_f64();
        total_pushes += pushes;
        total_wall += wall;
        let mass = sp.mass();
        anyhow::ensure!(
            (mass - 1.0).abs() < 1e-9,
            "epoch {epoch}: rank mass drifted to {mass}"
        );
        println!(
            "epoch {epoch}: {pushes} pushes in {:.0} ms, residual {:.1e}, mass {mass:.12}",
            wall * 1e3,
            tm.residual
        );
    }
    let pushes_per_sec = if total_wall > 0.0 { total_pushes as f64 / total_wall } else { 0.0 };
    println!(
        "\nchurn:  {total_pushes} pushes over {} epochs, {:.2e} pushes/s",
        epochs + 1,
        pushes_per_sec
    );

    // at the quick shape the power reference is affordable — pin the
    // resident ranks to it; the giant shape relies on the exact
    // residual + mass gates above
    if quick {
        let (xref, _) = power_method_f64(&g, 0.85, 1e-10, 10_000);
        let l1: f64 = sp.ranks().iter().zip(&xref).map(|(a, b)| (a - b).abs()).sum();
        println!("check:  L1 vs power reference {l1:.1e}");
        anyhow::ensure!(l1 < 1e-7, "resident ranks drifted from the power reference: {l1:.1e}");
    }

    let _ = std::fs::remove_file(&bin);

    write_bench_json(&jobj(&[
        ("schema", Json::Num(1.0)),
        ("bench", Json::Str("giant_graph".to_string())),
        ("graph", Json::Str(format!("rmat:{scale}"))),
        ("quick", Json::Bool(quick)),
        ("scale", Json::Num(scale as f64)),
        ("edge_factor", Json::Num(edge_factor as f64)),
        ("n", Json::Num(n as f64)),
        ("m_requested", Json::Num(m as f64)),
        ("nnz", Json::Num(nnz as f64)),
        ("compact_rowptr", Json::Bool(true)),
        (
            "build",
            jobj(&[
                ("write_ms", Json::Num(write_ms)),
                ("build_ms", Json::Num(build_ms)),
                ("csr_heap_bytes", Json::Num(heap as f64)),
                ("csr_heap_bytes_wide", Json::Num(heap_wide as f64)),
                ("edgelist_bytes", Json::Num(edgelist_bytes as f64)),
                ("dense_estimate_bytes", Json::Num(dense_estimate as f64)),
                (
                    "peak_rss_bytes",
                    rss.map(|r| Json::Num(r as f64)).unwrap_or(Json::Null),
                ),
            ]),
        ),
        (
            "churn",
            jobj(&[
                ("threads", Json::Num(threads as f64)),
                ("epochs", Json::Num((epochs + 1) as f64)),
                ("pushes", Json::Num(total_pushes as f64)),
                ("wall_ms", Json::Num(total_wall * 1e3)),
                ("pushes_per_sec", Json::Num(pushes_per_sec)),
            ]),
        ),
    ]))?;
    Ok(())
}
