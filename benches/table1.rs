//! Bench T1: regenerate Table 1 of the paper.
//!
//! Paper (Stanford-Web, α=0.85, local tol 1e-6, pcMax=1):
//!
//! | procs | iters | t (s) | [iters_min, iters_max] | [t_min, t_max] | <speedUp> |
//! |-------|-------|-------|------------------------|----------------|-----------|
//! | 2     | 44    | 179.2 | [68, 69]               | [86.3, 94.5]   | 1.98      |
//! | 4     | 44    | 331.4 | [82, 111]              | [139.2, 153.1] | 2.27      |
//! | 6     | 44    | 402.8 | [129, 148]             | [141.7, 160.6] | 2.66      |
//!
//! Virtual times regenerate deterministically; the wall-clock of the
//! *simulation itself* is also measured (criterion is unavailable
//! offline — util::harness provides warmup+stats).
//!
//! BENCH_FAST=1 or --quick runs the 1/10-scale graph.

use asyncpr::config::RunConfig;
use asyncpr::coordinator::experiments::{self, ExperimentCtx};
use asyncpr::metrics::table1_markdown;
use asyncpr::util::Bench;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("BENCH_FAST").ok().as_deref() == Some("1");
    let graph = if quick { "scaled:28190" } else { "stanford" };
    let bw_scale = if quick {
        asyncpr::simnet::ClusterProfile::demand_matched_scale(28_190, 4)
    } else {
        1.0
    };
    println!("== bench table1 (graph = {graph}) ==\n");
    let ctx = ExperimentCtx::new(RunConfig { graph: graph.into(), bandwidth_scale: bw_scale, ..Default::default() })?;

    let rows = experiments::table1(&ctx, &[2, 4, 6])?;
    let t1: Vec<_> = rows.iter().map(|(r, _, _)| r.clone()).collect();
    println!("{}", table1_markdown(&t1));
    println!("paper:   p=2: 44it/179.2s vs [68,69]it/[86.3,94.5]s speedup 1.98");
    println!("         p=4: 44it/331.4s vs [82,111]/[139.2,153.1] speedup 2.27");
    println!("         p=6: 44it/402.8s vs [129,148]/[141.7,160.6] speedup 2.66\n");

    // shape assertions (who wins, direction of growth)
    let mut last_sync = 0.0;
    for r in &t1 {
        assert!(r.speedup > 1.0, "async must win at p={}", r.procs);
        assert!(r.sync_time > last_sync, "sync time must grow with p");
        assert!(r.async_iters_max >= r.sync_iters, "async iterates at least as much");
        last_sync = r.sync_time;
    }
    println!("shape check PASSED: async wins at every p; sync time grows with p");

    // wall-clock of the simulation itself
    let bench = Bench::default();
    let stats = bench.run("simulate table1 row p=4 (sync+async)", || {
        let _ = experiments::table1(&ctx, &[4]).unwrap();
    });
    println!("\n{}", stats.report());
    Ok(())
}
