//! Bench G1+G2: the §5.2 global-threshold observations.
//!
//! G1 (paper): "Assembling vector fragments … reveals that a threshold
//! of the order of 5×10⁻⁵ has actually been reached" when the async
//! protocol stops at local tol 1e-6.
//!
//! G2 (paper): "timing with respect to reaching a common global
//! threshold … reveals a modest speedup of asynchronous vs.
//! synchronous computation in the 10-20 % range."
//!
//! Plus the §5.2 ranking remark: relative ranking survives the looser
//! threshold (quantified via Kendall-τ / top-100 overlap).

use asyncpr::config::RunConfig;
use asyncpr::coordinator::experiments::{self, ExperimentCtx};

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("BENCH_FAST").ok().as_deref() == Some("1");
    // the omniscient oracle runs apply_google per UE event — keep the
    // graph mid-sized so the bench completes in seconds
    let graph = if quick { "scaled:8000" } else { "scaled:28190" };
    let bw_scale = if quick {
        asyncpr::simnet::ClusterProfile::demand_matched_scale(8_000, 4)
    } else {
        asyncpr::simnet::ClusterProfile::demand_matched_scale(28_190, 4)
    };
    println!("== bench global_threshold (graph = {graph}) ==\n");
    let ctx = ExperimentCtx::new(RunConfig { graph: graph.into(), bandwidth_scale: bw_scale, ..Default::default() })?;

    // G1 at the paper's p=4 Table-2 configuration
    let g = experiments::global_threshold(&ctx, 4, 1e-6)?;
    println!(
        "G1: async stop at local tol {:.0e} -> TRUE global residual {:.2e}",
        g.local_tol, g.achieved_global_residual
    );
    println!("    paper: local 1e-6 -> global ~5e-5 (a ~50x gap)");
    println!(
        "    ranking: kendall-tau {:.6}, top-100 overlap {:.2} (paper: ranking is what matters)",
        g.ranking_tau, g.top100_overlap
    );
    println!(
        "\nG2 (p=4): race to common global tol {:.1e}: sync {:.1}s, async {:.1}s -> speedup {:.2}",
        g.achieved_global_residual.max(g.local_tol),
        g.sync_time_global,
        g.async_time_global,
        g.speedup_global
    );
    // the paper's 'modest 10-20%' fits the moderately-saturated regime;
    // at p=4 our wire model is harsher than their LAN (imports ~10% vs
    // their 28-45%), so the async global race is measured at p=2 too
    let g2 = experiments::global_threshold(&ctx, 2, 1e-6)?;
    println!(
        "G2 (p=2): race to common global tol {:.1e}: sync {:.1}s, async {:.1}s -> speedup {:.2}",
        g2.achieved_global_residual.max(g2.local_tol),
        g2.sync_time_global,
        g2.async_time_global,
        g2.speedup_global
    );
    println!("    paper: modest 10-20% speedup at a common global threshold");

    // shape assertions
    assert!(
        g.achieved_global_residual > g.local_tol,
        "global residual must be looser than the local threshold"
    );
    assert!(
        g.achieved_global_residual < 1e-2,
        "but still small (got {:.2e})",
        g.achieved_global_residual
    );
    assert!(g.ranking_tau > 0.999, "ranking must survive (tau {})", g.ranking_tau);
    // the paper reports +10-20% for async at the common global
    // threshold; that holds in the moderately-saturated p=2 regime.
    // At p=4 our wire is harsher than theirs and async pays staleness —
    // reported, not asserted (see EXPERIMENTS.md §Deviations).
    assert!(
        g2.speedup_global > 0.9,
        "async must stay competitive in the p=2 global race (got {:.2})",
        g2.speedup_global
    );
    println!("\nshape check PASSED: local<global residual gap, ranking intact, async competitive");
    Ok(())
}
