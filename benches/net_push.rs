//! Bench: asynchronous push over a throttled heterogeneous wire vs a
//! barriered superstep baseline.
//!
//! The async side is a real measured run: `run_threaded_push` with the
//! exchange riding the loopback [`Transport`] throttled by the paper's
//! Beowulf bandwidth/latency curves, one laggard peer's outbound links
//! carrying an extra injected delay (the heterogeneity). The wire
//! delays are real wall time — the loopback paces frame availability
//! with the clock, so the measurement includes every second the
//! asynchronous workers managed (or failed) to hide behind compute.
//!
//! The baseline is the deterministic superstep loop
//! ([`ShardedPush::solve`]: drain every shard, deliver every outbox,
//! barrier, repeat) with its compute measured and its wire charged
//! analytically from the same profile: each superstep ends at a
//! barrier, so every round pays the slowest link once — the laggard's
//! injected delay plus the shared-wire transfer of that round's
//! fragment bytes. The charge is generous to the baseline (one
//! latency hit per round, perfect overlap inside the round); the
//! paper's premise is that the async drain wins anyway because no
//! worker ever waits out the laggard's round trip.
//!
//! A correctness postlude holds both sides to the f64 power reference;
//! the perf comparison is reported (and written to the trajectory
//! file), not gated — wall clock on a shared CI box is informational.
//!
//! [`Transport`]: asyncpr::net::Transport

use std::time::Instant;

use asyncpr::asynciter::{run_threaded_push, PushThreadOptions, StopCause, TermMode};
use asyncpr::net::{FaultPlan, NetConfig};
use asyncpr::simnet::ClusterProfile;
use asyncpr::stream::{power_method_f64, DeltaGraph, ShardedPush};
use asyncpr::util::Json;

fn jobj(pairs: &[(&str, Json)]) -> Json {
    Json::Obj(pairs.iter().map(|(k, v)| (k.to_string(), v.clone())).collect())
}

/// Machine-readable bench output: set `ASYNCPR_BENCH_JSON_DIR=benches`
/// to refresh the committed `benches/BENCH_net_push.json` trajectory
/// file (see benches/README.md). No-op otherwise.
fn write_bench_json(doc: &Json) -> anyhow::Result<()> {
    if let Ok(dir) = std::env::var("ASYNCPR_BENCH_JSON_DIR") {
        if !dir.is_empty() {
            let path = format!("{dir}/BENCH_net_push.json");
            std::fs::write(&path, doc.to_string_compact())?;
            eprintln!("wrote {path}");
        }
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("BENCH_FAST").ok().as_deref() == Some("1");
    let graph = if quick { "scaled:3000" } else { "scaled:8000" };
    let shards = 4usize;
    let tol = 1e-9;
    let lag_ms = 25.0; // the laggard peer's extra one-way link delay
    println!(
        "== bench net_push (graph = {graph}, {shards} shards, beowulf wire, \
         laggard +{lag_ms} ms) ==\n"
    );

    let el = asyncpr::coordinator::load_edgelist(graph, 42)?;
    let g = DeltaGraph::from_edgelist(&el);
    println!("n = {}, m = {}\n", g.n(), g.m());

    // profile covers workers + the monitor endpoint
    let profile = ClusterProfile::paper_beowulf(shards + 1);

    // ---- async over the throttled heterogeneous loopback ------------
    let mut sp_async = ShardedPush::new(&g, 0.85, shards);
    let aopts = PushThreadOptions {
        tol,
        term: TermMode::Protocol,
        timeout: std::time::Duration::from_secs(120),
        net: Some(NetConfig {
            profile: profile.clone(),
            faults: FaultPlan::delay_from(shards - 1, lag_ms, 0.0),
            seed: 42,
        }),
        ..Default::default()
    };
    let t0 = Instant::now();
    let tm = run_threaded_push(&g, &mut sp_async, &aopts);
    let async_wall = t0.elapsed().as_secs_f64() * 1e3;
    let async_pushes: u64 = tm.shard_pushes.iter().sum();
    println!(
        "async:   stop {} after {async_wall:.1} ms, {async_pushes} pushes, \
         {} fragments, residual {:.1e} (converged: {}), {} CONVERGE / {} DIVERGE",
        tm.stop_cause.name(),
        tm.fragments_sent.iter().sum::<u64>(),
        tm.residual,
        tm.converged,
        tm.term_converge,
        tm.term_diverge
    );
    if tm.stop_cause == StopCause::Protocol && !tm.converged {
        anyhow::bail!("protocol stop was unsound: residual {:.3e} >= tol {tol:.0e}", tm.residual);
    }
    if !tm.converged {
        anyhow::bail!("async run over the wire failed to converge ({})", tm.stop_cause.name());
    }

    // ---- barriered superstep baseline -------------------------------
    // measured compute, analytically charged wire: every superstep
    // barrier waits out the laggard's delay plus the shared wire
    // moving that round's fragment bytes
    let mut sp_sync = ShardedPush::new(&g, 0.85, shards);
    let t0 = Instant::now();
    let st = sp_sync.solve(&g, tol, u64::MAX);
    let sync_compute = t0.elapsed().as_secs_f64() * 1e3;
    anyhow::ensure!(st.converged, "superstep baseline hit the push budget");
    let per_round_elems = (st.pushes / st.rounds.max(1)) as usize;
    let per_round_wire =
        lag_ms * 1e-3 + profile.wire_time(profile.fragment_bytes(per_round_elems));
    let sync_wire = st.rounds as f64 * per_round_wire * 1e3;
    let sync_wall = sync_compute + sync_wire;
    println!(
        "barrier: {} supersteps, {} pushes, {} fragments — {sync_compute:.1} ms compute \
         + {sync_wire:.1} ms charged wire = {sync_wall:.1} ms",
        st.rounds, st.pushes, st.fragments
    );

    let speedup = if async_wall > 0.0 { sync_wall / async_wall } else { 0.0 };
    println!(
        "\nasync over the throttled wire vs barriered supersteps: {speedup:.2}x \
         ({async_wall:.1} ms vs {sync_wall:.1} ms)"
    );

    // correctness before speed: both sides land on the reference
    let (xref, _) = power_method_f64(&g, 0.85, 1e-10, 10_000);
    for (name, sp) in [("async", &sp_async), ("barrier", &sp_sync)] {
        let l1: f64 = sp.ranks().iter().zip(&xref).map(|(a, b)| (a - b).abs()).sum();
        let mass = sp.mass();
        println!("{name}: L1 vs power {l1:.1e}, mass {mass:.12}");
        if l1 > 1e-7 {
            anyhow::bail!("{name} drifted from the power reference: {l1:.1e}");
        }
        if (mass - 1.0).abs() > 1e-9 {
            anyhow::bail!("{name} mass drifted to {mass}");
        }
    }

    write_bench_json(&jobj(&[
        ("schema", Json::Num(1.0)),
        ("bench", Json::Str("net_push".to_string())),
        ("graph", Json::Str(graph.to_string())),
        ("quick", Json::Bool(quick)),
        ("shards", Json::Num(shards as f64)),
        ("lag_ms", Json::Num(lag_ms)),
        (
            "async",
            jobj(&[
                ("stop", Json::Str(tm.stop_cause.name().to_string())),
                ("wall_ms", Json::Num(async_wall)),
                ("pushes", Json::Num(async_pushes as f64)),
                ("fragments", Json::Num(tm.fragments_sent.iter().sum::<u64>() as f64)),
                ("residual", Json::Num(tm.residual)),
                ("converged", Json::Bool(tm.converged)),
                ("converge_msgs", Json::Num(tm.term_converge as f64)),
                ("diverge_msgs", Json::Num(tm.term_diverge as f64)),
            ]),
        ),
        (
            "barrier",
            jobj(&[
                ("rounds", Json::Num(st.rounds as f64)),
                ("pushes", Json::Num(st.pushes as f64)),
                ("fragments", Json::Num(st.fragments as f64)),
                ("compute_ms", Json::Num(sync_compute)),
                ("charged_wire_ms", Json::Num(sync_wire)),
                ("wall_ms", Json::Num(sync_wall)),
            ]),
        ),
        ("speedup", Json::Num(speedup)),
    ]))?;
    Ok(())
}
