//! Bench A1–A4: ablations for the design choices §6 discusses.
//!
//! A1 cancel-window sweep — §6: unbounded sending "could overload the
//!    network; we guard against this misfortune by cancelling
//!    send()/recv() threads not having completed within a time window."
//! A2 adaptive per-peer rates — §6 future work.
//! A3 clique vs star vs tree — §6: "we would like to avoid all-to-all".
//! A4 ranking robustness vs threshold — §5.2 closing remark.
//! A5 partitioning: consecutive ⌈n/p⌉ (paper) vs balanced-nnz.

use std::sync::Arc;

use asyncpr::asynciter::{BlockOperator, Mode, NativeBlockOp, RunSpec, SimEngine};
use asyncpr::config::RunConfig;
use asyncpr::coordinator::experiments::{self, ExperimentCtx};
use asyncpr::coordinator::Partitioner;
use asyncpr::simnet::Topology;
use asyncpr::util::Table;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("BENCH_FAST").ok().as_deref() == Some("1");
    let graph = if quick { "scaled:8000" } else { "scaled:28190" };
    let bw_scale = if quick {
        asyncpr::simnet::ClusterProfile::demand_matched_scale(8_000, 4)
    } else {
        asyncpr::simnet::ClusterProfile::demand_matched_scale(28_190, 4)
    };
    println!("== bench ablations (graph = {graph}) ==\n");
    let ctx = ExperimentCtx::new(RunConfig { graph: graph.into(), bandwidth_scale: bw_scale, ..Default::default() })?;

    // ---- A1: cancellation window ----
    println!("A1: cancellation-window sweep (async, p=4)");
    let mut t = Table::new(&["window (s)", "t_max (s)", "cancelled", "queue wait (s)", "resid"]);
    let mut unbounded_wait = 0.0;
    let mut bounded_wait = f64::MAX;
    for (w, m) in
        experiments::ablation_cancel_window(&ctx, 4, &[None, Some(1.0), Some(3.0), Some(10.0)])?
    {
        let (_, tmax) = m.time_range();
        if w.is_none() {
            unbounded_wait = m.wire_queue_wait;
        } else {
            bounded_wait = bounded_wait.min(m.wire_queue_wait);
        }
        t.row(&[
            w.map(|x| format!("{x}")).unwrap_or_else(|| "inf".into()),
            format!("{tmax:.1}"),
            m.wire_cancelled.to_string(),
            format!("{:.1}", m.wire_queue_wait),
            format!("{:.1e}", m.final_global_residual),
        ]);
    }
    println!("{}", t.to_markdown());
    assert!(
        bounded_wait < unbounded_wait,
        "windows must relieve queue pressure ({bounded_wait} vs {unbounded_wait})"
    );
    println!("A1 PASSED: cancellation windows bound the sender-side buffer bloat\n");

    // ---- A2: adaptive rates with a straggler ----
    println!("A2: adaptive per-peer rates (async p=4, node 3 is 3x slower)");
    let (fixed, adap) = experiments::ablation_adaptive(&ctx, 4, 3.0)?;
    println!(
        "  fixed:    t={:.1}s attempted={} cancelled={} resid={:.1e}",
        fixed.total_time,
        fixed.sends_attempted.iter().sum::<u64>(),
        fixed.wire_cancelled,
        fixed.final_global_residual
    );
    println!(
        "  adaptive: t={:.1}s attempted={} cancelled={} resid={:.1e}",
        adap.total_time,
        adap.sends_attempted.iter().sum::<u64>(),
        adap.wire_cancelled,
        adap.final_global_residual
    );
    assert!(
        adap.wire_cancelled <= fixed.wire_cancelled,
        "adaptive must not cancel more than fixed"
    );
    println!("A2 PASSED: adaptive sheds doomed sends\n");

    // ---- A3: topology ----
    println!("A3: topology sweep (async, p=6)");
    let mut t3 = Table::new(&["topology", "msgs/round", "t_max (s)", "cancelled", "resid"]);
    for (topo, m) in experiments::ablation_topology(
        &ctx,
        6,
        &[Topology::Clique, Topology::Star, Topology::BinaryTree],
    )? {
        let (_, tmax) = m.time_range();
        t3.row(&[
            format!("{topo:?}"),
            topo.messages_per_round(6).to_string(),
            format!("{tmax:.1}"),
            m.wire_cancelled.to_string(),
            format!("{:.1e}", m.final_global_residual),
        ]);
    }
    println!("{}", t3.to_markdown());
    println!("A3 done: tree/star trade per-step freshness for far less wire traffic\n");

    // ---- A4: ranking robustness vs threshold ----
    println!("A4: ranking robustness under relaxed thresholds (async p=4)");
    let mut t4 = Table::new(&["local tol", "global resid", "kendall-tau", "top-100"]);
    let rows = experiments::ablation_ranking(&ctx, 4, &[1e-4, 1e-5, 1e-6])?;
    for (tol, resid, tau, top) in &rows {
        t4.row(&[
            format!("{tol:.0e}"),
            format!("{resid:.1e}"),
            format!("{tau:.6}"),
            format!("{top:.2}"),
        ]);
    }
    println!("{}", t4.to_markdown());
    let tight_tau = rows.last().unwrap().2;
    let loose_tau = rows.first().unwrap().2;
    assert!(tight_tau >= loose_tau - 1e-6, "tighter threshold can't rank worse");
    assert!(loose_tau > 0.98, "even loose thresholds preserve ranking");
    println!("A4 PASSED: relative ranking survives relaxed thresholds (the §5.2 point)\n");

    // ---- A5: partitioning ----
    println!("A5: consecutive ceil(n/p) (paper) vs balanced-nnz partitioning (async p=4)");
    let problem = ctx.problem.clone();
    let run_with = |partitioner: &Partitioner| {
        let mut ops: Vec<Box<dyn BlockOperator>> = partitioner
            .blocks()
            .into_iter()
            .map(|(lo, hi)| {
                Box::new(NativeBlockOp::new(Arc::clone(&problem), lo, hi))
                    as Box<dyn BlockOperator>
            })
            .collect();
        let profile = asyncpr::simnet::ClusterProfile::paper_beowulf(4);
        SimEngine::new(&profile, &problem)
            .run(&mut ops, &RunSpec::paper_table1(Mode::Asynchronous))
    };
    let cons = run_with(&Partitioner::consecutive(problem.n(), 4));
    let bal = run_with(&Partitioner::balanced_nnz(&problem.csr, 4));
    let (_, t_cons) = cons.time_range();
    let (_, t_bal) = bal.time_range();
    println!("  consecutive:  t_max={t_cons:.1}s iters={:?}", cons.iters);
    println!("  balanced-nnz: t_max={t_bal:.1}s iters={:?}", bal.iters);
    println!("A5 done: nnz balancing equalizes per-iteration compute across UEs\n");
    Ok(())
}
