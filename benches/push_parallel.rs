//! Bench: shard-count scaling of the parallel residual-push engine.
//!
//! Cold-solves one generated power-law web (~200k edges at full scale)
//! with `ShardedPush` + `run_threaded_push` at shard counts 1/2/4/8 and
//! reports wall time, total pushes (staleness inflates the count as
//! shards grow — the price of asynchrony the paper trades for wall
//! time), fragments exchanged, and speedup over the single-shard run.
//! A correctness postlude checks every shard count lands on the same
//! ranks as the f64 power method.
//!
//! The speedup ceiling is min(shards, host cores); on the paper's
//! premise the interesting number is that it is > 1 at all — no
//! synchronization phase, residual fragments only, and the solver
//! still accelerates.

use std::time::Instant;

use asyncpr::asynciter::{
    run_threaded_push, PushThreadOptions, StallInjection, StopCause, TermMode,
};
use asyncpr::graph::generators::{churn_batch, ChurnParams};
use asyncpr::metrics::{parallel_push_markdown, ShardScaleRow};
use asyncpr::stream::{power_method_f64, DeltaGraph, PushState, ShardedPush, UpdateBatch};
use asyncpr::util::{Bench, Json, Rng};

fn jobj(pairs: &[(&str, Json)]) -> Json {
    Json::Obj(pairs.iter().map(|(k, v)| (k.to_string(), v.clone())).collect())
}

/// Machine-readable bench output: set `ASYNCPR_BENCH_JSON_DIR=benches`
/// to refresh the committed `benches/BENCH_push_parallel.json`
/// trajectory file (see benches/README.md). No-op otherwise.
fn write_bench_json(doc: &Json) -> anyhow::Result<()> {
    if let Ok(dir) = std::env::var("ASYNCPR_BENCH_JSON_DIR") {
        if !dir.is_empty() {
            let path = format!("{dir}/BENCH_push_parallel.json");
            std::fs::write(&path, doc.to_string_compact())?;
            eprintln!("wrote {path}");
        }
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("BENCH_FAST").ok().as_deref() == Some("1");
    // WebParams::scaled keeps Stanford-Web's ~8.2 edges/node, so
    // scaled:25000 carries ~205k edges
    let graph = if quick { "scaled:8000" } else { "scaled:25000" };
    let tol = 1e-9;
    println!("== bench push_parallel (graph = {graph}, tol = {tol:.0e}) ==\n");

    let el = asyncpr::coordinator::load_edgelist(graph, 42)?;
    let g = DeltaGraph::from_edgelist(&el);
    println!(
        "n = {}, m = {}, host parallelism = {}\n",
        g.n(),
        g.m(),
        std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1)
    );

    let bench = if quick { Bench::new(1, 3) } else { Bench::new(1, 5) };
    let opts = PushThreadOptions { tol, ..Default::default() };

    let mut rows: Vec<ShardScaleRow> = Vec::new();
    let mut base_wall = 0.0f64;
    for shards in [1usize, 2, 4, 8] {
        let mut pushes = 0u64;
        let mut fragments = 0u64;
        let mut residual = 0.0f64;
        let stats = bench.run(&format!("cold solve, {shards} shard(s)"), || {
            let mut sp = ShardedPush::new(&g, 0.85, shards);
            let tm = run_threaded_push(&g, &mut sp, &opts);
            pushes = tm.shard_pushes.iter().sum();
            fragments = tm.fragments_sent.iter().sum();
            residual = tm.residual;
        });
        let wall_ms = stats.mean.as_secs_f64() * 1e3;
        if shards == 1 {
            base_wall = wall_ms;
        }
        println!("{}", stats.report());
        rows.push(ShardScaleRow {
            shards,
            wall_ms,
            pushes,
            fragments,
            speedup: if wall_ms > 0.0 { base_wall / wall_ms } else { 0.0 },
            residual,
        });
    }
    println!("\n{}", parallel_push_markdown(&rows));

    // correctness postlude: every shard count lands on the reference
    let (xref, _) = power_method_f64(&g, 0.85, 1e-10, 10_000);
    for shards in [1usize, 4] {
        let mut sp = ShardedPush::new(&g, 0.85, shards);
        let tm = run_threaded_push(&g, &mut sp, &opts);
        let x = sp.ranks();
        let l1: f64 = x.iter().zip(&xref).map(|(a, b)| (a - b).abs()).sum();
        println!(
            "{shards} shard(s): residual {:.1e} (converged: {}), L1 vs power {l1:.1e}",
            tm.residual, tm.converged
        );
    }
    let at4 = rows.iter().find(|r| r.shards == 4).map(|r| r.speedup).unwrap_or(0.0);
    println!(
        "\n4-shard speedup over 1 shard: {at4:.2}x (ceiling: min(4, {} cores))",
        std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1)
    );

    // ---- resident vs roundtrip epoch pipeline -----------------------
    // The same churn stream (identical batches: cloned graph + same
    // rng seed) driven through both epoch handoffs. Work metric is
    // pushes + CSR rows rebuilt: the roundtrip path pays a full
    // O(n)-row rebuild and a scatter/gather every epoch, the resident
    // path splices dirty rows and injects deltas into the live shards.
    let epochs = if quick { 4 } else { 10 };
    let shards = 4usize;
    println!("\n== resident vs roundtrip epoch pipeline ({epochs} churn epochs, {shards} shards) ==\n");
    let churn = ChurnParams::scaled_to(g.n(), g.m());
    let seed = 4242u64;

    // roundtrip: global PushState; per epoch inject -> full to_csr
    // rebuild -> scatter -> threaded drain -> gather -> polish
    let t0 = Instant::now();
    let (round_pushes, round_rows) = {
        let mut g2 = g.clone();
        let mut rng = Rng::new(seed);
        let mut state = PushState::new(g2.n(), 0.85);
        state.begin_epoch();
        let mut sp = ShardedPush::from_state(&state, &g2, shards);
        run_threaded_push(&g2, &mut sp, &opts);
        sp.gather_into(&mut state);
        state.solve(&g2, tol, u64::MAX);
        let mut rebuilt_rows = 0usize;
        for _ in 0..epochs {
            let batch = churn_batch(&g2, &churn, &mut rng);
            let delta = g2.apply(&batch)?;
            state.begin_epoch();
            state.apply_batch(&g2, &delta);
            let csr = g2.to_csr()?; // full rebuild: every row pays
            rebuilt_rows += csr.n();
            let mut sp = ShardedPush::from_state(&state, &g2, shards);
            run_threaded_push(&g2, &mut sp, &opts);
            sp.gather_into(&mut state);
            state.solve(&g2, tol, u64::MAX);
        }
        (state.total_pushes(), rebuilt_rows)
    };
    let round_wall = t0.elapsed().as_secs_f64() * 1e3;

    // resident: one ShardedPush lives across all epochs; deltas inject
    // in place, bounds re-balance on skew, the CSR snapshot is spliced
    let t0 = Instant::now();
    let (res_pushes, res_rows) = {
        let mut g2 = g.clone();
        let mut rng = Rng::new(seed);
        let mut sharded = ShardedPush::new(&g2, 0.85, shards);
        let ropts = PushThreadOptions { rebalance_factor: Some(2.0), ..opts.clone() };
        let tm = run_threaded_push(&g2, &mut sharded, &ropts);
        if !tm.converged {
            sharded.solve(&g2, tol, u64::MAX);
        }
        let mut csr = g2.to_csr()?; // splice-chain baseline (epoch 0)
        let mut spliced_rows = 0usize;
        for _ in 0..epochs {
            let batch = churn_batch(&g2, &churn, &mut rng);
            let delta = g2.apply(&batch)?;
            sharded.begin_epoch();
            sharded.apply_batch(&g2, &delta);
            let (next, ms) = g2.merge_csr(&csr)?;
            csr = next;
            spliced_rows += ms.dirty_rows;
            let tm = run_threaded_push(&g2, &mut sharded, &ropts);
            if !tm.converged {
                sharded.solve(&g2, tol, u64::MAX);
            }
        }
        (sharded.total_pushes(), spliced_rows)
    };
    let res_wall = t0.elapsed().as_secs_f64() * 1e3;

    let round_work = round_pushes + round_rows as u64;
    let res_work = res_pushes + res_rows as u64;
    println!(
        "roundtrip: {round_pushes} pushes + {round_rows} rebuilt CSR rows = {round_work} \
         ({round_wall:.1} ms)"
    );
    println!(
        "resident:  {res_pushes} pushes + {res_rows} spliced CSR rows = {res_work} \
         ({res_wall:.1} ms)"
    );
    println!(
        "resident does strictly less push+copy work: {}",
        if res_work < round_work { "yes" } else { "NO" }
    );
    if res_work >= round_work {
        anyhow::bail!("resident epoch path did not beat the scatter/gather roundtrip");
    }

    // ---- steal vs static on a hub-heavy hot spot --------------------
    // Converge, then confine a dense churn burst to the LAST shard's
    // row range: the residual — hence ALL remaining push work — lands
    // on one shard. Statically, its three peers idle-spin their quiet
    // windows while it drains alone (makespan = the hot shard's push
    // count). With --steal the idle workers adopt its hottest rows
    // mid-drain. Metrics compared over identical warm states:
    //   * makespan proxy: max per-shard pushes (scheduler-independent),
    //   * quiet-window stalls: rounds a worker spent idle,
    //   * wall clock (informational: 2-core CI makes it noisy).
    // The bench BAILS if stealing loses — nothing stolen, or a steal
    // makespan no better than static.
    println!("\n== steal vs static (hot spot confined to one shard, {shards} shards) ==\n");
    let steal_race = {
        let mut g2 = g.clone();
        let mut warm = ShardedPush::new(&g2, 0.85, shards);
        warm.solve(&g2, tol, u64::MAX);
        let bounds = warm.partitioner().bounds().to_vec();
        let (blo, bhi) = (bounds[bounds.len() - 2], bounds[bounds.len() - 1]);
        let mut rng = Rng::new(99);
        let mut batch = UpdateBatch::default();
        let burst = if quick { 1_500 } else { 4_000 };
        for _ in 0..burst {
            batch
                .insert
                .push((rng.range(blo, bhi) as u32, rng.range(blo, bhi) as u32));
        }
        let delta = g2.apply(&batch)?;
        warm.begin_epoch();
        warm.apply_batch(&g2, &delta);
        (g2, warm)
    };
    let (g2, warm) = steal_race;
    let run_race = |steal: bool| {
        let mut sp = warm.clone();
        let ropts = PushThreadOptions { steal, steal_batch: 64, ..opts.clone() };
        let t0 = Instant::now();
        let tm = run_threaded_push(&g2, &mut sp, &ropts);
        let wall = t0.elapsed().as_secs_f64() * 1e3;
        let makespan = tm.shard_pushes.iter().copied().max().unwrap_or(0);
        let stalls: u64 = tm.idle_rounds.iter().sum();
        let stolen: u64 = tm.stolen_rows.iter().sum();
        (sp, tm, wall, makespan, stalls, stolen)
    };
    let (mut sp_static, tm_static, wall_s, make_s, stalls_s, _) = run_race(false);
    let (mut sp_steal, tm_steal, wall_t, make_t, stalls_t, stolen) = run_race(true);
    println!(
        "static: makespan {make_s} pushes (per-shard {:?}), {stalls_s} idle rounds, {wall_s:.1} ms",
        tm_static.shard_pushes
    );
    println!(
        "steal:  makespan {make_t} pushes (per-shard {:?}), {stalls_t} idle rounds, {wall_t:.1} ms, \
         {stolen} rows stolen ({} grants)",
        tm_steal.shard_pushes,
        tm_steal.steal_grants.iter().sum::<u64>()
    );
    // correctness before speed: both races land on the reference
    let (xref2, _) = power_method_f64(&g2, 0.85, 1e-10, 10_000);
    for (name, sp, tm) in
        [("static", &mut sp_static, &tm_static), ("steal", &mut sp_steal, &tm_steal)]
    {
        if !tm.converged {
            sp.solve(&g2, tol, u64::MAX);
        }
        let l1: f64 = sp.ranks().iter().zip(&xref2).map(|(a, b)| (a - b).abs()).sum();
        if l1 > 1e-7 {
            anyhow::bail!("{name} race drifted from the power reference: {l1:.1e}");
        }
    }
    println!(
        "stealing spreads the hot shard's work: {}",
        if stolen > 0 && make_t < make_s { "yes" } else { "NO" }
    );
    if stolen == 0 {
        anyhow::bail!("steal race moved no rows — no idle worker ever found the hot shard");
    }
    if make_t >= make_s {
        anyhow::bail!(
            "stealing lost: steal makespan {make_t} >= static {make_s} \
             (stalls {stalls_t} vs {stalls_s})"
        );
    }

    // ---- termination race: quiet window vs §4.2 protocol ------------
    // Identical warm hot-spot states, the hot shard's worker stalled
    // mid-solve. Both termination modes race the same scenario: the
    // protocol's stop must be sound (exact gather-time residual under
    // tol — the bench bails otherwise), while the quiet window's stop
    // cause and residual are reported for the trajectory file; whether
    // it fires prematurely here depends on in-flight fragments, which
    // is exactly why it lost the default to the protocol.
    println!("\n== termination race: --term quiet vs protocol (stalled hot-shard worker) ==\n");
    let run_term = |term: TermMode| {
        let mut sp = warm.clone();
        let topts = PushThreadOptions {
            term,
            inject_stall: Some(StallInjection { worker: shards - 1, after_rounds: 0, ms: 150 }),
            ..opts.clone()
        };
        let t0 = Instant::now();
        let tm = run_threaded_push(&g2, &mut sp, &topts);
        let wall = t0.elapsed().as_secs_f64() * 1e3;
        (tm, wall)
    };
    let (tm_q, wall_q) = run_term(TermMode::Quiet);
    let (tm_p, wall_p) = run_term(TermMode::Protocol);
    println!(
        "quiet:    stop {} after {wall_q:.1} ms, {} pushes, residual {:.1e} (converged: {})",
        tm_q.stop_cause.name(),
        tm_q.shard_pushes.iter().sum::<u64>(),
        tm_q.residual,
        tm_q.converged
    );
    println!(
        "protocol: stop {} after {wall_p:.1} ms, {} pushes, residual {:.1e} (converged: {}), \
         {} CONVERGE / {} DIVERGE",
        tm_p.stop_cause.name(),
        tm_p.shard_pushes.iter().sum::<u64>(),
        tm_p.residual,
        tm_p.converged,
        tm_p.term_converge,
        tm_p.term_diverge
    );
    if tm_p.stop_cause == StopCause::Protocol && !tm_p.converged {
        anyhow::bail!("protocol stop was unsound: residual {:.3e} >= tol {tol:.0e}", tm_p.residual);
    }
    if !tm_p.converged {
        anyhow::bail!("protocol run failed to converge (stop: {})", tm_p.stop_cause.name());
    }

    write_bench_json(&jobj(&[
        ("schema", Json::Num(1.0)),
        ("bench", Json::Str("push_parallel".to_string())),
        ("graph", Json::Str(graph.to_string())),
        ("quick", Json::Bool(quick)),
        ("scaling", Json::Arr(rows.iter().map(|r| r.to_json()).collect())),
        (
            "resident_race",
            jobj(&[
                (
                    "roundtrip",
                    jobj(&[
                        ("pushes", Json::Num(round_pushes as f64)),
                        ("csr_rows", Json::Num(round_rows as f64)),
                        ("work", Json::Num(round_work as f64)),
                        ("wall_ms", Json::Num(round_wall)),
                    ]),
                ),
                (
                    "resident",
                    jobj(&[
                        ("pushes", Json::Num(res_pushes as f64)),
                        ("csr_rows", Json::Num(res_rows as f64)),
                        ("work", Json::Num(res_work as f64)),
                        ("wall_ms", Json::Num(res_wall)),
                    ]),
                ),
            ]),
        ),
        (
            "steal_race",
            jobj(&[
                (
                    "static",
                    jobj(&[
                        ("makespan", Json::Num(make_s as f64)),
                        ("idle_rounds", Json::Num(stalls_s as f64)),
                        ("wall_ms", Json::Num(wall_s)),
                    ]),
                ),
                (
                    "steal",
                    jobj(&[
                        ("makespan", Json::Num(make_t as f64)),
                        ("idle_rounds", Json::Num(stalls_t as f64)),
                        ("wall_ms", Json::Num(wall_t)),
                        ("stolen_rows", Json::Num(stolen as f64)),
                        (
                            "grants",
                            Json::Num(tm_steal.steal_grants.iter().sum::<u64>() as f64),
                        ),
                    ]),
                ),
            ]),
        ),
        (
            "term_race",
            jobj(&[
                (
                    "quiet",
                    jobj(&[
                        ("stop", Json::Str(tm_q.stop_cause.name().to_string())),
                        ("wall_ms", Json::Num(wall_q)),
                        ("pushes", Json::Num(tm_q.shard_pushes.iter().sum::<u64>() as f64)),
                        ("residual", Json::Num(tm_q.residual)),
                        ("converged", Json::Bool(tm_q.converged)),
                    ]),
                ),
                (
                    "protocol",
                    jobj(&[
                        ("stop", Json::Str(tm_p.stop_cause.name().to_string())),
                        ("wall_ms", Json::Num(wall_p)),
                        ("pushes", Json::Num(tm_p.shard_pushes.iter().sum::<u64>() as f64)),
                        ("residual", Json::Num(tm_p.residual)),
                        ("converged", Json::Bool(tm_p.converged)),
                        ("converge_msgs", Json::Num(tm_p.term_converge as f64)),
                        ("diverge_msgs", Json::Num(tm_p.term_diverge as f64)),
                    ]),
                ),
            ]),
        ),
    ]))?;
    Ok(())
}
