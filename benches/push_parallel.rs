//! Bench: shard-count scaling of the parallel residual-push engine.
//!
//! Cold-solves one generated power-law web (~200k edges at full scale)
//! with `ShardedPush` + `run_threaded_push` at shard counts 1/2/4/8 and
//! reports wall time, total pushes (staleness inflates the count as
//! shards grow — the price of asynchrony the paper trades for wall
//! time), fragments exchanged, and speedup over the single-shard run.
//! A correctness postlude checks every shard count lands on the same
//! ranks as the f64 power method.
//!
//! The speedup ceiling is min(shards, host cores); on the paper's
//! premise the interesting number is that it is > 1 at all — no
//! synchronization phase, residual fragments only, and the solver
//! still accelerates.

use asyncpr::asynciter::{run_threaded_push, PushThreadOptions};
use asyncpr::metrics::{parallel_push_markdown, ShardScaleRow};
use asyncpr::stream::{power_method_f64, DeltaGraph, ShardedPush};
use asyncpr::util::Bench;

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("BENCH_FAST").ok().as_deref() == Some("1");
    // WebParams::scaled keeps Stanford-Web's ~8.2 edges/node, so
    // scaled:25000 carries ~205k edges
    let graph = if quick { "scaled:8000" } else { "scaled:25000" };
    let tol = 1e-9;
    println!("== bench push_parallel (graph = {graph}, tol = {tol:.0e}) ==\n");

    let el = asyncpr::coordinator::load_edgelist(graph, 42)?;
    let g = DeltaGraph::from_edgelist(&el);
    println!(
        "n = {}, m = {}, host parallelism = {}\n",
        g.n(),
        g.m(),
        std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1)
    );

    let bench = if quick { Bench::new(1, 3) } else { Bench::new(1, 5) };
    let opts = PushThreadOptions { tol, ..Default::default() };

    let mut rows: Vec<ShardScaleRow> = Vec::new();
    let mut base_wall = 0.0f64;
    for shards in [1usize, 2, 4, 8] {
        let mut pushes = 0u64;
        let mut fragments = 0u64;
        let mut residual = 0.0f64;
        let stats = bench.run(&format!("cold solve, {shards} shard(s)"), || {
            let mut sp = ShardedPush::new(&g, 0.85, shards);
            let tm = run_threaded_push(&g, &mut sp, &opts);
            pushes = tm.shard_pushes.iter().sum();
            fragments = tm.fragments_sent.iter().sum();
            residual = tm.residual;
        });
        let wall_ms = stats.mean.as_secs_f64() * 1e3;
        if shards == 1 {
            base_wall = wall_ms;
        }
        println!("{}", stats.report());
        rows.push(ShardScaleRow {
            shards,
            wall_ms,
            pushes,
            fragments,
            speedup: if wall_ms > 0.0 { base_wall / wall_ms } else { 0.0 },
            residual,
        });
    }
    println!("\n{}", parallel_push_markdown(&rows));

    // correctness postlude: every shard count lands on the reference
    let (xref, _) = power_method_f64(&g, 0.85, 1e-10, 10_000);
    for shards in [1usize, 4] {
        let mut sp = ShardedPush::new(&g, 0.85, shards);
        let tm = run_threaded_push(&g, &mut sp, &opts);
        let x = sp.ranks();
        let l1: f64 = x.iter().zip(&xref).map(|(a, b)| (a - b).abs()).sum();
        println!(
            "{shards} shard(s): residual {:.1e} (converged: {}), L1 vs power {l1:.1e}",
            tm.residual, tm.converged
        );
    }
    let at4 = rows.iter().find(|r| r.shards == 4).map(|r| r.speedup).unwrap_or(0.0);
    println!(
        "\n4-shard speedup over 1 shard: {at4:.2}x (ceiling: min(4, {} cores))",
        std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1)
    );
    Ok(())
}
