//! Report generation (the paper's "automatic report generation" option,
//! §5.1): collect run results into a JSON document + markdown summary.

use std::collections::BTreeMap;
use std::path::Path;

use crate::asynciter::RunMetrics;
use crate::util::Json;
use crate::Result;

/// Accumulates experiment outputs and writes them out.
#[derive(Default)]
pub struct Report {
    sections: Vec<(String, String)>, // (title, markdown body)
    json: BTreeMap<String, Json>,
}

impl Report {
    pub fn new() -> Report {
        Report::default()
    }

    pub fn add_section(&mut self, title: &str, markdown: &str) {
        self.sections.push((title.to_string(), markdown.to_string()));
    }

    pub fn add_json(&mut self, key: &str, value: Json) {
        self.json.insert(key.to_string(), value);
    }

    pub fn add_run(&mut self, key: &str, m: &RunMetrics) {
        let mut o = BTreeMap::new();
        o.insert("mode".into(), Json::Str(format!("{:?}", m.mode)));
        o.insert("p".into(), Json::Num(m.p as f64));
        o.insert(
            "iters".into(),
            Json::Arr(m.iters.iter().map(|&i| Json::Num(i as f64)).collect()),
        );
        o.insert(
            "finish_times".into(),
            Json::Arr(m.finish_times.iter().map(|&t| Json::Num(t)).collect()),
        );
        o.insert("total_time".into(), Json::Num(m.total_time));
        o.insert(
            "global_residual".into(),
            Json::Num(m.final_global_residual as f64),
        );
        o.insert(
            "imports".into(),
            Json::Arr(
                m.imports
                    .iter()
                    .map(|row| Json::Arr(row.iter().map(|&v| Json::Num(v as f64)).collect()))
                    .collect(),
            ),
        );
        o.insert("wire_sent".into(), Json::Num(m.wire_sent as f64));
        o.insert("wire_cancelled".into(), Json::Num(m.wire_cancelled as f64));
        self.add_json(key, Json::Obj(o));
    }

    pub fn to_markdown(&self) -> String {
        let mut out = String::from("# asyncpr experiment report\n\n");
        for (title, body) in &self.sections {
            out.push_str(&format!("## {title}\n\n{body}\n\n"));
        }
        out
    }

    pub fn to_json(&self) -> String {
        Json::Obj(self.json.clone()).to_string_compact()
    }

    /// Write `<stem>.md` and `<stem>.json`.
    pub fn write(&self, stem: impl AsRef<Path>) -> Result<()> {
        let stem = stem.as_ref();
        let md = stem.with_extension("md");
        let js = stem.with_extension("json");
        std::fs::write(&md, self.to_markdown())?;
        std::fs::write(&js, self.to_json())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_roundtrip() {
        let mut r = Report::new();
        r.add_section("Table 1", "| a |\n|---|\n| 1 |");
        r.add_json("x", Json::Num(1.0));
        let md = r.to_markdown();
        assert!(md.contains("## Table 1"));
        let parsed = Json::parse(&r.to_json()).unwrap();
        assert_eq!(parsed.get("x").unwrap().as_f64(), Some(1.0));
    }

    #[test]
    fn write_creates_files() {
        let dir = std::env::temp_dir().join(format!("asyncpr_report_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut r = Report::new();
        r.add_section("s", "body");
        r.write(dir.join("out")).unwrap();
        assert!(dir.join("out.md").exists());
        assert!(dir.join("out.json").exists());
        std::fs::remove_dir_all(&dir).ok();
    }
}
