//! Multi-run experiment drivers: one function per DESIGN.md §5 entry.
//!
//! Benches and the CLI call these; each returns structured results the
//! caller renders (markdown for the terminal, JSON for reports).

use std::sync::Arc;

use crate::asynciter::{
    run_threaded_push, run_threaded_push_certified, Mode, PushThreadOptions, RunMetrics,
    RunSpec, SimEngine, StallInjection, StopCause, TermMode,
};
use crate::config::RunConfig;
use crate::graph::generators::{churn_batch, ChurnParams};
use crate::metrics::{StreamEpochRow, Table1Row, TopKEpochStats};
use crate::net::{run_socket_push, FaultPlan, NetConfig, SocketRunOptions};
use crate::pagerank::PagerankProblem;
use crate::simnet::{ClusterProfile, Topology};
use crate::stream::{
    power_method_f64, power_method_pers, solve_certified_sharded, solve_certified_state,
    DeltaGraph, OutboxPolicy, Personalization, PushState, ServeOptions, ServeTier, ShardedPush,
    TopKCertificate, TopKGoal, TopKTracker,
};
use crate::termination::GlobalOracle;
use crate::util::Rng;
use crate::Result;

use super::{build_ops, load_edgelist, load_graph, partition_for, profile_for};

/// Shared context for an experiment series: one graph, one problem.
pub struct ExperimentCtx {
    pub problem: Arc<PagerankProblem>,
    pub base: RunConfig,
    pub engine: Option<crate::runtime::Engine>,
}

impl ExperimentCtx {
    pub fn new(base: RunConfig) -> Result<Self> {
        let csr = load_graph(&base.graph, base.seed)?;
        let problem = Arc::new(PagerankProblem::new(csr, base.alpha));
        let engine = if base.use_artifact {
            Some(crate::runtime::Engine::new(crate::runtime::default_artifacts_dir())?)
        } else {
            None
        };
        Ok(ExperimentCtx { problem, base, engine })
    }

    /// Run one (mode, procs) cell against the shared problem.
    pub fn run_cell(&self, procs: usize, mode: Mode, cfg_mut: impl Fn(&mut RunConfig)) -> Result<RunMetrics> {
        let mut cfg = self.base.clone();
        cfg.procs = procs;
        cfg.mode = mode;
        cfg_mut(&mut cfg);
        cfg.validate()?;
        let partitioner = partition_for(&self.problem, &cfg)?;
        let mut ops = build_ops(&self.problem, &partitioner, &cfg, self.engine.as_ref())?;
        let profile = profile_for(&cfg);
        let spec = RunSpec {
            mode: cfg.mode,
            stop: cfg.stop_rule(),
            adaptive: cfg.adaptive,
            seed: cfg.seed,
            max_total_iters: 2_000_000,
        };
        let sim = SimEngine::new(&profile, &self.problem);
        Ok(sim.run(&mut ops, &spec))
    }
}

/// T1: Table 1 — sync vs async for the given machine counts.
pub fn table1(ctx: &ExperimentCtx, procs: &[usize]) -> Result<Vec<(Table1Row, RunMetrics, RunMetrics)>> {
    let mut out = Vec::new();
    for &p in procs {
        let sync = ctx.run_cell(p, Mode::Synchronous, |_| {})?;
        let asyn = ctx.run_cell(p, Mode::Asynchronous, |_| {})?;
        out.push((Table1Row::from_runs(&sync, &asyn), sync, asyn));
    }
    Ok(out)
}

/// T2: Table 2 — async imports matrix at p UEs (paper: 4).
pub fn table2(ctx: &ExperimentCtx, procs: usize) -> Result<RunMetrics> {
    ctx.run_cell(procs, Mode::Asynchronous, |_| {})
}

/// G1 result: what global residual does the local threshold actually buy?
#[derive(Debug, Clone)]
pub struct GlobalThresholdResult {
    /// The local stopping threshold, widened to f64 so comparisons
    /// against the f64 achieved residual below never re-narrow it.
    pub local_tol: f64,
    /// True ‖Gx−x‖₁ when the Figure-1 protocol stopped the async run —
    /// the oracle's f64 tally: at n ≳ 10⁶ an f32 sum's rounding error
    /// is the same order as the thresholds this experiment certifies.
    pub achieved_global_residual: f64,
    /// Kendall-τ of the stopped vector's ranking vs a tight reference.
    pub ranking_tau: f64,
    pub top100_overlap: f64,
    /// G2: times to reach a common global threshold.
    pub sync_time_global: f64,
    pub async_time_global: f64,
    pub speedup_global: f64,
}

/// G1+G2: run the async protocol at `local_tol`, measure the achieved
/// global residual; then race both modes to that same global threshold.
pub fn global_threshold(ctx: &ExperimentCtx, procs: usize, local_tol: f32) -> Result<GlobalThresholdResult> {
    let asyn = ctx.run_cell(procs, Mode::Asynchronous, |c| c.tol = local_tol)?;

    // Re-measure the achieved residual through the oracle's f64 tally
    // rather than trusting the engine's f32 metric: the two agree to
    // f32 precision, but the f64 value is the one G2's threshold race
    // (and the report) should carry.
    let mut oracle = GlobalOracle::new(&ctx.problem, (local_tol * 1e-3).max(1e-9));
    let achieved = oracle.global_residual(&asyn.x);
    let tau = oracle.ranking_tau(&asyn.x);
    let top100 = oracle.top_k(&asyn.x, 100);

    // G2: race to the common global threshold
    let g_tol = (achieved as f32).max(local_tol);
    let sync_g = ctx.run_cell(procs, Mode::Synchronous, |c| {
        c.global_threshold = true;
        c.tol = g_tol;
    })?;
    let async_g = ctx.run_cell(procs, Mode::Asynchronous, |c| {
        c.global_threshold = true;
        c.tol = g_tol;
    })?;
    Ok(GlobalThresholdResult {
        local_tol: local_tol as f64,
        achieved_global_residual: achieved,
        ranking_tau: tau,
        top100_overlap: top100,
        sync_time_global: sync_g.total_time,
        async_time_global: async_g.total_time,
        speedup_global: sync_g.total_time / async_g.total_time,
    })
}

/// A1: cancellation-window sweep (async, fixed p).
pub fn ablation_cancel_window(
    ctx: &ExperimentCtx,
    procs: usize,
    windows: &[Option<f64>],
) -> Result<Vec<(Option<f64>, RunMetrics)>> {
    windows
        .iter()
        .map(|&w| Ok((w, ctx.run_cell(procs, Mode::Asynchronous, |c| c.cancel_window = w)?)))
        .collect()
}

/// A2: adaptive per-peer rates on a cluster with one slow node.
pub fn ablation_adaptive(
    ctx: &ExperimentCtx,
    procs: usize,
    slow_factor: f64,
) -> Result<(RunMetrics, RunMetrics)> {
    // NOTE: the slow node enters through a modified profile, so this
    // bypasses run_cell's profile_for and builds the sim directly.
    let run = |adaptive: bool| -> Result<RunMetrics> {
        let mut cfg = ctx.base.clone();
        cfg.procs = procs;
        cfg.mode = Mode::Asynchronous;
        cfg.adaptive = adaptive;
        let partitioner = partition_for(&ctx.problem, &cfg)?;
        let mut ops = build_ops(&ctx.problem, &partitioner, &cfg, ctx.engine.as_ref())?;
        let profile = profile_for(&cfg).with_slow_node(procs - 1, slow_factor);
        let spec = RunSpec {
            mode: cfg.mode,
            stop: cfg.stop_rule(),
            adaptive,
            seed: cfg.seed,
            max_total_iters: 2_000_000,
        };
        Ok(SimEngine::new(&profile, &ctx.problem).run(&mut ops, &spec))
    };
    Ok((run(false)?, run(true)?))
}

/// A3: topology sweep (async only; sync requires clique).
pub fn ablation_topology(
    ctx: &ExperimentCtx,
    procs: usize,
    topologies: &[Topology],
) -> Result<Vec<(Topology, RunMetrics)>> {
    topologies
        .iter()
        .map(|&t| Ok((t, ctx.run_cell(procs, Mode::Asynchronous, |c| c.topology = t)?)))
        .collect()
}

/// Which process-boundary transport the stream's threaded drains ride
/// (`--net`): `None` keeps the mpsc channels.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NetBackend {
    /// Serialize every exchange through the wire codec and an
    /// in-process [`crate::net::LoopbackNet`] throttled by a
    /// [`ClusterProfile`] — same worker loop, real frames, injectable
    /// faults, one OS process.
    Loopback,
    /// One OS process per shard over real sockets
    /// ([`run_socket_push`]). Restricted: no steal / top-k / resident /
    /// PPR / trace, protocol termination only.
    Socket,
}

/// Bandwidth/latency curves for the loopback fabric (`--net-profile`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NetProfileKind {
    /// Near-zero latency, fat links — the fast default for tests.
    Test,
    /// The paper's heterogeneous Beowulf-cluster curves.
    Beowulf,
}

/// Options for the evolving-graph epoch experiment.
#[derive(Debug, Clone)]
pub struct StreamOptions {
    /// Update epochs after the initial build (the report has
    /// `epochs + 1` rows; row 0 is the cold build).
    pub epochs: usize,
    pub alpha: f64,
    /// Residual tolerance `‖r‖₁ + |rd|` for both solves. The rank error
    /// is bounded by `tol/(1-α)`, so the default 1e-10 pins epoch ranks
    /// to the fresh power-method reference well below 1e-8 L1.
    pub tol: f64,
    pub seed: u64,
    /// Churn shape; `None` scales to the graph
    /// ([`ChurnParams::scaled_to`]).
    pub churn: Option<ChurnParams>,
    /// Individual overrides applied on top of the resolved churn params
    /// (lets the CLI tweak one knob without materializing the graph
    /// just to size the others).
    pub arrivals: Option<usize>,
    pub links_per_arrival: Option<usize>,
    pub churn_inserts: Option<usize>,
    pub churn_removes: Option<usize>,
    /// Per-solve push budget (safety cap).
    pub max_pushes: u64,
    /// Worker threads (= shards) for the incremental solve. `1` keeps
    /// the single-queue deterministic solver; `> 1` scatters the warm
    /// state into a balanced-nnz [`ShardedPush`] drained by
    /// [`run_threaded_push`] on real OS threads, then gathers and — if
    /// the monitor cut early — finishes sequentially, so the reported
    /// ranks meet `tol` either way.
    ///
    /// [`ShardedPush`]: crate::stream::ShardedPush
    /// [`run_threaded_push`]: crate::asynciter::threads::run_threaded_push
    pub threads: usize,
    /// Keep ONE [`ShardedPush`] alive across every epoch (the
    /// epoch-resident path): churn batches inject directly into the
    /// live shards via [`ShardedPush::apply_batch`] — no per-epoch
    /// scatter/gather round-trip through a global [`PushState`] — and
    /// the CSR snapshot for the static stack is maintained by
    /// [`DeltaGraph::merge_csr`] splices instead of full rebuilds.
    ///
    /// [`ShardedPush`]: crate::stream::ShardedPush
    /// [`ShardedPush::apply_batch`]: crate::stream::ShardedPush::apply_batch
    pub resident: bool,
    /// Resident path only: re-balance the shard bounds between epochs
    /// when churn skews the per-shard out-nnz beyond this factor of the
    /// ideal share ([`ShardedPush::rebalance`]).
    ///
    /// [`ShardedPush::rebalance`]: crate::stream::ShardedPush::rebalance
    pub rebalance_factor: Option<f64>,
    /// Intra-epoch work stealing on the threaded drains (`--steal`,
    /// needs `threads >= 2`): an idle worker adopts the hottest rows of
    /// the most-loaded peer mid-solve; the report gains per-epoch
    /// `stolen` / `grants` columns. Complements the between-epoch
    /// re-balancer: `--rebalance-factor` fixes durable nnz skew at the
    /// epoch boundary, `--steal` fixes transient residual skew inside
    /// the epoch's drain.
    pub steal: bool,
    /// Rows per steal grant (`--steal-batch B`, default 64).
    pub steal_batch: usize,
    /// Serving path: track and certify the top-k head of the ranking
    /// each epoch ([`TopKTracker`]); the report gains head-churn and
    /// pushes-to-certification columns.
    pub topk: Option<usize>,
    /// Require the *order* within the head to certify too, not just
    /// the set.
    pub topk_order: bool,
    /// How the threaded drains decide to stop (`--term`): the §4.2
    /// persistence-counter protocol (default) or the legacy
    /// quiet-window heuristic, kept so the two can be raced.
    pub term: TermMode,
    /// Worker-side persistence counter threshold (`--pc-max`,
    /// protocol mode only).
    pub pc_max: u32,
    /// Fault injection (`--inject-stall W:MS[:R]`): worker `W` sleeps
    /// `MS` milliseconds once it reaches round `R` of each threaded
    /// drain — the scenario that exposes the quiet-window's premature
    /// stop and that the protocol must survive.
    pub inject_stall: Option<StallInjection>,
    /// `stop_when_topk_certified`: end each epoch's solve as soon as
    /// the head certifies instead of running to `tol` — the serving
    /// early-exit. Epochs whose head cannot certify (ties at the
    /// boundary) still run to full convergence.
    pub topk_stop: bool,
    /// Personalized PageRank (`--ppr SRC[,SRC..]`): replace the global
    /// `e/n` teleport with `v` uniform over these source nodes,
    /// dangling mass following `v` (the standard PPR surfer). Every
    /// backend on the epoch loop — sequential, sharded, threaded — and
    /// the from-scratch baseline plus the power reference switch to the
    /// personalized fixed point, so all the cross-checks (L1 vs. power,
    /// mass conservation, top-k certification audit) hold verbatim.
    pub ppr: Option<Vec<u32>>,
    /// Progress-telemetry collector (`--trace`): attached to the
    /// sharded solver and passed to the threaded drains, so per-shard
    /// events and the residual-decay series accumulate across every
    /// epoch. `None` (the default) keeps the solvers untraced — the
    /// recording sites are all behind `Option` checks, so the disabled
    /// path costs nothing.
    pub trace: Option<Arc<crate::obs::TraceCollector>>,
    /// Route the threaded drains over a process-boundary transport
    /// (`--net loopback|socket`); needs `threads >= 2`.
    pub net: Option<NetBackend>,
    /// Loopback throttling curves (`--net-profile`, default test).
    pub net_profile: NetProfileKind,
    /// Loopback fault injection (`--inject-link L:MS[:JITTER]`): every
    /// frame out of endpoint `L` takes an extra `MS` milliseconds plus
    /// uniform jitter in `[0, JITTER)` ms — the wire analogue of
    /// `--inject-stall`, and the scenario the quiet-window heuristic
    /// mis-calls while the §4.2 protocol waits out the in-flight mass.
    pub inject_link: Option<(usize, f64, f64)>,
    /// Per-peer outbox representation for the sharded solvers
    /// (`--outbox auto|dense|sparse`). `Auto` (the default) keeps the
    /// O(span) dense accumulators while `shards <=`
    /// [`SPARSE_OUTBOX_SHARDS`] and switches every shard to sparse
    /// maps above it, capping outbox memory at O(touched) instead of
    /// O(n) per shard.
    ///
    /// [`SPARSE_OUTBOX_SHARDS`]: crate::stream::SPARSE_OUTBOX_SHARDS
    pub outbox: OutboxPolicy,
}

impl Default for StreamOptions {
    fn default() -> Self {
        StreamOptions {
            epochs: 10,
            alpha: 0.85,
            tol: 1e-10,
            seed: 42,
            churn: None,
            arrivals: None,
            links_per_arrival: None,
            churn_inserts: None,
            churn_removes: None,
            max_pushes: u64::MAX,
            threads: 1,
            resident: false,
            rebalance_factor: None,
            steal: false,
            steal_batch: 64,
            topk: None,
            topk_order: false,
            topk_stop: false,
            ppr: None,
            term: TermMode::Protocol,
            pc_max: 3,
            inject_stall: None,
            trace: None,
            net: None,
            net_profile: NetProfileKind::Test,
            inject_link: None,
            outbox: OutboxPolicy::default(),
        }
    }
}

impl StreamOptions {
    /// L1 agreement threshold vs. the power reference that `tol`
    /// actually guarantees: both solvers' error bounds `tol/(1-α)`,
    /// doubled for slack, floored at the repo's 1e-8 acceptance bar.
    pub fn l1_check_threshold(&self) -> f64 {
        (2.0 * self.tol / (1.0 - self.alpha)).max(1e-8)
    }
}

/// Result of [`stream_epochs`].
#[derive(Debug, Clone)]
pub struct StreamReport {
    pub rows: Vec<StreamEpochRow>,
    /// Totals over the UPDATE epochs (row 0's cold build excluded —
    /// both solvers start cold there by construction).
    pub update_inc_pushes: u64,
    pub update_scratch_pushes: u64,
    /// Did every update epoch's warm start beat from-scratch?
    pub all_updates_cheaper: bool,
    /// Final-epoch L1 distance to the fresh power-method reference.
    pub final_l1_vs_power: f64,
}

/// From-scratch push baseline + fresh power-method check on the current
/// snapshot — the per-epoch yardstick shared by the roundtrip and
/// resident drivers. Returns `(scratch_pushes, L1 of ranks vs power,
/// the power reference itself)` — the reference doubles as the top-k
/// audit oracle.
fn epoch_baseline(
    g: &DeltaGraph,
    alpha: f64,
    tol: f64,
    power_tol: f64,
    max_pushes: u64,
    epoch: usize,
    ranks: &[f64],
    pers: Option<&Arc<Personalization>>,
) -> Result<(u64, f64, Vec<f64>)> {
    let mut cold = match pers {
        Some(p) => PushState::new_personalized(g.n(), alpha, Arc::clone(p)),
        None => PushState::new(g.n(), alpha),
    };
    cold.begin_epoch();
    let cold_stats = cold.solve(g, tol, max_pushes);
    anyhow::ensure!(cold_stats.converged, "epoch {epoch}: baseline hit the push budget");
    let (xref, _) = match pers {
        Some(p) => power_method_pers(g, alpha, p, power_tol, 100_000),
        None => power_method_f64(g, alpha, power_tol, 100_000),
    };
    let l1: f64 = ranks.iter().zip(&xref).map(|(a, b)| (a - b).abs()).sum();
    Ok((cold_stats.pushes, l1, xref))
}

/// The threaded [`PushThreadOptions`] a [`StreamOptions`] implies
/// (tolerance, budget, and the steal knobs — the rebalance entry hook
/// is driven separately by the resident loop).
fn thread_opts(opts: &StreamOptions, max_pushes: u64) -> PushThreadOptions {
    // loopback is the only backend the worker loop drives in-process;
    // socket mode routes around run_threaded_push entirely
    let net = (opts.net == Some(NetBackend::Loopback)).then(|| {
        let endpoints = opts.threads + 1; // workers + monitor
        NetConfig {
            profile: match opts.net_profile {
                NetProfileKind::Beowulf => ClusterProfile::paper_beowulf(endpoints),
                NetProfileKind::Test => ClusterProfile::test_profile(endpoints),
            },
            faults: opts
                .inject_link
                .map(|(l, ms, j)| FaultPlan::delay_from(l, ms, j))
                .unwrap_or_default(),
            seed: opts.seed,
        }
    });
    PushThreadOptions {
        tol: opts.tol,
        max_pushes,
        steal: opts.steal,
        steal_batch: opts.steal_batch,
        term: opts.term,
        pc_max: opts.pc_max,
        inject_stall: opts.inject_stall,
        trace: opts.trace.clone(),
        net,
        ..Default::default()
    }
}

/// Per-epoch termination bookkeeping folded into the stream rows: the
/// stop cause of the last threaded drain plus the epoch's protocol
/// message totals (zero on sequential or quiet-mode epochs).
#[derive(Debug, Clone, Copy, Default)]
struct EpochTerm {
    cause: Option<StopCause>,
    converge: u64,
    diverge: u64,
}

impl EpochTerm {
    /// Fold one threaded run's verdict in: counts accumulate, the
    /// latest run's cause wins (it is what actually ended the epoch).
    fn fold(&mut self, cause: Option<StopCause>, converge: u64, diverge: u64) {
        if cause.is_some() {
            self.cause = cause;
        }
        self.converge += converge;
        self.diverge += diverge;
    }
}

/// Resident path: drain the live shards to `tol` on real threads, with
/// the deterministic sequential finish when the monitor cuts early
/// (timeout / quiet race) — the budget is whatever the epoch has left
/// of `max_pushes` after the `p0` baseline. Returns
/// `(residual, converged, termination bookkeeping)`.
fn finish_threaded_resident(
    g: &DeltaGraph,
    sharded: &mut ShardedPush,
    opts: &StreamOptions,
    p0: u64,
) -> (f64, bool, EpochTerm) {
    let used = sharded.total_pushes() - p0;
    let topts = thread_opts(opts, opts.max_pushes.saturating_sub(used));
    let tm = run_threaded_push(g, sharded, &topts);
    let mut term = EpochTerm::default();
    term.fold(Some(tm.stop_cause), tm.term_converge, tm.term_diverge);
    if tm.converged {
        (tm.residual, true, term)
    } else {
        let used = sharded.total_pushes() - p0;
        let st = sharded.solve(g, opts.tol, opts.max_pushes.saturating_sub(used));
        (st.residual, st.converged, term)
    }
}

/// Fold one epoch's certificate into the serving-path columns: head
/// churn vs. the previous epoch, audit overlap vs. the power
/// reference, and — when the epoch *certified* with a margin the
/// reference can resolve — a hard check that the certified set is
/// exactly the reference's top-k.
fn topk_epoch_stats(
    cert: &TopKCertificate,
    goal: TopKGoal,
    pushes_to_cert: Option<u64>,
    prev_head: &mut Vec<u32>,
    epoch: usize,
    xref: &[f64],
    power_tol: f64,
    alpha: f64,
) -> Result<TopKEpochStats> {
    use std::collections::HashSet;
    let head: HashSet<u32> = cert.head.iter().copied().collect();
    let (entries, exits) = if epoch == 0 {
        (0, 0)
    } else {
        let prev: HashSet<u32> = prev_head.iter().copied().collect();
        (head.difference(&prev).count(), prev.difference(&head).count())
    };
    let k_eff = goal.k.min(xref.len());
    let overlap = if k_eff == 0 {
        1.0
    } else {
        let ref_top: HashSet<u32> =
            crate::pagerank::top_k_ids(xref, k_eff).into_iter().collect();
        head.intersection(&ref_top).count() as f64 / k_eff as f64
    };
    // the reference itself carries error <= power_tol/(1-alpha) per
    // node; only when the certificate's margin clears twice that can a
    // disagreement be blamed on the certifier
    if cert.set_certified && cert.margin() > 2.0 * power_tol / (1.0 - alpha) {
        anyhow::ensure!(
            overlap == 1.0,
            "epoch {epoch}: certified top-{} disagrees with the power reference \
             (overlap {overlap}, margin {:.2e})",
            goal.k,
            cert.margin()
        );
    }
    *prev_head = cert.head.clone();
    Ok(TopKEpochStats {
        k: goal.k,
        certified: cert.set_certified,
        order_certified: cert.order_certified,
        pushes_to_cert,
        entries,
        exits,
        overlap_vs_power: overlap,
    })
}

/// S1: the evolving-graph experiment. One initial build plus
/// `opts.epochs` churn epochs; each epoch solves incrementally
/// (warm-started push) AND from scratch on the identical snapshot, and
/// checks both against a fresh f64 power-method run. This is the
/// measurable form of the subsystem's claim: recompute cost ∝ change
/// size, not graph size.
///
/// Two incremental drivers share the loop: the default **roundtrip**
/// path (global [`PushState`] per epoch, scattered into a
/// [`ShardedPush`] when `threads > 1` and gathered back), and the
/// **resident** path (`opts.resident`) where one `ShardedPush` lives
/// across all epochs — deltas inject in place, the shard bounds
/// re-balance on demand, and the static-stack CSR snapshot is spliced
/// by [`DeltaGraph::merge_csr`] instead of rebuilt.
pub fn stream_epochs(graph_spec: &str, opts: &StreamOptions) -> Result<StreamReport> {
    anyhow::ensure!(
        (0.0..1.0).contains(&opts.alpha),
        "alpha {} out of [0,1)",
        opts.alpha
    );
    anyhow::ensure!(opts.tol > 0.0, "tol must be positive, got {}", opts.tol);
    anyhow::ensure!(
        (1..=64).contains(&opts.threads),
        "threads {} out of [1, 64] (outbox memory scales with shards x n)",
        opts.threads
    );
    if let Some(f) = opts.rebalance_factor {
        anyhow::ensure!(f >= 1.0, "rebalance factor {f} must be >= 1");
        anyhow::ensure!(
            opts.resident,
            "--rebalance-factor only applies to the resident path \
             (the roundtrip path re-partitions every epoch by construction)"
        );
    }
    anyhow::ensure!(
        !opts.steal || opts.threads >= 2,
        "--steal needs --threads N with N >= 2 (a single shard has no peer to rob)"
    );
    anyhow::ensure!(opts.steal_batch >= 1, "--steal-batch must be >= 1");
    anyhow::ensure!(
        opts.net.is_none() || opts.threads >= 2,
        "--net needs --threads N with N >= 2 (one shard has no peer to talk to)"
    );
    if opts.net == Some(NetBackend::Socket) {
        // a process boundary removes the shared registers the richer
        // modes lean on; the socket tier speaks frames or nothing
        anyhow::ensure!(
            !opts.steal && opts.topk.is_none() && !opts.resident && opts.ppr.is_none(),
            "--net socket supports the plain roundtrip drain only \
             (no --steal / --topk / --resident / --ppr)"
        );
        anyhow::ensure!(
            opts.term == TermMode::Protocol,
            "--net socket requires --term protocol (the quiet-window heuristic \
             reads a shared in-flight register that does not cross processes)"
        );
        anyhow::ensure!(
            opts.inject_stall.is_none() && opts.inject_link.is_none(),
            "fault injection is loopback-only (--net loopback)"
        );
        anyhow::ensure!(
            opts.trace.is_none(),
            "--trace does not cross the process boundary (--net loopback instead)"
        );
    }
    if let Some((l, ms, j)) = opts.inject_link {
        anyhow::ensure!(
            opts.net == Some(NetBackend::Loopback),
            "--inject-link needs --net loopback (the fault injector lives in the \
             loopback fabric)"
        );
        anyhow::ensure!(
            l < opts.threads,
            "--inject-link endpoint {l} out of range (workers are 0..{})",
            opts.threads
        );
        anyhow::ensure!(
            ms >= 0.0 && j >= 0.0,
            "--inject-link delay/jitter must be non-negative"
        );
    }
    anyhow::ensure!(opts.pc_max >= 1, "--pc-max must be >= 1 (persistence needs a streak)");
    if let Some(st) = opts.inject_stall {
        anyhow::ensure!(
            opts.threads >= 2 && st.worker < opts.threads,
            "--inject-stall worker {} needs --threads N with N >= 2 and worker < N",
            st.worker
        );
    }
    let topk_goal = opts.topk.map(|k| TopKGoal { k, order: opts.topk_order });
    anyhow::ensure!(
        topk_goal.is_some() || (!opts.topk_order && !opts.topk_stop),
        "--topk-order / --topk-stop need --topk K"
    );
    let mut tracker = topk_goal.map(TopKTracker::new);
    let mut prev_head: Vec<u32> = Vec::new();
    let el = load_edgelist(graph_spec, opts.seed)?;
    let mut g = DeltaGraph::from_edgelist(&el);
    anyhow::ensure!(g.n() > 0, "graph {graph_spec} is empty");
    let pers = match &opts.ppr {
        Some(srcs) => {
            let p = Personalization::sources(srcs)?;
            anyhow::ensure!(
                (p.max_node() as usize) < g.n(),
                "--ppr source {} out of range for n = {}",
                p.max_node(),
                g.n()
            );
            Some(Arc::new(p))
        }
        None => None,
    };
    let mut churn = opts
        .churn
        .clone()
        .unwrap_or_else(|| ChurnParams::scaled_to(g.n(), g.m()));
    if let Some(v) = opts.arrivals {
        churn.arrivals = v;
    }
    if let Some(v) = opts.links_per_arrival {
        churn.links_per_arrival = v;
    }
    if let Some(v) = opts.churn_inserts {
        churn.churn_inserts = v;
    }
    if let Some(v) = opts.churn_removes {
        churn.churn_removes = v;
    }
    let mut rng = Rng::new(opts.seed ^ 0x5354_5245_414d); // "STREAM"
    let power_tol = opts.tol.min(1e-10);

    let mut rows = Vec::with_capacity(opts.epochs + 1);
    if opts.resident {
        // ---- epoch-resident path: ONE ShardedPush lives across every
        // epoch; churn injects in place, the CSR snapshot is spliced ----
        let mut sharded = match &pers {
            Some(p) => {
                ShardedPush::new_personalized(&g, opts.alpha, opts.threads, Arc::clone(p))
            }
            None => ShardedPush::new(&g, opts.alpha, opts.threads),
        };
        sharded.set_outbox_policy(opts.outbox);
        if let Some(tr) = &opts.trace {
            sharded.attach_trace(Arc::clone(tr));
        }
        let mut csr = g.to_csr()?; // the splice chain's baseline
        for epoch in 0..=opts.epochs {
            let (new_nodes, inserted, removed, csr_dirty) = if epoch == 0 {
                sharded.begin_epoch();
                (0, 0, 0, 0)
            } else {
                let batch = churn_batch(&g, &churn, &mut rng);
                let delta = g.apply(&batch)?;
                sharded.begin_epoch();
                sharded.apply_batch(&g, &delta);
                if let Some(f) = opts.rebalance_factor {
                    sharded.rebalance(&g, f);
                }
                let (next, ms) = g.merge_csr(&csr)?;
                csr = next;
                anyhow::ensure!(
                    csr.n() == g.n() && csr.nnz() == g.m(),
                    "epoch {epoch}: spliced CSR inconsistent with the graph"
                );
                (batch.new_nodes, delta.inserted, delta.removed, ms.dirty_rows)
            };
            let p0 = sharded.total_pushes();
            let (steal0_rows, steal0_grants) = sharded.steal_totals();
            let mut term = EpochTerm::default();
            let (residual, converged, epoch_cert) = match tracker.as_mut() {
                Some(tr) if opts.threads == 1 => {
                    let st = solve_certified_sharded(
                        &mut sharded,
                        &g,
                        tr,
                        opts.tol,
                        opts.max_pushes,
                        opts.topk_stop,
                    );
                    (st.residual, st.converged, Some((st.cert, st.pushes_to_cert)))
                }
                Some(tr) => {
                    // threaded serving path: certified phase first (the
                    // tentative-stop/exact-recheck protocol lives in
                    // run_threaded_push_certified), then run to tol
                    // unless stopping at certification
                    let goal = tr.goal();
                    let topts = thread_opts(opts, opts.max_pushes);
                    let out = run_threaded_push_certified(&g, &mut sharded, tr, &topts);
                    term.fold(out.last_stop, out.term_converge, out.term_diverge);
                    let mut cert = out.cert;
                    let mut pushes_to_cert = out.pushes_to_cert;
                    let mut residual = out.residual;
                    let mut converged = out.converged;
                    if !converged && !(opts.topk_stop && pushes_to_cert.is_some()) {
                        // finish to tol back on the threads (tracking no
                        // longer needs to interrupt the run), with the
                        // usual deterministic fallback
                        let (r, c, t) = finish_threaded_resident(&g, &mut sharded, opts, p0);
                        residual = r;
                        converged = c;
                        term.fold(t.cause, t.converge, t.diverge);
                        if pushes_to_cert.is_none() {
                            cert = tr.check_sharded(&mut sharded);
                            if cert.certified(goal.order) {
                                pushes_to_cert = Some(sharded.total_pushes() - p0);
                            }
                        }
                    }
                    (residual, converged, Some((cert, pushes_to_cert)))
                }
                None if opts.threads > 1 => {
                    let (r, c, t) = finish_threaded_resident(&g, &mut sharded, opts, p0);
                    term = t;
                    (r, c, None)
                }
                None => {
                    let st = sharded.solve(&g, opts.tol, opts.max_pushes);
                    (st.residual, st.converged, None)
                }
            };
            let cert_early_exit = opts.topk_stop
                && epoch_cert.as_ref().map_or(false, |(_, at)| at.is_some());
            anyhow::ensure!(
                converged || cert_early_exit,
                "epoch {epoch}: resident solve hit the push budget at residual {residual:.2e}"
            );
            let mass = sharded.mass();
            let target = sharded.target_mass();
            anyhow::ensure!(
                (mass - target).abs() < 1e-8,
                "epoch {epoch}: conserved mass drifted to {mass} (target {target})"
            );
            let ranks = sharded.ranks();
            let (scratch_pushes, l1, xref) = epoch_baseline(
                &g,
                opts.alpha,
                opts.tol,
                power_tol,
                opts.max_pushes,
                epoch,
                &ranks,
                pers.as_ref(),
            )?;
            let topk = match (&epoch_cert, topk_goal) {
                (Some((cert, at)), Some(goal)) => Some(topk_epoch_stats(
                    cert,
                    goal,
                    *at,
                    &mut prev_head,
                    epoch,
                    &xref,
                    power_tol,
                    opts.alpha,
                )?),
                _ => None,
            };
            let (steal1_rows, steal1_grants) = sharded.steal_totals();
            rows.push(StreamEpochRow {
                epoch,
                n: g.n(),
                m: g.m(),
                new_nodes,
                inserted,
                removed,
                inc_pushes: sharded.total_pushes() - p0,
                inc_touched: sharded.touched(),
                inc_residual: residual,
                scratch_pushes,
                l1_vs_power: l1,
                csr_dirty_rows: csr_dirty,
                stolen_rows: steal1_rows - steal0_rows,
                steal_grants: steal1_grants - steal0_grants,
                stop_cause: term.cause,
                term_converge: term.converge,
                term_diverge: term.diverge,
                topk,
            });
        }
    } else {
        let mut inc = match &pers {
            Some(p) => PushState::new_personalized(g.n(), opts.alpha, Arc::clone(p)),
            None => PushState::new(g.n(), opts.alpha),
        };
        for epoch in 0..=opts.epochs {
            let (new_nodes, inserted, removed) = if epoch == 0 {
                inc.begin_epoch();
                (0, 0, 0)
            } else {
                let batch = churn_batch(&g, &churn, &mut rng);
                let delta = g.apply(&batch)?;
                inc.begin_epoch();
                inc.apply_batch(&g, &delta);
                (batch.new_nodes, delta.inserted, delta.removed)
            };
            // the parallel path pays an O(n) scatter/gather per epoch, so
            // it only engages when the injected residual is big enough to
            // need real drain work; a near-converged epoch (tiny churn)
            // solves sequentially in a handful of pushes either way
            let parallel_worthwhile = inc.residual_l1() > 1e3 * opts.tol;
            let mut parallel_pushes = 0u64;
            let mut epoch_stolen = 0u64;
            let mut epoch_grants = 0u64;
            let mut term = EpochTerm::default();
            if opts.threads > 1 && parallel_worthwhile {
                // scatter → parallel drain on real threads → gather; any
                // residual the monitor left behind is polished sequentially
                // so the epoch meets `tol` (or certifies) regardless of
                // scheduling. The monitor only gets the top-k goal in
                // early-stop mode: cutting the threaded drain at a
                // tentative certificate is the point there, but in
                // tracking-only mode it would dump the rest of the
                // epoch's convergence onto the sequential polish.
                let mut sharded = ShardedPush::from_state(&inc, &g, opts.threads);
                sharded.set_outbox_policy(opts.outbox);
                if let Some(tr) = &opts.trace {
                    sharded.attach_trace(Arc::clone(tr));
                }
                if opts.net == Some(NetBackend::Socket) {
                    // real process boundary: write the snapshot so every
                    // child materializes the identical graph, seed the
                    // children with the warm shard states, drain to a
                    // protocol STOP, land the results back here
                    let path = std::env::temp_dir()
                        .join(format!("asyncpr_net_{}_{epoch}.bin", std::process::id()));
                    crate::graph::io::save_edgelist_bin(&g.to_edgelist(), &path)?;
                    let p0 = sharded.total_pushes();
                    let sopts = SocketRunOptions {
                        shards: opts.threads,
                        alpha: opts.alpha,
                        tol: opts.tol,
                        seed: opts.seed,
                        max_pushes: opts.max_pushes,
                        pc_max: opts.pc_max,
                        ..SocketRunOptions::default()
                    };
                    let res = run_socket_push(&mut sharded, &path.to_string_lossy(), &sopts);
                    let _ = std::fs::remove_file(&path);
                    let sm = res?;
                    parallel_pushes = sharded.total_pushes() - p0;
                    let cause =
                        if sm.converged { StopCause::Protocol } else { StopCause::Budget };
                    term.fold(Some(cause), sm.term_converge, sm.term_diverge);
                } else {
                    let topts = PushThreadOptions {
                        topk: if opts.topk_stop { topk_goal } else { None },
                        ..thread_opts(opts, opts.max_pushes)
                    };
                    let tm = run_threaded_push(&g, &mut sharded, &topts);
                    parallel_pushes = tm.shard_pushes.iter().sum();
                    epoch_stolen = tm.stolen_rows.iter().sum();
                    epoch_grants = tm.steal_grants.iter().sum();
                    term.fold(Some(tm.stop_cause), tm.term_converge, tm.term_diverge);
                }
                sharded.gather_into(&mut inc);
            }
            // the sequential phase only gets whatever the parallel phase
            // left of the per-solve budget
            let seq_budget = opts.max_pushes.saturating_sub(parallel_pushes);
            let (inc_pushes, inc_residual, converged, epoch_cert) = match tracker.as_mut() {
                Some(tr) => {
                    // certified sequential phase on the gathered state;
                    // pushes-to-cert counts the parallel phase wholesale
                    // (it ran before the first exact check could fire)
                    let st = solve_certified_state(
                        &mut inc,
                        &g,
                        tr,
                        opts.tol,
                        seq_budget,
                        opts.topk_stop,
                    );
                    let at = st.pushes_to_cert.map(|p| parallel_pushes + p);
                    (parallel_pushes + st.pushes, st.residual, st.converged, Some((st.cert, at)))
                }
                None => {
                    let st = inc.solve(&g, opts.tol, seq_budget);
                    (parallel_pushes + st.pushes, st.residual, st.converged, None)
                }
            };
            let cert_early_exit = opts.topk_stop
                && epoch_cert.as_ref().map_or(false, |(_, at)| at.is_some());
            anyhow::ensure!(
                converged || cert_early_exit,
                "epoch {epoch}: incremental solve hit the push budget at \
                 residual {inc_residual:.2e}"
            );
            let (scratch_pushes, l1, xref) = epoch_baseline(
                &g,
                opts.alpha,
                opts.tol,
                power_tol,
                opts.max_pushes,
                epoch,
                inc.ranks(),
                pers.as_ref(),
            )?;
            let topk = match (&epoch_cert, topk_goal) {
                (Some((cert, at)), Some(goal)) => Some(topk_epoch_stats(
                    cert,
                    goal,
                    *at,
                    &mut prev_head,
                    epoch,
                    &xref,
                    power_tol,
                    opts.alpha,
                )?),
                _ => None,
            };
            rows.push(StreamEpochRow {
                epoch,
                n: g.n(),
                m: g.m(),
                new_nodes,
                inserted,
                removed,
                inc_pushes,
                inc_touched: inc.touched(),
                inc_residual,
                scratch_pushes,
                l1_vs_power: l1,
                csr_dirty_rows: 0,
                stolen_rows: epoch_stolen,
                steal_grants: epoch_grants,
                stop_cause: term.cause,
                term_converge: term.converge,
                term_diverge: term.diverge,
                topk,
            });
        }
    }

    let update_rows = &rows[1..];
    let update_inc_pushes = update_rows.iter().map(|r| r.inc_pushes).sum();
    let update_scratch_pushes = update_rows.iter().map(|r| r.scratch_pushes).sum();
    let all_updates_cheaper = update_rows
        .iter()
        .all(|r| r.inc_pushes < r.scratch_pushes);
    let final_l1_vs_power = rows.last().map(|r| r.l1_vs_power).unwrap_or(0.0);
    Ok(StreamReport {
        rows,
        update_inc_pushes,
        update_scratch_pushes,
        all_updates_cheaper,
        final_l1_vs_power,
    })
}

/// Options for the serving-tier experiment (`repro serve`): a
/// [`ServeTier`] answering a recurring PPR query stream over a
/// churning graph.
#[derive(Debug, Clone)]
pub struct ServeRunOptions {
    pub alpha: f64,
    /// Per-query residual target (see [`ServeOptions::tol`]).
    pub tol: f64,
    pub seed: u64,
    /// Churn rounds; every round applies one scaled churn batch through
    /// [`ServeTier::apply_batch`] and then replays the query mix, so
    /// the run measures sustained QPS *under* invalidation (round 0
    /// queries the pristine graph).
    pub epochs: usize,
    /// Queries issued per round.
    pub queries_per_epoch: usize,
    /// Size of the recurring working set of source sets. Queries draw
    /// uniformly from this pool, so repeats land warm whenever the pool
    /// fits the cache.
    pub distinct_queries: usize,
    /// Sources per query (distinct nodes, sampled once per pool entry).
    pub sources_per_query: usize,
    /// LRU capacity handed to the tier.
    pub cache_cap: usize,
    /// Head size certified per answer.
    pub topk: usize,
}

impl Default for ServeRunOptions {
    fn default() -> Self {
        ServeRunOptions {
            alpha: 0.85,
            tol: 1e-10,
            seed: 42,
            epochs: 5,
            queries_per_epoch: 64,
            distinct_queries: 24,
            sources_per_query: 2,
            cache_cap: 64,
            topk: 16,
        }
    }
}

/// Result of [`serve_queries`].
#[derive(Debug, Clone)]
pub struct ServeReport {
    /// Queries answered (`(epochs + 1) * queries_per_epoch`).
    pub queries: u64,
    /// Fraction answered from a warm cached state.
    pub hit_rate: f64,
    pub evictions: u64,
    /// Pushes spent advancing warm states (the cost of staying current
    /// under churn) vs. pushes spent on cold builds.
    pub warm_pushes: u64,
    pub cold_pushes: u64,
    /// Per-query wall-clock latency percentiles, microseconds.
    pub p50_us: f64,
    pub p99_us: f64,
    /// Answers whose top-k set certified.
    pub certified: u64,
}

/// S2: the serving-tier experiment. Builds one [`ServeTier`] over an
/// evolving graph and replays a recurring PPR query mix across churn
/// rounds, reporting cache effectiveness (hit rate, warm-vs-cold push
/// split) and per-query latency percentiles. The warm-push figure is
/// the serving form of the stream claim: answer cost ∝ change size,
/// not graph size.
pub fn serve_queries(graph_spec: &str, opts: &ServeRunOptions) -> Result<ServeReport> {
    anyhow::ensure!((0.0..1.0).contains(&opts.alpha), "alpha {} out of [0,1)", opts.alpha);
    anyhow::ensure!(opts.tol > 0.0, "tol must be positive, got {}", opts.tol);
    anyhow::ensure!(
        opts.queries_per_epoch > 0 && opts.distinct_queries > 0 && opts.sources_per_query > 0,
        "query mix needs positive queries/round, pool size, and sources/query"
    );
    let el = load_edgelist(graph_spec, opts.seed)?;
    let mut g = DeltaGraph::from_edgelist(&el);
    anyhow::ensure!(g.n() > 0, "graph {graph_spec} is empty");
    anyhow::ensure!(
        opts.sources_per_query <= g.n(),
        "sources/query {} exceeds n = {}",
        opts.sources_per_query,
        g.n()
    );
    let churn = ChurnParams::scaled_to(g.n(), g.m());
    let mut rng = Rng::new(opts.seed ^ 0x53_4552_5645); // "SERVE"
    // the recurring working set, sampled over the initial node range so
    // every pool entry stays valid as the graph grows
    let pool: Vec<Vec<u32>> = (0..opts.distinct_queries)
        .map(|_| {
            rng.sample_distinct(g.n(), opts.sources_per_query)
                .into_iter()
                .map(|u| u as u32)
                .collect()
        })
        .collect();
    let mut tier = ServeTier::new(ServeOptions {
        alpha: opts.alpha,
        tol: opts.tol,
        cache_cap: opts.cache_cap,
        topk: opts.topk,
        ..Default::default()
    });
    let mut lat_us: Vec<f64> = Vec::with_capacity((opts.epochs + 1) * opts.queries_per_epoch);
    let mut certified = 0u64;
    for epoch in 0..=opts.epochs {
        if epoch > 0 {
            let batch = churn_batch(&g, &churn, &mut rng);
            let delta = g.apply(&batch)?;
            tier.apply_batch(&g, &delta);
        }
        for _ in 0..opts.queries_per_epoch {
            let q = &pool[rng.range(0, pool.len())];
            let t0 = std::time::Instant::now();
            let ans = tier.query(&g, q)?;
            lat_us.push(t0.elapsed().as_secs_f64() * 1e6);
            anyhow::ensure!(
                ans.residual < opts.tol,
                "epoch {epoch}: answer for {q:?} returned unconverged at {:.2e}",
                ans.residual
            );
            if ans.set_certified {
                certified += 1;
            }
        }
    }
    lat_us.sort_by(f64::total_cmp);
    let pct = |p: f64| {
        let i = ((lat_us.len() as f64 - 1.0) * p).round() as usize;
        lat_us[i]
    };
    let st = tier.stats();
    Ok(ServeReport {
        queries: st.queries,
        hit_rate: st.hit_rate(),
        evictions: st.evictions,
        warm_pushes: st.warm_pushes,
        cold_pushes: st.cold_pushes,
        p50_us: pct(0.50),
        p99_us: pct(0.99),
        certified,
    })
}

/// A4: ranking robustness under relaxed thresholds.
pub fn ablation_ranking(
    ctx: &ExperimentCtx,
    procs: usize,
    tols: &[f32],
) -> Result<Vec<(f32, f32, f64, f64)>> {
    // returns (tol, achieved_global_resid, kendall_tau, top100)
    let oracle = GlobalOracle::new(&ctx.problem, 1e-9);
    tols.iter()
        .map(|&tol| {
            let m = ctx.run_cell(procs, Mode::Asynchronous, |c| c.tol = tol)?;
            Ok((
                tol,
                m.final_global_residual,
                oracle.ranking_tau(&m.x),
                oracle.top_k(&m.x, 100),
            ))
        })
        .collect()
}
