//! Multi-run experiment drivers: one function per DESIGN.md §5 entry.
//!
//! Benches and the CLI call these; each returns structured results the
//! caller renders (markdown for the terminal, JSON for reports).

use std::sync::Arc;

use crate::asynciter::{Mode, RunMetrics, RunSpec, SimEngine};
use crate::config::RunConfig;
use crate::metrics::Table1Row;
use crate::pagerank::PagerankProblem;
use crate::simnet::Topology;
use crate::termination::GlobalOracle;
use crate::Result;

use super::{build_ops, load_graph, profile_for, Partitioner};

/// Shared context for an experiment series: one graph, one problem.
pub struct ExperimentCtx {
    pub problem: Arc<PagerankProblem>,
    pub base: RunConfig,
    pub engine: Option<crate::runtime::Engine>,
}

impl ExperimentCtx {
    pub fn new(base: RunConfig) -> Result<Self> {
        let csr = load_graph(&base.graph, base.seed)?;
        let problem = Arc::new(PagerankProblem::new(csr, base.alpha));
        let engine = if base.use_artifact {
            Some(crate::runtime::Engine::new(crate::runtime::default_artifacts_dir())?)
        } else {
            None
        };
        Ok(ExperimentCtx { problem, base, engine })
    }

    /// Run one (mode, procs) cell against the shared problem.
    pub fn run_cell(&self, procs: usize, mode: Mode, cfg_mut: impl Fn(&mut RunConfig)) -> Result<RunMetrics> {
        let mut cfg = self.base.clone();
        cfg.procs = procs;
        cfg.mode = mode;
        cfg_mut(&mut cfg);
        cfg.validate()?;
        let partitioner = Partitioner::consecutive(self.problem.n(), cfg.procs);
        let mut ops = build_ops(&self.problem, &partitioner, &cfg, self.engine.as_ref())?;
        let profile = profile_for(&cfg);
        let spec = RunSpec {
            mode: cfg.mode,
            stop: cfg.stop_rule(),
            adaptive: cfg.adaptive,
            seed: cfg.seed,
            max_total_iters: 2_000_000,
        };
        let sim = SimEngine::new(&profile, &self.problem);
        Ok(sim.run(&mut ops, &spec))
    }
}

/// T1: Table 1 — sync vs async for the given machine counts.
pub fn table1(ctx: &ExperimentCtx, procs: &[usize]) -> Result<Vec<(Table1Row, RunMetrics, RunMetrics)>> {
    let mut out = Vec::new();
    for &p in procs {
        let sync = ctx.run_cell(p, Mode::Synchronous, |_| {})?;
        let asyn = ctx.run_cell(p, Mode::Asynchronous, |_| {})?;
        out.push((Table1Row::from_runs(&sync, &asyn), sync, asyn));
    }
    Ok(out)
}

/// T2: Table 2 — async imports matrix at p UEs (paper: 4).
pub fn table2(ctx: &ExperimentCtx, procs: usize) -> Result<RunMetrics> {
    ctx.run_cell(procs, Mode::Asynchronous, |_| {})
}

/// G1 result: what global residual does the local threshold actually buy?
#[derive(Debug, Clone)]
pub struct GlobalThresholdResult {
    pub local_tol: f32,
    /// True ‖Gx−x‖₁ when the Figure-1 protocol stopped the async run.
    pub achieved_global_residual: f32,
    /// Kendall-τ of the stopped vector's ranking vs a tight reference.
    pub ranking_tau: f64,
    pub top100_overlap: f64,
    /// G2: times to reach a common global threshold.
    pub sync_time_global: f64,
    pub async_time_global: f64,
    pub speedup_global: f64,
}

/// G1+G2: run the async protocol at `local_tol`, measure the achieved
/// global residual; then race both modes to that same global threshold.
pub fn global_threshold(ctx: &ExperimentCtx, procs: usize, local_tol: f32) -> Result<GlobalThresholdResult> {
    let asyn = ctx.run_cell(procs, Mode::Asynchronous, |c| c.tol = local_tol)?;
    let achieved = asyn.final_global_residual;

    let mut oracle = GlobalOracle::new(&ctx.problem, (local_tol * 1e-3).max(1e-9));
    let tau = oracle.ranking_tau(&asyn.x);
    let top100 = oracle.top_k(&asyn.x, 100);
    let _ = &mut oracle;

    // G2: race to the common global threshold
    let g_tol = achieved.max(local_tol);
    let sync_g = ctx.run_cell(procs, Mode::Synchronous, |c| {
        c.global_threshold = true;
        c.tol = g_tol;
    })?;
    let async_g = ctx.run_cell(procs, Mode::Asynchronous, |c| {
        c.global_threshold = true;
        c.tol = g_tol;
    })?;
    Ok(GlobalThresholdResult {
        local_tol,
        achieved_global_residual: achieved,
        ranking_tau: tau,
        top100_overlap: top100,
        sync_time_global: sync_g.total_time,
        async_time_global: async_g.total_time,
        speedup_global: sync_g.total_time / async_g.total_time,
    })
}

/// A1: cancellation-window sweep (async, fixed p).
pub fn ablation_cancel_window(
    ctx: &ExperimentCtx,
    procs: usize,
    windows: &[Option<f64>],
) -> Result<Vec<(Option<f64>, RunMetrics)>> {
    windows
        .iter()
        .map(|&w| Ok((w, ctx.run_cell(procs, Mode::Asynchronous, |c| c.cancel_window = w)?)))
        .collect()
}

/// A2: adaptive per-peer rates on a cluster with one slow node.
pub fn ablation_adaptive(
    ctx: &ExperimentCtx,
    procs: usize,
    slow_factor: f64,
) -> Result<(RunMetrics, RunMetrics)> {
    // NOTE: the slow node enters through a modified profile, so this
    // bypasses run_cell's profile_for and builds the sim directly.
    let run = |adaptive: bool| -> Result<RunMetrics> {
        let mut cfg = ctx.base.clone();
        cfg.procs = procs;
        cfg.mode = Mode::Asynchronous;
        cfg.adaptive = adaptive;
        let partitioner = Partitioner::consecutive(ctx.problem.n(), procs);
        let mut ops = build_ops(&ctx.problem, &partitioner, &cfg, ctx.engine.as_ref())?;
        let profile = profile_for(&cfg).with_slow_node(procs - 1, slow_factor);
        let spec = RunSpec {
            mode: cfg.mode,
            stop: cfg.stop_rule(),
            adaptive,
            seed: cfg.seed,
            max_total_iters: 2_000_000,
        };
        Ok(SimEngine::new(&profile, &ctx.problem).run(&mut ops, &spec))
    };
    Ok((run(false)?, run(true)?))
}

/// A3: topology sweep (async only; sync requires clique).
pub fn ablation_topology(
    ctx: &ExperimentCtx,
    procs: usize,
    topologies: &[Topology],
) -> Result<Vec<(Topology, RunMetrics)>> {
    topologies
        .iter()
        .map(|&t| Ok((t, ctx.run_cell(procs, Mode::Asynchronous, |c| c.topology = t)?)))
        .collect()
}

/// A4: ranking robustness under relaxed thresholds.
pub fn ablation_ranking(
    ctx: &ExperimentCtx,
    procs: usize,
    tols: &[f32],
) -> Result<Vec<(f32, f32, f64, f64)>> {
    // returns (tol, achieved_global_resid, kendall_tau, top100)
    let oracle = GlobalOracle::new(&ctx.problem, 1e-9);
    tols.iter()
        .map(|&tol| {
            let m = ctx.run_cell(procs, Mode::Asynchronous, |c| c.tol = tol)?;
            Ok((
                tol,
                m.final_global_residual,
                oracle.ranking_tau(&m.x),
                oracle.top_k(&m.x, 100),
            ))
        })
        .collect()
}
