//! Row partitioning across UEs.
//!
//! The paper distributes "blocks of consecutive ⌈n/p⌉ rows" (§5.2);
//! [`Partitioner::consecutive`] reproduces that exactly. The balanced
//! variant splits by nonzero count instead — the natural fix for the
//! heterogeneity the paper's own degree skew induces — and is compared
//! in the ablation bench.

use crate::graph::Csr;

/// A partition of [0, n) into p contiguous blocks.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Partitioner {
    bounds: Vec<usize>, // len p+1, bounds[0]=0, bounds[p]=n
}

impl Partitioner {
    /// The paper's scheme: blocks of ⌈n/p⌉ consecutive rows (last block
    /// takes the remainder).
    pub fn consecutive(n: usize, p: usize) -> Partitioner {
        assert!(p >= 1 && n >= p, "need n >= p >= 1");
        let size = n.div_ceil(p);
        let mut bounds = Vec::with_capacity(p + 1);
        for i in 0..=p {
            bounds.push((i * size).min(n));
        }
        // guard against empty trailing blocks when p*size >> n
        for i in 1..=p {
            if bounds[i] <= bounds[i - 1] {
                bounds[i] = (bounds[i - 1] + 1).min(n);
            }
        }
        *bounds.last_mut().unwrap() = n;
        Partitioner { bounds }
    }

    /// Balanced-nnz scheme: contiguous blocks with roughly equal
    /// nonzero counts (equalizes per-iteration compute across UEs).
    pub fn balanced_nnz(csr: &Csr, p: usize) -> Partitioner {
        let n = csr.n();
        assert!(p >= 1 && n >= p);
        let total: usize = csr.nnz();
        let target = total as f64 / p as f64;
        let mut bounds = vec![0usize];
        let mut acc = 0usize;
        let mut next_target = target;
        for i in 0..n {
            acc += csr.row_len(i);
            if acc as f64 >= next_target && bounds.len() < p {
                bounds.push(i + 1);
                next_target += target;
            }
        }
        while bounds.len() < p {
            // degenerate: pad with single-row blocks at the end
            bounds.push((bounds.last().unwrap() + 1).min(n - (p - bounds.len())));
        }
        bounds.push(n);
        // ensure strictly increasing
        for i in 1..bounds.len() {
            if bounds[i] <= bounds[i - 1] {
                bounds[i] = bounds[i - 1] + 1;
            }
        }
        *bounds.last_mut().unwrap() = n;
        Partitioner { bounds }
    }

    pub fn p(&self) -> usize {
        self.bounds.len() - 1
    }

    /// Block ranges [(lo, hi); p].
    pub fn blocks(&self) -> Vec<(usize, usize)> {
        self.bounds.windows(2).map(|w| (w[0], w[1])).collect()
    }

    /// Which UE owns row i.
    pub fn owner_of(&self, row: usize) -> usize {
        debug_assert!(row < *self.bounds.last().unwrap());
        match self.bounds.binary_search(&row) {
            Ok(i) if i == self.p() => i - 1,
            Ok(i) => i,
            Err(i) => i - 1,
        }
    }

    /// Max/min block size ratio (load imbalance indicator).
    pub fn imbalance(&self) -> f64 {
        let sizes: Vec<usize> = self.blocks().iter().map(|(l, h)| h - l).collect();
        let max = *sizes.iter().max().unwrap();
        let min = *sizes.iter().min().unwrap().max(&1);
        max as f64 / min as f64
    }

    /// Nnz per block under a given matrix.
    pub fn block_nnz(&self, csr: &Csr) -> Vec<usize> {
        self.blocks()
            .iter()
            .map(|&(lo, hi)| (lo..hi).map(|i| csr.row_len(i)).sum())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generators, Csr};

    #[test]
    fn consecutive_tiles_exactly() {
        for (n, p) in [(10, 3), (281_903, 6), (7, 7), (100, 1)] {
            let part = Partitioner::consecutive(n, p);
            let blocks = part.blocks();
            assert_eq!(blocks.len(), p);
            assert_eq!(blocks[0].0, 0);
            assert_eq!(blocks[p - 1].1, n);
            for w in blocks.windows(2) {
                assert_eq!(w[0].1, w[1].0);
                assert!(w[0].0 < w[0].1);
            }
        }
    }

    #[test]
    fn consecutive_matches_paper_ceil() {
        // paper: blocks of ceil(n/p) consecutive rows
        let part = Partitioner::consecutive(281_903, 6);
        let blocks = part.blocks();
        let size = 281_903usize.div_ceil(6); // 46984
        assert_eq!(blocks[0], (0, size));
        assert_eq!(blocks[1], (size, 2 * size));
        assert_eq!(blocks[5].1, 281_903);
    }

    #[test]
    fn owner_of_is_consistent() {
        let part = Partitioner::consecutive(100, 7);
        for (ue, (lo, hi)) in part.blocks().into_iter().enumerate() {
            for r in lo..hi {
                assert_eq!(part.owner_of(r), ue, "row {r}");
            }
        }
    }

    #[test]
    fn balanced_nnz_reduces_imbalance() {
        let el = generators::power_law_web(&generators::WebParams::scaled(5_000), 5);
        let csr = Csr::from_edgelist(&el).unwrap();
        let p = 4;
        let cons = Partitioner::consecutive(csr.n(), p);
        let bal = Partitioner::balanced_nnz(&csr, p);
        assert_eq!(bal.p(), p);
        let spread = |nnz: &[usize]| {
            let max = *nnz.iter().max().unwrap() as f64;
            let min = *nnz.iter().min().unwrap().max(&1) as f64;
            max / min
        };
        let s_cons = spread(&cons.block_nnz(&csr));
        let s_bal = spread(&bal.block_nnz(&csr));
        assert!(
            s_bal <= s_cons,
            "balanced {s_bal:.2} should not exceed consecutive {s_cons:.2}"
        );
        // and the balanced split still tiles the matrix
        assert_eq!(bal.blocks()[0].0, 0);
        assert_eq!(bal.blocks()[p - 1].1, csr.n());
    }

    #[test]
    #[should_panic(expected = "need n >= p")]
    fn rejects_more_blocks_than_rows() {
        Partitioner::consecutive(3, 4);
    }
}
