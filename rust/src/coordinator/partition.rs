//! Row partitioning across UEs.
//!
//! The paper distributes "blocks of consecutive ⌈n/p⌉ rows" (§5.2);
//! [`Partitioner::consecutive`] reproduces that exactly. The balanced
//! variant splits by nonzero count instead — the natural fix for the
//! heterogeneity the paper's own degree skew induces — and is compared
//! in the ablation bench.

use crate::graph::Csr;

/// A partition of [0, n) into p contiguous blocks.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Partitioner {
    bounds: Vec<usize>, // len p+1, bounds[0]=0, bounds[p]=n
}

impl Partitioner {
    /// The paper's scheme: blocks of ⌈n/p⌉ consecutive rows (last block
    /// takes the remainder).
    pub fn consecutive(n: usize, p: usize) -> Partitioner {
        assert!(p >= 1 && n >= p, "need n >= p >= 1");
        let size = n.div_ceil(p);
        let mut bounds = Vec::with_capacity(p + 1);
        for i in 0..=p {
            bounds.push((i * size).min(n));
        }
        // guard against empty trailing blocks when p*size >> n
        for i in 1..=p {
            if bounds[i] <= bounds[i - 1] {
                bounds[i] = (bounds[i - 1] + 1).min(n);
            }
        }
        *bounds.last_mut().unwrap() = n;
        Partitioner { bounds }
    }

    /// Balanced-nnz scheme: contiguous blocks with roughly equal
    /// nonzero counts (equalizes per-iteration compute across UEs).
    pub fn balanced_nnz(csr: &Csr, p: usize) -> Partitioner {
        let lens: Vec<usize> = (0..csr.n()).map(|i| csr.row_len(i)).collect();
        Partitioner::balanced_nnz_lens(&lens, p)
    }

    /// Balanced split over explicit per-row weights — the same greedy
    /// prefix scheme as [`Partitioner::balanced_nnz`] but usable with
    /// any row-cost vector (CSR in-rows for the DES operators,
    /// [`crate::stream::DeltaGraph`] out-rows for the sharded push
    /// engine). `p` is clamped to the row count, so `p > n` degrades
    /// to one row per block instead of panicking.
    ///
    /// Each interior cut is placed where the running weight sum crosses
    /// a multiple of `total/p`, assigning the boundary row to whichever
    /// side lands closer to the target; every block keeps at least one
    /// row. On graphs whose heaviest row does not exceed the ideal
    /// block weight (power-law webs at moderate `p`), the heaviest
    /// block therefore stays below 2x the ideal.
    pub fn balanced_nnz_lens(lens: &[usize], p: usize) -> Partitioner {
        let n = lens.len();
        assert!(n >= 1, "cannot partition an empty row set");
        assert!(p >= 1, "need at least one block");
        let p = p.min(n);
        let total: usize = lens.iter().sum();
        let target = total as f64 / p as f64;
        let mut bounds = Vec::with_capacity(p + 1);
        bounds.push(0usize);
        let mut acc = 0usize;
        for (i, &len) in lens.iter().enumerate() {
            let cut_idx = bounds.len(); // next interior cut: 1..p-1
            if cut_idx == p {
                break;
            }
            let boundary = target * cut_idx as f64;
            let before = acc as f64;
            let after = (acc + len) as f64;
            if after >= boundary {
                // the ideal boundary falls inside row i: cut on the
                // closer side, but never create an empty block and
                // always leave >= 1 row per remaining block
                let cut = if boundary - before <= after - boundary { i } else { i + 1 };
                let lo = bounds.last().unwrap() + 1;
                let hi = n - (p - cut_idx);
                bounds.push(cut.clamp(lo, hi.max(lo)));
            }
            acc += len;
        }
        // degenerate tail (e.g. all remaining weight was zero): pad so
        // every block still gets a row
        while bounds.len() < p {
            let cut_idx = bounds.len();
            bounds.push((bounds.last().unwrap() + 1).min(n - (p - cut_idx)));
        }
        bounds.push(n);
        debug_assert!(bounds.windows(2).all(|w| w[0] < w[1]), "bounds {bounds:?}");
        Partitioner { bounds }
    }

    /// Adopt explicit cut points: `bounds[0] == 0`, strictly
    /// increasing, `bounds[p] == n`. The epoch-resident sharded solver
    /// uses this to extend the last block over newly arrived rows
    /// without disturbing the interior cuts.
    pub fn from_bounds(bounds: Vec<usize>) -> Partitioner {
        assert!(bounds.len() >= 2, "need at least one block");
        assert_eq!(bounds[0], 0, "bounds must start at 0");
        assert!(
            bounds.windows(2).all(|w| w[0] < w[1]),
            "bounds must be strictly increasing: {bounds:?}"
        );
        Partitioner { bounds }
    }

    /// The raw cut points: `bounds()[i]..bounds()[i+1]` is block `i`.
    pub fn bounds(&self) -> &[usize] {
        &self.bounds
    }

    pub fn p(&self) -> usize {
        self.bounds.len() - 1
    }

    /// Block ranges [(lo, hi); p].
    pub fn blocks(&self) -> Vec<(usize, usize)> {
        self.bounds.windows(2).map(|w| (w[0], w[1])).collect()
    }

    /// Which UE owns row i.
    pub fn owner_of(&self, row: usize) -> usize {
        debug_assert!(row < *self.bounds.last().unwrap());
        match self.bounds.binary_search(&row) {
            Ok(i) if i == self.p() => i - 1,
            Ok(i) => i,
            Err(i) => i - 1,
        }
    }

    /// Max/min block size ratio (load imbalance indicator).
    pub fn imbalance(&self) -> f64 {
        let sizes: Vec<usize> = self.blocks().iter().map(|(l, h)| h - l).collect();
        let max = *sizes.iter().max().unwrap();
        let min = *sizes.iter().min().unwrap().max(&1);
        max as f64 / min as f64
    }

    /// Nnz per block under a given matrix.
    pub fn block_nnz(&self, csr: &Csr) -> Vec<usize> {
        self.blocks()
            .iter()
            .map(|&(lo, hi)| (lo..hi).map(|i| csr.row_len(i)).sum())
            .collect()
    }

    /// Total weight per block under an explicit per-row weight vector
    /// (the out-row nnz the sharded push engine balances on).
    pub fn block_weights(&self, lens: &[usize]) -> Vec<usize> {
        debug_assert_eq!(lens.len(), *self.bounds.last().unwrap());
        self.blocks()
            .iter()
            .map(|&(lo, hi)| lens[lo..hi].iter().sum())
            .collect()
    }

    /// Heaviest block weight over the ideal `total/p` — the skew signal
    /// the between-epoch re-balancer thresholds on. `1.0` means
    /// perfectly balanced; an all-zero weight vector reports `1.0`
    /// (nothing to balance).
    pub fn weight_imbalance(&self, lens: &[usize]) -> f64 {
        let w = self.block_weights(lens);
        let total: usize = w.iter().sum();
        if total == 0 {
            return 1.0;
        }
        let ideal = total as f64 / self.p() as f64;
        *w.iter().max().unwrap() as f64 / ideal
    }
}

/// Row-ownership map: which shard currently *owns* each row — the
/// generalization of a contiguous [`Partitioner`] that intra-epoch work
/// stealing needs.
///
/// A fresh map is just a partition: every row is owned by the shard
/// whose contiguous **home** block contains it, and `owner_of` runs on
/// the partitioner's binary search (the contiguous fast path — no
/// per-row array exists at all). The first ownership move materializes
/// a dense `u16` shard-id array; from then on `owner_of` is a single
/// indexed load. [`fold_contiguous`](Self::fold_contiguous) drops the
/// dense array again once every row is back home — which is exactly
/// what `ShardedPush::rebalance` does before re-cutting bounds, so the
/// re-balancer only ever reasons about contiguous blocks.
///
/// Terminology used throughout the steal machinery:
/// * a row's **home** is the shard whose contiguous block contains it
///   (never changes between re-partitions);
/// * a row's **owner** is the shard currently holding its rank mass and
///   queued residual (changes on steal grants and repatriation).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct OwnerMap {
    part: Partitioner,
    /// Dense per-row owner; `None` while ownership matches the home
    /// partition (the common case — allocated lazily on the first
    /// steal, dropped again by `fold_contiguous`).
    dense: Option<Vec<u16>>,
}

impl OwnerMap {
    /// A map where every row is owned by its home shard.
    pub fn contiguous(part: Partitioner) -> OwnerMap {
        assert!(
            part.p() <= u16::MAX as usize,
            "owner ids are u16 ({} shards requested)",
            part.p()
        );
        OwnerMap { part, dense: None }
    }

    /// The home partition underneath the ownership overlay.
    pub fn partitioner(&self) -> &Partitioner {
        &self.part
    }

    /// Shard that currently owns `row`.
    #[inline]
    pub fn owner_of(&self, row: usize) -> usize {
        match &self.dense {
            Some(d) => d[row] as usize,
            None => self.part.owner_of(row),
        }
    }

    /// Shard whose contiguous home block contains `row` (ignores
    /// steals).
    #[inline]
    pub fn home_of(&self, row: usize) -> usize {
        self.part.owner_of(row)
    }

    /// Whether ownership currently coincides with the home partition
    /// (no dense overlay in use).
    pub fn is_contiguous(&self) -> bool {
        self.dense.is_none()
    }

    /// Move ownership of `row` to `shard`, materializing the dense
    /// overlay on first use.
    pub fn set_owner(&mut self, row: usize, shard: usize) {
        debug_assert!(row < *self.part.bounds().last().unwrap());
        debug_assert!(shard < self.part.p());
        let dense = self.dense.get_or_insert_with(|| {
            let mut d = Vec::with_capacity(*self.part.bounds().last().unwrap());
            for (id, (lo, hi)) in self.part.blocks().into_iter().enumerate() {
                d.extend(std::iter::repeat(id as u16).take(hi - lo));
            }
            d
        });
        dense[row] = shard as u16;
    }

    /// Rows currently owned away from their home shard.
    pub fn displaced(&self) -> usize {
        match &self.dense {
            None => 0,
            Some(d) => d
                .iter()
                .enumerate()
                .filter(|&(row, &o)| o as usize != self.part.owner_of(row))
                .count(),
        }
    }

    /// Drop the dense overlay if (and only if) every row is owned by
    /// its home shard again. Returns whether the map is contiguous
    /// afterwards — `ShardedPush::rebalance` calls this after
    /// repatriating stolen rows, folding the map back to plain bounds
    /// before any re-cut.
    pub fn fold_contiguous(&mut self) -> bool {
        if self.displaced() == 0 {
            self.dense = None;
        }
        self.dense.is_none()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generators, Csr};

    #[test]
    fn consecutive_tiles_exactly() {
        for (n, p) in [(10, 3), (281_903, 6), (7, 7), (100, 1)] {
            let part = Partitioner::consecutive(n, p);
            let blocks = part.blocks();
            assert_eq!(blocks.len(), p);
            assert_eq!(blocks[0].0, 0);
            assert_eq!(blocks[p - 1].1, n);
            for w in blocks.windows(2) {
                assert_eq!(w[0].1, w[1].0);
                assert!(w[0].0 < w[0].1);
            }
        }
    }

    #[test]
    fn consecutive_matches_paper_ceil() {
        // paper: blocks of ceil(n/p) consecutive rows
        let part = Partitioner::consecutive(281_903, 6);
        let blocks = part.blocks();
        let size = 281_903usize.div_ceil(6); // 46984
        assert_eq!(blocks[0], (0, size));
        assert_eq!(blocks[1], (size, 2 * size));
        assert_eq!(blocks[5].1, 281_903);
    }

    #[test]
    fn owner_of_is_consistent() {
        let part = Partitioner::consecutive(100, 7);
        for (ue, (lo, hi)) in part.blocks().into_iter().enumerate() {
            for r in lo..hi {
                assert_eq!(part.owner_of(r), ue, "row {r}");
            }
        }
    }

    #[test]
    fn balanced_nnz_reduces_imbalance() {
        let el = generators::power_law_web(&generators::WebParams::scaled(5_000), 5);
        let csr = Csr::from_edgelist(&el).unwrap();
        let p = 4;
        let cons = Partitioner::consecutive(csr.n(), p);
        let bal = Partitioner::balanced_nnz(&csr, p);
        assert_eq!(bal.p(), p);
        let spread = |nnz: &[usize]| {
            let max = *nnz.iter().max().unwrap() as f64;
            let min = *nnz.iter().min().unwrap().max(&1) as f64;
            max / min
        };
        let s_cons = spread(&cons.block_nnz(&csr));
        let s_bal = spread(&bal.block_nnz(&csr));
        assert!(
            s_bal <= s_cons,
            "balanced {s_bal:.2} should not exceed consecutive {s_cons:.2}"
        );
        // and the balanced split still tiles the matrix
        assert_eq!(bal.blocks()[0].0, 0);
        assert_eq!(bal.blocks()[p - 1].1, csr.n());
    }

    #[test]
    #[should_panic(expected = "need n >= p")]
    fn rejects_more_blocks_than_rows() {
        Partitioner::consecutive(3, 4);
    }

    fn assert_tiles(part: &Partitioner, n: usize) {
        let blocks = part.blocks();
        assert_eq!(blocks[0].0, 0);
        assert_eq!(blocks[blocks.len() - 1].1, n);
        for w in blocks.windows(2) {
            assert_eq!(w[0].1, w[1].0, "gap between blocks");
        }
        for &(lo, hi) in &blocks {
            assert!(lo < hi, "empty block in {blocks:?}");
        }
    }

    #[test]
    fn balanced_lens_clamps_p_above_n() {
        // p > n degrades to one row per block instead of panicking
        let part = Partitioner::balanced_nnz_lens(&[3, 1, 2], 10);
        assert_eq!(part.p(), 3);
        assert_tiles(&part, 3);
        assert_eq!(part.blocks(), vec![(0, 1), (1, 2), (2, 3)]);
    }

    #[test]
    fn balanced_lens_handles_empty_rows() {
        // leading/trailing/interior zero-weight rows still tile
        let lens = [0, 0, 5, 0, 0, 5, 0, 0];
        for p in 1..=8 {
            let part = Partitioner::balanced_nnz_lens(&lens, p);
            assert_eq!(part.p(), p, "p={p}");
            assert_tiles(&part, lens.len());
        }
        // all-zero weights (fully dangling graph) degrade gracefully
        let part = Partitioner::balanced_nnz_lens(&[0; 6], 3);
        assert_eq!(part.p(), 3);
        assert_tiles(&part, 6);
    }

    #[test]
    fn balanced_lens_isolates_dominant_hub() {
        // one hub row carries ~all the weight: it must land alone-ish in
        // one block while the partition still tiles and every other
        // block gets its share of the remainder
        let mut lens = vec![1usize; 64];
        lens[20] = 10_000;
        let part = Partitioner::balanced_nnz_lens(&lens, 4);
        assert_eq!(part.p(), 4);
        assert_tiles(&part, 64);
        let nnz: Vec<usize> = part
            .blocks()
            .iter()
            .map(|&(lo, hi)| lens[lo..hi].iter().sum())
            .collect();
        // the hub block dominates; no other block exceeds the non-hub total
        let hub_block = part.owner_of(20);
        for (i, &w) in nnz.iter().enumerate() {
            if i != hub_block {
                assert!(w <= 63, "block {i} holds {w} nnz without the hub");
            }
        }
        assert!(nnz[hub_block] >= 10_000);
    }

    #[test]
    fn balanced_nnz_within_2x_ideal_on_power_law() {
        // the acceptance property for the sharded push engine: on
        // power-law webs the heaviest block stays below 2x the ideal
        for (n, seed) in [(4_000, 7), (8_000, 8)] {
            let el = generators::power_law_web(&generators::WebParams::scaled(n), seed);
            let csr = Csr::from_edgelist(&el).unwrap();
            for p in [2usize, 4, 8] {
                let part = Partitioner::balanced_nnz(&csr, p);
                let nnz = part.block_nnz(&csr);
                let ideal = csr.nnz() as f64 / p as f64;
                let max = *nnz.iter().max().unwrap() as f64;
                assert!(
                    max <= 2.0 * ideal,
                    "n={n} p={p}: max block {max} vs ideal {ideal}"
                );
            }
        }
    }

    #[test]
    fn from_bounds_roundtrips_and_validates() {
        let part = Partitioner::balanced_nnz_lens(&[3, 1, 4, 1, 5], 3);
        let same = Partitioner::from_bounds(part.bounds().to_vec());
        assert_eq!(part, same);
        // extending the last block (node arrivals) keeps interior cuts
        let mut b = part.bounds().to_vec();
        *b.last_mut().unwrap() = 9;
        let grown = Partitioner::from_bounds(b);
        assert_eq!(grown.p(), part.p());
        assert_eq!(grown.blocks().last().unwrap().1, 9);
        assert_eq!(grown.bounds()[..part.p()], part.bounds()[..part.p()]);
    }

    #[test]
    #[should_panic(expected = "strictly increasing")]
    fn from_bounds_rejects_empty_block() {
        Partitioner::from_bounds(vec![0, 2, 2, 5]);
    }

    #[test]
    fn weight_imbalance_flags_skew() {
        let lens = [1usize, 1, 1, 1, 1, 1, 1, 1];
        let part = Partitioner::balanced_nnz_lens(&lens, 4);
        assert_eq!(part.block_weights(&lens), vec![2, 2, 2, 2]);
        assert!((part.weight_imbalance(&lens) - 1.0).abs() < 1e-12);
        // a hub arriving in block 0 skews it
        let skewed = [100usize, 1, 1, 1, 1, 1, 1, 1];
        assert!(part.weight_imbalance(&skewed) > 3.0);
        // all-zero weights: nothing to balance
        assert_eq!(part.weight_imbalance(&[0; 8]), 1.0);
    }

    #[test]
    fn owner_map_contiguous_fast_path_and_overlay_agree() {
        let part = Partitioner::balanced_nnz_lens(&[3, 1, 4, 1, 5, 9, 2, 6], 3);
        let mut owners = OwnerMap::contiguous(part.clone());
        assert!(owners.is_contiguous());
        for row in 0..8 {
            assert_eq!(owners.owner_of(row), part.owner_of(row));
            assert_eq!(owners.home_of(row), part.owner_of(row));
        }
        // move one row: dense overlay materializes, only that row moves
        let moved = part.blocks()[0].0; // first row of shard 0
        owners.set_owner(moved, 2);
        assert!(!owners.is_contiguous());
        assert_eq!(owners.displaced(), 1);
        assert_eq!(owners.owner_of(moved), 2);
        assert_eq!(owners.home_of(moved), 0);
        for row in 0..8 {
            if row != moved {
                assert_eq!(owners.owner_of(row), part.owner_of(row));
            }
        }
        // folding refuses while displaced, succeeds after return home
        assert!(!owners.fold_contiguous());
        owners.set_owner(moved, 0);
        assert!(owners.fold_contiguous());
        assert!(owners.is_contiguous());
    }

    #[test]
    fn balanced_lens_matches_csr_variant() {
        let el = generators::power_law_web(&generators::WebParams::scaled(2_000), 9);
        let csr = Csr::from_edgelist(&el).unwrap();
        let lens: Vec<usize> = (0..csr.n()).map(|i| csr.row_len(i)).collect();
        assert_eq!(
            Partitioner::balanced_nnz(&csr, 5),
            Partitioner::balanced_nnz_lens(&lens, 5)
        );
    }
}
