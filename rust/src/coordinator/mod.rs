//! The leader: partitioning, run orchestration, and reports.
//!
//! This is the role the paper's "Configuration objects" play (§5.1):
//! load parameters, partition and distribute matrix data, launch the
//! computation, and generate reports. [`run_experiment`] turns one
//! [`crate::config::RunConfig`] into a [`crate::asynciter::RunMetrics`];
//! [`experiments`] bundles the multi-run drivers behind Tables 1–2 and
//! the G/A experiment series of DESIGN.md §5.

mod partition;
pub mod experiments;
mod report;

pub use partition::{OwnerMap, Partitioner};
pub use report::Report;

use std::sync::Arc;

use crate::asynciter::{ArtifactBlockOp, BlockOperator, NativeBlockOp, RunMetrics, RunSpec, SimEngine};
use crate::config::RunConfig;
use crate::graph::{generators, io, Csr, EdgeList};
use crate::pagerank::PagerankProblem;
use crate::simnet::ClusterProfile;
use crate::stream::PushBlockOp;
use crate::Result;

/// Materialize the edge list named by a graph spec ("stanford",
/// "scaled:<n>", "erdos:<n>:<m>", "rmat:<scale>[:<edge-factor>]", or a
/// path to a .txt/.bin edge list). The raw-edge form is what
/// `repro generate` saves and what the `stream` subsystem's
/// [`crate::stream::DeltaGraph`] consumes.
pub fn load_edgelist(spec: &str, seed: u64) -> Result<EdgeList> {
    Ok(if spec == "stanford" {
        generators::stanford_web_like(seed)
    } else if let Some(rest) = spec.strip_prefix("scaled:") {
        let n: usize = rest.parse()?;
        generators::power_law_web(&generators::WebParams::scaled(n), seed)
    } else if let Some(rest) = spec.strip_prefix("erdos:") {
        let (n, m) = rest
            .split_once(':')
            .ok_or_else(|| anyhow::anyhow!("erdos:<n>:<m>"))?;
        generators::erdos_renyi(n.parse()?, m.parse()?, seed)
    } else if let Some(rest) = spec.strip_prefix("rmat:") {
        let (scale, ef) = match rest.split_once(':') {
            Some((s, e)) => (s.parse()?, e.parse()?),
            None => (rest.parse()?, 8usize),
        };
        anyhow::ensure!(
            (1..=30u32).contains(&scale),
            "rmat:<scale>[:<edge-factor>] wants scale in 1..=30, got {scale}"
        );
        let m = (1usize << scale) * ef;
        generators::rmat(scale, m, generators::RMAT_WEB_PROBS, seed)
    } else if spec.ends_with(".bin") {
        io::load_edgelist_bin(spec)?
    } else {
        io::load_edgelist_text(spec, None)?
    })
}

/// Materialize the (transposed, normalized) CSR for a graph spec.
/// `.bin` specs take the streaming two-pass build
/// ([`io::stream_csr_from_bin`]) — peak RSS stays O(n + nnz) with no
/// intermediate edge list; everything else materializes edges first.
pub fn load_graph(spec: &str, seed: u64) -> Result<Csr> {
    if spec.ends_with(".bin") {
        return Ok(io::stream_csr_from_bin(spec, &io::StreamCsrOptions::default())?);
    }
    Csr::from_edgelist(&load_edgelist(spec, seed)?)
}

/// Build the per-UE block operators for a problem.
pub fn build_ops(
    problem: &Arc<PagerankProblem>,
    partitioner: &Partitioner,
    cfg: &RunConfig,
    engine: Option<&crate::runtime::Engine>,
) -> Result<Vec<Box<dyn BlockOperator>>> {
    let mut ops: Vec<Box<dyn BlockOperator>> = Vec::with_capacity(cfg.procs);
    for (lo, hi) in partitioner.blocks() {
        if cfg.use_artifact {
            let eng = engine.ok_or_else(|| {
                anyhow::anyhow!("use_artifact requires a runtime engine (make artifacts)")
            })?;
            ops.push(Box::new(ArtifactBlockOp::new(
                eng,
                problem.clone(),
                lo,
                hi,
                cfg.ell_width,
            )?));
        } else if cfg.use_push {
            ops.push(Box::new(PushBlockOp::new(problem.clone(), lo, hi)));
        } else {
            ops.push(Box::new(NativeBlockOp::new(problem.clone(), lo, hi)));
        }
    }
    Ok(ops)
}

/// Row partition matching a config: the paper's consecutive ⌈n/p⌉
/// blocks, or balanced-nnz when `cfg.balanced_partition` is set (the
/// sharding the parallel push engine uses, applied here to the DES
/// operators so the simulator runs the same sharded layout under
/// virtual time).
///
/// Errors (rather than panicking downstream) when the config asks for
/// more UEs than the graph has rows — `RunConfig::validate` cannot
/// check this, it never sees the graph.
pub fn partition_for(problem: &PagerankProblem, cfg: &RunConfig) -> Result<Partitioner> {
    anyhow::ensure!(
        cfg.procs <= problem.n(),
        "procs {} exceeds the graph's {} rows",
        cfg.procs,
        problem.n()
    );
    Ok(if cfg.balanced_partition {
        Partitioner::balanced_nnz(&problem.csr, cfg.procs)
    } else {
        Partitioner::consecutive(problem.n(), cfg.procs)
    })
}

/// Cluster profile matching a config (paper testbed + overrides).
pub fn profile_for(cfg: &RunConfig) -> ClusterProfile {
    let mut prof = ClusterProfile::paper_beowulf(cfg.procs)
        .with_topology(cfg.topology)
        .with_cancel_window(cfg.cancel_window);
    prof.bandwidth *= cfg.bandwidth_scale;
    prof
}

/// Execute one configured run end-to-end (graph → ops → simulation).
pub fn run_experiment(cfg: &RunConfig, engine: Option<&crate::runtime::Engine>) -> Result<RunMetrics> {
    cfg.validate()?;
    let csr = load_graph(&cfg.graph, cfg.seed)?;
    let problem = Arc::new(PagerankProblem::new(csr, cfg.alpha));
    let partitioner = partition_for(&problem, cfg)?;
    let mut ops = build_ops(&problem, &partitioner, cfg, engine)?;
    let profile = profile_for(cfg);
    let spec = RunSpec {
        mode: cfg.mode,
        stop: cfg.stop_rule(),
        adaptive: cfg.adaptive,
        seed: cfg.seed,
        max_total_iters: 2_000_000,
    };
    let sim = SimEngine::new(&profile, &problem);
    Ok(sim.run(&mut ops, &spec))
}
