//! Raw directed-edge list: the form a crawl (or generator) produces.

use super::NodeId;
use crate::Result;

/// A directed graph as a plain (src, dst) edge list over `n` nodes.
///
/// Self-loops are allowed (the Stanford crawl contains them); duplicate
/// edges are deduplicated when converting to [`super::Csr`] (PageRank's
/// adjacency matrix is 0/1).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct EdgeList {
    n: usize,
    edges: Vec<(NodeId, NodeId)>,
}

impl EdgeList {
    pub fn new(n: usize) -> Self {
        EdgeList { n, edges: Vec::new() }
    }

    pub fn with_capacity(n: usize, m: usize) -> Self {
        EdgeList { n, edges: Vec::with_capacity(m) }
    }

    /// Build from parts, validating node bounds.
    pub fn from_edges(n: usize, edges: Vec<(NodeId, NodeId)>) -> Result<Self> {
        for &(s, d) in &edges {
            if s as usize >= n || d as usize >= n {
                anyhow::bail!("edge ({s}, {d}) out of bounds for n={n}");
            }
        }
        Ok(EdgeList { n, edges })
    }

    /// Add one edge. Panics on out-of-bounds in debug builds.
    #[inline]
    pub fn push(&mut self, src: NodeId, dst: NodeId) {
        debug_assert!((src as usize) < self.n && (dst as usize) < self.n);
        self.edges.push((src, dst));
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Number of edges including duplicates.
    pub fn len(&self) -> usize {
        self.edges.len()
    }

    pub fn is_empty(&self) -> bool {
        self.edges.is_empty()
    }

    pub fn edges(&self) -> &[(NodeId, NodeId)] {
        &self.edges
    }

    pub fn into_edges(self) -> Vec<(NodeId, NodeId)> {
        self.edges
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_edges_validates_bounds() {
        assert!(EdgeList::from_edges(2, vec![(0, 1), (1, 0)]).is_ok());
        assert!(EdgeList::from_edges(2, vec![(0, 2)]).is_err());
    }

    #[test]
    fn push_and_len() {
        let mut e = EdgeList::new(3);
        assert!(e.is_empty());
        e.push(0, 1);
        e.push(1, 2);
        assert_eq!(e.len(), 2);
        assert_eq!(e.edges(), &[(0, 1), (1, 2)]);
    }
}
