//! Synthetic web-graph generators.
//!
//! The paper evaluates on the Stanford-Web crawl (n = 281,903,
//! nnz = 2,312,497, 172 dangling). Without the original file we match
//! its *statistics*: [`stanford_web_like`] produces a directed graph
//! with the same node count, edge count (±0.5 %), dangling count, and
//! the power-law in-degree / out-degree laws reported for the web by
//! Broder et al. (paper ref [10]: in-degree exponent ≈ 2.1 with a
//! heavy tail, out-degree ≈ 2.72 and more concentrated). Convergence
//! speed of PageRank depends on α and on this degree structure, so the
//! substitution preserves the phenomena Tables 1–2 measure (DESIGN.md §3).
//!
//! Also provided: Erdős–Rényi (uniform null model), Broder-style
//! bow-tie (SCC/IN/OUT macro-structure), and pathological chain/star
//! graphs used by property tests.

use super::{EdgeList, NodeId};
use crate::util::Rng;

/// Parameters for [`power_law_web`].
#[derive(Debug, Clone)]
pub struct WebParams {
    pub n: usize,
    /// Target edge count (approximate, ±1 %).
    pub m: usize,
    /// Number of dangling pages (exact).
    pub dangling: usize,
    /// Out-degree power-law exponent (Broder: ≈ 2.72).
    pub gamma_out: f64,
    /// In-degree power-law exponent (Broder: ≈ 2.1).
    pub gamma_in: f64,
    /// Max out-degree cap (crawler politeness caps real data too).
    pub max_out: usize,
    /// Probability that a link is reciprocated (site-internal links in
    /// real crawls are heavily bidirectional; this plus `chain_frac`
    /// produces the slow mixing that gives the paper's ~44 power
    /// iterations at tol=1e-6 — a pure Chung–Lu graph is an expander
    /// and converges in ~15).
    pub reciprocity: f64,
    /// Fraction of pages arranged in next-page navigational chains.
    pub chain_frac: f64,
    /// Fraction of pages arranged as pure mutual-link pairs (page ↔
    /// same-site page with no other outlinks). These are the α-rate
    /// Jacobi eigenmodes that set real-web power-method iteration
    /// counts (~44 at 1e-6 for Stanford-Web) and that Gauss–Seidel
    /// resolves at α² per sweep — reproducing the classic ≈2× GS gain.
    pub couple_frac: f64,
}

impl WebParams {
    /// The Stanford-Web matrix of the paper, §5.2.
    pub fn stanford() -> WebParams {
        WebParams {
            n: 281_903,
            m: 2_312_497,
            dangling: 172,
            gamma_out: 2.72,
            gamma_in: 2.1,
            max_out: 255,
            reciprocity: 0.35,
            chain_frac: 0.08,
            couple_frac: 0.012,
        }
    }

    /// Scaled-down variant with the same shape (for tests/examples).
    pub fn scaled(n: usize) -> WebParams {
        let s = WebParams::stanford();
        let ratio = n as f64 / s.n as f64;
        WebParams {
            n,
            m: ((s.m as f64) * ratio) as usize,
            dangling: ((s.dangling as f64) * ratio).ceil() as usize,
            max_out: s.max_out.min(n.saturating_sub(1)).max(1),
            ..s
        }
    }
}

/// Power-law directed web graph.
///
/// Construction: (1) draw out-degrees from a power law, rescale to hit
/// the target edge count, zero out `dangling` randomly chosen pages;
/// (2) draw in-degree attractiveness weights from a second power law
/// and connect each out-slot to a target sampled ∝ weight (a static
/// preferential-attachment / Chung-Lu scheme). Self-loops allowed,
/// duplicates later collapsed by CSR (matching crawl semantics).
pub fn power_law_web(p: &WebParams, seed: u64) -> EdgeList {
    assert!(p.dangling <= p.n);
    let mut rng = Rng::new(seed);

    // --- out-degrees ---
    let mut outdeg: Vec<usize> = (0..p.n)
        .map(|_| rng.power_law(1.0, p.max_out as f64, p.gamma_out).round() as usize)
        .map(|d| d.clamp(1, p.max_out))
        .collect();
    // dangling pages: pick distinct indices, zero them
    let dang_idx = rng.sample_distinct(p.n, p.dangling);
    for &i in &dang_idx {
        outdeg[i] = 0;
    }
    // rescale out-slots so that slots + expected reciprocal copies hit
    // the target edge count: S = (m + r*chain)/(1+r), where chain links
    // are never reciprocated.
    let chain_nodes_est = ((p.n as f64) * p.chain_frac) as usize;
    let target_slots =
        (p.m as f64 + p.reciprocity * chain_nodes_est as f64) / (1.0 + p.reciprocity);
    let total: usize = outdeg.iter().sum();
    let scale = target_slots / total.max(1) as f64;
    let mut m_acc = 0usize;
    for (i, d) in outdeg.iter_mut().enumerate() {
        if *d > 0 {
            let scaled = ((*d as f64) * scale).round() as usize;
            *d = scaled.clamp(1, p.max_out.max(1));
        }
        m_acc += *d;
        let _ = i;
    }

    // --- in-degree attractiveness (Chung–Lu weights) ---
    // cumulative weight table for O(log n) sampling
    let mut cum = Vec::with_capacity(p.n);
    let mut acc = 0.0f64;
    for _ in 0..p.n {
        acc += rng.power_law(1.0, p.n as f64 / 10.0, p.gamma_in);
        cum.push(acc);
    }
    let total_w = acc;

    // --- navigational chains (site page sequences) ---
    // chain pages consume one out-slot for the next-page link; the
    // remaining slots still point power-law. Chains are what slows
    // mixing down to real-web levels (they propagate rank one hop per
    // iteration).
    // Node-range layout: [0, couples) mutual pairs, [couples,
    // couples+chains) navigational chains, rest power-law. Dangling
    // pages were already planted uniformly; pages in the special
    // ranges with outdeg 0 stay dangling.
    let couple_nodes = (((p.n as f64) * p.couple_frac) as usize) & !1usize; // even
    let chain_nodes = ((p.n as f64) * p.chain_frac) as usize;
    let chain_lo = couple_nodes;
    let chain_hi = (couple_nodes + chain_nodes).min(p.n);
    let chain_len = 12usize.min(p.n.max(2) - 1).max(2);

    let mut el = EdgeList::with_capacity(p.n, m_acc + chain_nodes + couple_nodes);
    for (src, &d) in outdeg.iter().enumerate() {
        if d == 0 {
            continue; // dangling page
        }
        if src < couple_nodes {
            // pure mutual pair: 2k <-> 2k+1, single outlink each
            let partner = src ^ 1;
            el.push(src as NodeId, partner as NodeId);
            continue;
        }
        if (chain_lo..chain_hi).contains(&src) {
            // pure navigational page: single next-page link; the chain
            // TERMINATES into a power-law target (no wrap — terminated
            // chains are transient modes, wrapped cycles would be
            // α-rate modes GS cannot accelerate).
            let pos = src - chain_lo;
            let next = if (pos + 1) % chain_len != 0 && src + 1 < chain_hi {
                src + 1
            } else {
                let t = rng.f64() * total_w;
                cum.partition_point(|&c| c < t).min(p.n - 1)
            };
            el.push(src as NodeId, next as NodeId);
            continue;
        }
        let budget = d;
        for _ in 0..budget {
            let t = rng.f64() * total_w;
            let dst = cum.partition_point(|&c| c < t).min(p.n - 1);
            el.push(src as NodeId, dst as NodeId);
            // reciprocate site-internal style links
            if rng.chance(p.reciprocity) && outdeg[dst] > 0 {
                el.push(dst as NodeId, src as NodeId);
            }
        }
    }
    el
}

/// The paper's experimental graph (statistics-matched substitute).
pub fn stanford_web_like(seed: u64) -> EdgeList {
    power_law_web(&WebParams::stanford(), seed)
}

/// Erdős–Rényi G(n, m): uniform null model for ablations.
pub fn erdos_renyi(n: usize, m: usize, seed: u64) -> EdgeList {
    let mut rng = Rng::new(seed);
    let mut el = EdgeList::with_capacity(n, m);
    for _ in 0..m {
        el.push(rng.range(0, n) as NodeId, rng.range(0, n) as NodeId);
    }
    el
}

/// Broder-style bow-tie: a strongly connected core (SCC), an IN set
/// that reaches the core, an OUT set reached from it, plus tendrils
/// (mostly dangling). Fractions follow Broder et al.'s measurements
/// (roughly 28 % SCC / 21 % IN / 21 % OUT / 30 % other).
pub fn bow_tie(n: usize, avg_deg: usize, seed: u64) -> EdgeList {
    let mut rng = Rng::new(seed);
    let scc = n * 28 / 100;
    let in_n = n * 21 / 100;
    let out_n = n * 21 / 100;
    let scc_lo = 0;
    let in_lo = scc; // [scc, scc+in_n)
    let out_lo = scc + in_n; // [.., ..+out_n)
    let rest_lo = scc + in_n + out_n;

    let mut el = EdgeList::with_capacity(n, n * avg_deg);
    // SCC: ring + random chords (strong connectivity by construction)
    for i in 0..scc {
        el.push((scc_lo + i) as NodeId, (scc_lo + (i + 1) % scc) as NodeId);
        for _ in 0..avg_deg.saturating_sub(1) {
            el.push((scc_lo + i) as NodeId, (scc_lo + rng.range(0, scc)) as NodeId);
        }
    }
    // IN: points into SCC
    for i in 0..in_n {
        for _ in 0..avg_deg.max(1) {
            el.push((in_lo + i) as NodeId, (scc_lo + rng.range(0, scc)) as NodeId);
        }
    }
    // OUT: pointed at from SCC; OUT pages link among OUT or dangle
    for i in 0..out_n {
        el.push((scc_lo + rng.range(0, scc)) as NodeId, (out_lo + i) as NodeId);
        if rng.chance(0.5) {
            el.push((out_lo + i) as NodeId, (out_lo + rng.range(0, out_n)) as NodeId);
        }
    }
    // tendrils/disconnected: half link somewhere random, half dangle
    for i in rest_lo..n {
        if rng.chance(0.5) {
            el.push(i as NodeId, rng.range(0, n) as NodeId);
        }
    }
    el
}

/// R-MAT / Kronecker-style recursive generator (Chakrabarti et al.):
/// each edge picks a quadrant of the adjacency matrix recursively with
/// probabilities (a, b, c, d). The standard web-like setting
/// (0.57, 0.19, 0.19, 0.05) produces the skew + community structure
/// real crawls show; used by the generator-sensitivity ablation.
pub fn rmat(scale: u32, m: usize, probs: (f64, f64, f64, f64), seed: u64) -> EdgeList {
    let n = 1usize << scale;
    let mut el = EdgeList::with_capacity(n, m);
    for (s, d) in rmat_edges(scale, m, probs, seed) {
        el.push(s, d);
    }
    el
}

/// The standard web-like R-MAT quadrant probabilities
/// (Chakrabarti et al.) — what the giant-graph bench and the `rmat:`
/// graph spec use.
pub const RMAT_WEB_PROBS: (f64, f64, f64, f64) = (0.57, 0.19, 0.19, 0.05);

/// Streaming form of [`rmat`]: yields the exact same edge sequence
/// (same seed, same generator draws), one record at a time, so a giant
/// instance can pipe straight to disk through
/// [`io::save_edgelist_bin_iter`](crate::graph::io::save_edgelist_bin_iter)
/// without ever materializing the `Vec<(src, dst)>` — the O(m) edge
/// buffer is exactly what the giant-graph memory tier must avoid.
pub fn rmat_edges(scale: u32, m: usize, probs: (f64, f64, f64, f64), seed: u64) -> RmatEdges {
    let (a, b, c, d) = probs;
    assert!((a + b + c + d - 1.0).abs() < 1e-9, "quadrant probs must sum to 1");
    RmatEdges { n: 1usize << scale, probs, rng: Rng::new(seed), remaining: m }
}

/// Iterator behind [`rmat_edges`]. Each `next` runs one quadrant
/// descent — `scale` uniform draws per edge.
#[derive(Debug, Clone)]
pub struct RmatEdges {
    n: usize,
    probs: (f64, f64, f64, f64),
    rng: Rng,
    remaining: usize,
}

impl Iterator for RmatEdges {
    type Item = (NodeId, NodeId);

    fn next(&mut self) -> Option<(NodeId, NodeId)> {
        if self.remaining == 0 {
            return None;
        }
        self.remaining -= 1;
        let (a, b, c, _) = self.probs;
        let (mut r0, mut r1, mut c0, mut c1) = (0usize, self.n, 0usize, self.n);
        while r1 - r0 > 1 {
            let u = self.rng.f64();
            let (top, left) = if u < a {
                (true, true)
            } else if u < a + b {
                (true, false)
            } else if u < a + b + c {
                (false, true)
            } else {
                (false, false)
            };
            let rm = (r0 + r1) / 2;
            let cm = (c0 + c1) / 2;
            if top {
                r1 = rm;
            } else {
                r0 = rm;
            }
            if left {
                c1 = cm;
            } else {
                c0 = cm;
            }
        }
        Some((r0 as NodeId, c0 as NodeId))
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        (self.remaining, Some(self.remaining))
    }
}

impl ExactSizeIterator for RmatEdges {}

/// Parameters for the crawl-like update stream ([`churn_batch`]).
///
/// Models what successive crawls of a living web region observe:
/// *arrivals* (new pages, linking out immediately — preferential
/// attachment, the mechanism behind the in-degree power law) and *link
/// churn* (existing pages gaining and losing links as sites are
/// edited). Defaults follow [`ChurnParams::scaled_to`], which sizes one
/// epoch to roughly half a percent of the graph.
#[derive(Debug, Clone)]
pub struct ChurnParams {
    /// New pages per epoch (each born with out-links).
    pub arrivals: usize,
    /// Out-links per arriving page.
    pub links_per_arrival: usize,
    /// New links between existing pages per epoch.
    pub churn_inserts: usize,
    /// Existing links deleted per epoch.
    pub churn_removes: usize,
    /// Probability a link target is chosen ∝ in-degree (preferential
    /// attachment) instead of uniformly.
    pub pref_attach: f64,
}

impl ChurnParams {
    /// Epoch sized to a graph with `n` nodes / `m` edges: ~0.1 % node
    /// arrivals and ~0.5 % edge churn, the "small change between
    /// crawls" regime where incremental recomputation should win big.
    pub fn scaled_to(n: usize, m: usize) -> ChurnParams {
        ChurnParams {
            arrivals: (n / 1000).max(1),
            links_per_arrival: 8,
            churn_inserts: (m / 400).max(4),
            churn_removes: (m / 800).max(2),
            pref_attach: 0.8,
        }
    }
}

/// Generate one epoch's [`UpdateBatch`](crate::stream::UpdateBatch) of
/// crawl-like mutations against the current graph state.
///
/// Deterministic given the `rng` stream. Arriving pages get
/// `links_per_arrival` out-links to (mostly) degree-proportional
/// targets and, with probability ½, one in-link from a random existing
/// page (so newcomers can accrue rank). Churn removals are sampled
/// uniformly from the current edge set — deleting a page's last
/// out-link legitimately makes it dangling, which the incremental
/// solver must absorb.
pub fn churn_batch(
    g: &crate::stream::DeltaGraph,
    p: &ChurnParams,
    rng: &mut Rng,
) -> crate::stream::UpdateBatch {
    let n0 = g.n();
    assert!(n0 > 0, "churn_batch on an empty graph");
    // flatten the current edges once: uniform-edge sampling gives a
    // degree-proportional *target* distribution (each edge nominates
    // its destination), the standard preferential-attachment trick
    let mut edges: Vec<(NodeId, NodeId)> = Vec::with_capacity(g.m());
    g.for_each_edge(|s, d| edges.push((s, d)));

    let mut batch = crate::stream::UpdateBatch {
        new_nodes: p.arrivals,
        insert: Vec::new(),
        remove: Vec::new(),
    };
    let mut pick_target = |rng: &mut Rng| -> NodeId {
        if !edges.is_empty() && rng.chance(p.pref_attach) {
            edges[rng.range(0, edges.len())].1
        } else {
            rng.range(0, n0) as NodeId
        }
    };

    // arrivals: out-links immediately, maybe one in-link
    for j in 0..p.arrivals {
        let newcomer = (n0 + j) as NodeId;
        for _ in 0..p.links_per_arrival {
            batch.insert.push((newcomer, pick_target(rng)));
        }
        if rng.chance(0.5) {
            batch.insert.push((rng.range(0, n0) as NodeId, newcomer));
        }
    }
    // link churn among existing pages
    for _ in 0..p.churn_inserts {
        let src = rng.range(0, n0) as NodeId;
        batch.insert.push((src, pick_target(rng)));
    }
    if !edges.is_empty() {
        let k = p.churn_removes.min(edges.len());
        for idx in rng.sample_distinct(edges.len(), k) {
            batch.remove.push(edges[idx]);
        }
    }
    batch
}

/// Directed chain 0→1→…→n-1 (last node dangling). Worst case for
/// information propagation; property tests use it.
pub fn chain(n: usize) -> EdgeList {
    let mut el = EdgeList::with_capacity(n, n.saturating_sub(1));
    for i in 0..n.saturating_sub(1) {
        el.push(i as NodeId, (i + 1) as NodeId);
    }
    el
}

/// Star: all leaves point at the hub (node 0), hub dangles.
pub fn star(n: usize) -> EdgeList {
    let mut el = EdgeList::with_capacity(n, n.saturating_sub(1));
    for i in 1..n {
        el.push(i as NodeId, 0);
    }
    el
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Csr;

    #[test]
    fn scaled_params_preserve_density() {
        let p = WebParams::scaled(28_190); // 1/10 scale
        assert_eq!(p.n, 28_190);
        assert!((p.m as f64 / p.n as f64 - 8.2).abs() < 0.3); // stanford avg deg
        assert!(p.dangling >= 12);
    }

    #[test]
    fn power_law_web_matches_targets() {
        let p = WebParams::scaled(20_000);
        let el = power_law_web(&p, 1);
        let g = Csr::from_edgelist(&el).unwrap();
        assert_eq!(g.n(), p.n);
        // raw edge count within 10% of target (dedup removes a few)
        let err = (g.nnz() as f64 - p.m as f64).abs() / p.m as f64;
        assert!(err < 0.10, "nnz {} target {} err {err}", g.nnz(), p.m);
        // dangling: exactly the planted ones (collisions could in theory
        // add more, but planted pages never emit edges)
        assert!(g.dangling().len() >= p.dangling);
        assert!(g.dangling().len() <= p.dangling + p.n / 100);
    }

    #[test]
    fn power_law_web_heavy_tail() {
        let p = WebParams::scaled(20_000);
        let el = power_law_web(&p, 2);
        let g = Csr::from_edgelist(&el).unwrap();
        // in-degree tail: max in-degree far above the mean
        let max_in = (0..g.n()).map(|i| g.row_len(i)).max().unwrap();
        let mean_in = g.nnz() as f64 / g.n() as f64;
        assert!(
            max_in as f64 > 10.0 * mean_in,
            "no heavy tail: max {max_in} mean {mean_in}"
        );
    }

    #[test]
    fn deterministic_per_seed() {
        let p = WebParams::scaled(5_000);
        assert_eq!(power_law_web(&p, 7), power_law_web(&p, 7));
        assert_ne!(power_law_web(&p, 7), power_law_web(&p, 8));
    }

    #[test]
    fn erdos_renyi_edge_count() {
        let el = erdos_renyi(1000, 5000, 3);
        assert_eq!(el.len(), 5000);
    }

    #[test]
    fn bow_tie_in_reaches_scc_out_doesnt_feed_back() {
        let el = bow_tie(1000, 3, 4);
        let n = 1000;
        let scc = n * 28 / 100;
        let in_lo = scc;
        let in_hi = scc + n * 21 / 100;
        let out_lo = in_hi;
        let out_hi = out_lo + n * 21 / 100;
        for &(s, d) in el.edges() {
            let (s, d) = (s as usize, d as usize);
            if (in_lo..in_hi).contains(&s) {
                assert!(d < scc, "IN page {s} links outside SCC");
            }
            if (out_lo..out_hi).contains(&s) {
                assert!(
                    (out_lo..out_hi).contains(&d),
                    "OUT page {s} links back to {d}"
                );
            }
        }
    }

    #[test]
    fn rmat_shapes_and_skew() {
        let el = rmat(12, 40_000, (0.57, 0.19, 0.19, 0.05), 5);
        assert_eq!(el.n(), 1 << 12);
        assert_eq!(el.len(), 40_000);
        let g = Csr::from_edgelist(&el).unwrap();
        // R-MAT with skewed quadrants concentrates edges: max in-degree
        // far above the mean
        let max_in = (0..g.n()).map(|i| g.row_len(i)).max().unwrap();
        let mean = g.nnz() as f64 / g.n() as f64;
        assert!(max_in as f64 > 8.0 * mean, "max {max_in} mean {mean}");
        // deterministic
        assert_eq!(el, rmat(12, 40_000, (0.57, 0.19, 0.19, 0.05), 5));
    }

    #[test]
    #[should_panic(expected = "sum to 1")]
    fn rmat_rejects_bad_probs() {
        rmat(4, 10, (0.5, 0.2, 0.2, 0.2), 1);
    }

    #[test]
    fn rmat_edges_streams_the_same_sequence() {
        let el = rmat(10, 5_000, RMAT_WEB_PROBS, 42);
        let it = rmat_edges(10, 5_000, RMAT_WEB_PROBS, 42);
        assert_eq!(it.len(), 5_000);
        let streamed: Vec<_> = it.collect();
        assert_eq!(el.edges(), &streamed[..]);
    }

    #[test]
    fn churn_batch_is_deterministic_and_in_bounds() {
        use crate::stream::DeltaGraph;
        let el = power_law_web(&WebParams::scaled(3_000), 20);
        let g = DeltaGraph::from_edgelist(&el);
        let p = ChurnParams::scaled_to(g.n(), g.m());
        let a = churn_batch(&g, &p, &mut crate::util::Rng::new(5));
        let b = churn_batch(&g, &p, &mut crate::util::Rng::new(5));
        assert_eq!(a, b, "same rng stream, same batch");
        let c = churn_batch(&g, &p, &mut crate::util::Rng::new(6));
        assert_ne!(a, c);
        assert_eq!(a.new_nodes, p.arrivals);
        // applying must succeed: every endpoint within n + arrivals
        let mut g2 = g.clone();
        let d = g2.apply(&a).unwrap();
        assert_eq!(d.new_n, g.n() + p.arrivals);
        assert!(d.inserted > 0 && d.removed > 0);
        // removals were sampled from real edges
        for &(s, t) in &a.remove {
            assert!(g.has_edge(s, t), "({s},{t}) not in the pre-batch graph");
        }
    }

    #[test]
    fn churn_targets_skew_preferential() {
        use crate::stream::DeltaGraph;
        let el = power_law_web(&WebParams::scaled(3_000), 21);
        let g = DeltaGraph::from_edgelist(&el);
        let csr = Csr::from_edgelist(&el).unwrap();
        let mean_in = csr.nnz() as f64 / csr.n() as f64;
        let p = ChurnParams {
            churn_inserts: 2_000,
            arrivals: 0,
            churn_removes: 0,
            pref_attach: 1.0,
            links_per_arrival: 0,
        };
        let batch = churn_batch(&g, &p, &mut crate::util::Rng::new(7));
        // fully preferential targets land on high in-degree pages far
        // more often than uniform would
        let avg_target_indeg: f64 = batch
            .insert
            .iter()
            .map(|&(_, t)| csr.row_len(t as usize) as f64)
            .sum::<f64>()
            / batch.insert.len() as f64;
        assert!(
            avg_target_indeg > 3.0 * mean_in,
            "avg target in-degree {avg_target_indeg} vs mean {mean_in}"
        );
    }

    #[test]
    fn chain_and_star_shapes() {
        let c = Csr::from_edgelist(&chain(5)).unwrap();
        assert_eq!(c.dangling(), &[4]);
        assert_eq!(c.nnz(), 4);
        let s = Csr::from_edgelist(&star(5)).unwrap();
        assert_eq!(s.dangling(), &[0]);
        assert_eq!(s.row_len(0), 4);
    }
}
