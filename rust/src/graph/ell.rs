//! Padded ELLPACK layout with virtual-row splitting — the accelerator
//! (artifact) layout. See DESIGN.md §Hardware-Adaptation.
//!
//! The Pallas kernel wants a static `(rows, K)` tile. Web graphs are
//! heavy-tailed: most pages have a handful of in-links, a few have
//! thousands. Padding every row to the max in-degree would explode
//! memory, so rows with more than `K` entries are split into several
//! *virtual rows*; after the kernel runs, virtual-row partial sums are
//! folded back into their parent row on the host (a cheap O(#virtual)
//! pass). The mapping is recorded in `owner`.

use super::{Csr, NodeId};

/// A whole matrix (or row range) in padded ELL form.
#[derive(Debug, Clone, PartialEq)]
pub struct Ell {
    /// Padded slots per (virtual) row.
    width: usize,
    /// ELL values, `rows * width`, row-major; padded slots are 0.0.
    vals: Vec<f32>,
    /// ELL column indices; padded slots point at 0 (their val is 0).
    cols: Vec<NodeId>,
    /// For each virtual row, the LOGICAL row (within the range) whose
    /// sum it contributes to. Monotone non-decreasing.
    owner: Vec<u32>,
    /// Logical rows covered.
    logical_rows: usize,
}

/// One UE's block: the ELL rows for logical rows [row_lo, row_hi).
#[derive(Debug, Clone)]
pub struct EllBlock {
    pub row_lo: usize,
    pub row_hi: usize,
    pub ell: Ell,
}

impl Ell {
    /// Convert rows [row_lo, row_hi) of a CSR matrix, splitting rows
    /// longer than `width` into virtual rows.
    pub fn from_csr_range(csr: &Csr, row_lo: usize, row_hi: usize, width: usize) -> Ell {
        assert!(width > 0, "ELL width must be positive");
        assert!(row_lo <= row_hi && row_hi <= csr.n());
        let logical_rows = row_hi - row_lo;
        // count virtual rows first to allocate exactly
        let mut vrows = 0usize;
        for i in row_lo..row_hi {
            vrows += csr.row_len(i).div_ceil(width).max(1);
        }
        let mut vals = vec![0.0f32; vrows * width];
        let mut cols = vec![0 as NodeId; vrows * width];
        let mut owner = Vec::with_capacity(vrows);
        let mut vr = 0usize;
        for i in row_lo..row_hi {
            let (rcols, rvals) = csr.row(i);
            let chunks = rcols.len().div_ceil(width).max(1);
            for c in 0..chunks {
                let lo = c * width;
                let hi = (lo + width).min(rcols.len());
                let base = vr * width;
                if hi > lo {
                    vals[base..base + (hi - lo)].copy_from_slice(&rvals[lo..hi]);
                    cols[base..base + (hi - lo)].copy_from_slice(&rcols[lo..hi]);
                }
                owner.push((i - row_lo) as u32);
                vr += 1;
            }
        }
        debug_assert_eq!(vr, vrows);
        Ell { width, vals, cols, owner, logical_rows }
    }

    /// Convert a whole CSR matrix.
    pub fn from_csr(csr: &Csr, width: usize) -> Ell {
        Ell::from_csr_range(csr, 0, csr.n(), width)
    }

    pub fn width(&self) -> usize {
        self.width
    }

    /// Number of virtual rows (what the kernel sees).
    pub fn virtual_rows(&self) -> usize {
        self.owner.len()
    }

    /// Number of logical rows (what the iteration sees).
    pub fn logical_rows(&self) -> usize {
        self.logical_rows
    }

    /// Row expansion factor virtual/logical (1.0 = no splitting).
    pub fn expansion(&self) -> f64 {
        self.virtual_rows() as f64 / self.logical_rows.max(1) as f64
    }

    pub fn vals(&self) -> &[f32] {
        &self.vals
    }

    pub fn cols(&self) -> &[NodeId] {
        &self.cols
    }

    pub fn owner(&self) -> &[u32] {
        &self.owner
    }

    /// Fold virtual-row results `vy` (len = virtual_rows) into logical
    /// rows: `out[owner[v]] += vy[v]`. `out` must be zeroed by the caller.
    pub fn fold_virtual(&self, vy: &[f32], out: &mut [f32]) {
        debug_assert_eq!(vy.len(), self.virtual_rows());
        debug_assert_eq!(out.len(), self.logical_rows);
        for (v, &o) in vy.iter().zip(&self.owner) {
            out[o as usize] += v;
        }
    }

    /// Host-side ELL SpMV over the virtual rows (native twin of the
    /// Pallas kernel; used for cross-validation and as CPU fallback).
    pub fn spmv_virtual(&self, x: &[f32], vy: &mut [f32]) {
        debug_assert_eq!(vy.len(), self.virtual_rows());
        for (r, out) in vy.iter_mut().enumerate() {
            let base = r * self.width;
            let mut acc = 0.0f32;
            for s in 0..self.width {
                acc += self.vals[base + s] * x[self.cols[base + s] as usize];
            }
            *out = acc;
        }
    }

    /// Full logical SpMV: kernel + fold.
    pub fn spmv(&self, x: &[f32], y: &mut [f32]) {
        let mut vy = vec![0.0f32; self.virtual_rows()];
        self.spmv_virtual(x, &mut vy);
        y.iter_mut().for_each(|v| *v = 0.0);
        self.fold_virtual(&vy, y);
    }
}

impl EllBlock {
    /// Build the block for logical rows [row_lo, row_hi).
    pub fn new(csr: &Csr, row_lo: usize, row_hi: usize, width: usize) -> EllBlock {
        EllBlock { row_lo, row_hi, ell: Ell::from_csr_range(csr, row_lo, row_hi, width) }
    }

    pub fn logical_rows(&self) -> usize {
        self.row_hi - self.row_lo
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::EdgeList;
    use crate::util::Rng;

    fn toy() -> Csr {
        let el =
            EdgeList::from_edges(4, vec![(0, 1), (0, 2), (1, 2), (2, 0)]).unwrap();
        Csr::from_edgelist(&el).unwrap()
    }

    #[test]
    fn ell_matches_csr_spmv() {
        let g = toy();
        let ell = Ell::from_csr(&g, 2);
        let x = [0.1f32, 0.2, 0.3, 0.4];
        let mut y_csr = [0.0f32; 4];
        let mut y_ell = [0.0f32; 4];
        g.spmv(&x, &mut y_csr);
        ell.spmv(&x, &mut y_ell);
        for (a, b) in y_csr.iter().zip(&y_ell) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn splits_long_rows() {
        let g = toy();
        // width 1 forces the 2-entry row 2 to split into 2 virtual rows
        let ell = Ell::from_csr(&g, 1);
        assert_eq!(ell.logical_rows(), 4);
        assert_eq!(ell.virtual_rows(), 5); // rows: 1,1,2,1 entries -> 1+1+2+1
        assert!(ell.expansion() > 1.0);
        let x = [0.1f32, 0.2, 0.3, 0.4];
        let mut y1 = [0.0f32; 4];
        let mut y2 = [0.0f32; 4];
        g.spmv(&x, &mut y1);
        ell.spmv(&x, &mut y2);
        for (a, b) in y1.iter().zip(&y2) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn empty_rows_get_one_virtual_row() {
        let g = Csr::from_edgelist(&EdgeList::new(3)).unwrap();
        let ell = Ell::from_csr(&g, 4);
        assert_eq!(ell.virtual_rows(), 3);
        assert_eq!(ell.expansion(), 1.0);
        let mut y = [1.0f32; 3];
        ell.spmv(&[1.0, 1.0, 1.0], &mut y);
        assert_eq!(y, [0.0, 0.0, 0.0]);
    }

    #[test]
    fn range_blocks_tile_the_matrix() {
        let g = toy();
        let x = [0.3f32, 0.1, 0.4, 0.2];
        let mut full = [0.0f32; 4];
        g.spmv(&x, &mut full);
        for (lo, hi) in [(0, 2), (2, 4)] {
            let blk = EllBlock::new(&g, lo, hi, 2);
            let mut y = vec![0.0f32; hi - lo];
            blk.ell.spmv(&x, &mut y);
            for (a, b) in full[lo..hi].iter().zip(&y) {
                assert!((a - b).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn random_graphs_ell_equals_csr() {
        let mut rng = Rng::new(42);
        for trial in 0..10 {
            let n = 50 + trial * 13;
            let mut el = EdgeList::new(n);
            for _ in 0..n * 3 {
                el.push(rng.range(0, n) as u32, rng.range(0, n) as u32);
            }
            let g = Csr::from_edgelist(&el).unwrap();
            let width = 1 + trial % 5;
            let ell = Ell::from_csr(&g, width);
            let x: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
            let mut y1 = vec![0.0f32; n];
            let mut y2 = vec![0.0f32; n];
            g.spmv(&x, &mut y1);
            ell.spmv(&x, &mut y2);
            for (a, b) in y1.iter().zip(&y2) {
                assert!((a - b).abs() < 1e-4, "trial {trial}");
            }
        }
    }
}
