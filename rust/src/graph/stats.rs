//! Graph statistics: the numbers the paper reports about its dataset
//! ("281,903 pages, 2,312,497 non-zero elements, 172 dangling nodes")
//! plus degree-distribution summaries used to validate the generator.

use super::Csr;

/// Summary statistics of a (normalized, transposed) link matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct GraphStats {
    pub n: usize,
    pub nnz: usize,
    pub dangling: usize,
    pub avg_in_deg: f64,
    pub max_in_deg: usize,
    pub max_out_deg: usize,
    /// Gini coefficient of the in-degree distribution (0 = uniform,
    /// →1 = concentrated) — a scale-free web sits well above 0.5.
    pub in_deg_gini: f64,
}

impl GraphStats {
    pub fn compute(g: &Csr) -> GraphStats {
        let n = g.n();
        let mut in_degs: Vec<usize> = (0..n).map(|i| g.row_len(i)).collect();
        let max_in_deg = in_degs.iter().copied().max().unwrap_or(0);
        let max_out_deg = g.outdeg().iter().copied().max().unwrap_or(0) as usize;
        let nnz = g.nnz();
        let avg_in_deg = nnz as f64 / n.max(1) as f64;

        // Gini over in-degrees
        in_degs.sort_unstable();
        let total: f64 = in_degs.iter().map(|&d| d as f64).sum();
        let gini = if total > 0.0 && n > 1 {
            let weighted: f64 = in_degs
                .iter()
                .enumerate()
                .map(|(i, &d)| (i as f64 + 1.0) * d as f64)
                .sum();
            (2.0 * weighted) / (n as f64 * total) - (n as f64 + 1.0) / n as f64
        } else {
            0.0
        };

        GraphStats {
            n,
            nnz,
            dangling: g.dangling().len(),
            avg_in_deg,
            max_in_deg,
            max_out_deg,
            in_deg_gini: gini,
        }
    }

    /// One-line report, paper-style.
    pub fn report(&self) -> String {
        format!(
            "n={} nnz={} dangling={} avg_in={:.2} max_in={} max_out={} gini={:.3}",
            self.n,
            self.nnz,
            self.dangling,
            self.avg_in_deg,
            self.max_in_deg,
            self.max_out_deg,
            self.in_deg_gini
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generators, EdgeList};

    #[test]
    fn toy_stats() {
        let el = EdgeList::from_edges(4, vec![(0, 1), (0, 2), (1, 2), (2, 0)]).unwrap();
        let g = Csr::from_edgelist(&el).unwrap();
        let s = GraphStats::compute(&g);
        assert_eq!(s.n, 4);
        assert_eq!(s.nnz, 4);
        assert_eq!(s.dangling, 1);
        assert_eq!(s.max_in_deg, 2);
        assert_eq!(s.max_out_deg, 2);
        assert!(s.report().contains("n=4"));
    }

    #[test]
    fn uniform_graph_low_gini_web_graph_high_gini() {
        let er = Csr::from_edgelist(&generators::erdos_renyi(5000, 40_000, 1)).unwrap();
        let web = Csr::from_edgelist(&generators::power_law_web(
            &generators::WebParams::scaled(5000),
            1,
        ))
        .unwrap();
        let s_er = GraphStats::compute(&er);
        let s_web = GraphStats::compute(&web);
        assert!(
            s_web.in_deg_gini > s_er.in_deg_gini + 0.1,
            "web gini {} should exceed ER gini {}",
            s_web.in_deg_gini,
            s_er.in_deg_gini
        );
    }

    #[test]
    fn empty_graph_stats() {
        let g = Csr::from_edgelist(&EdgeList::new(2)).unwrap();
        let s = GraphStats::compute(&g);
        assert_eq!(s.nnz, 0);
        assert_eq!(s.in_deg_gini, 0.0);
    }
}
