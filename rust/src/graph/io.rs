//! Graph IO: text edge lists (SNAP style, what the Stanford-Web data
//! ships as), and a compact binary format for fast reload of generated
//! graphs.
//!
//! Two readers share the binary format. [`load_edgelist_bin`]
//! materializes the whole `Vec<(src, dst)>` — fine at test scales.
//! [`stream_csr_from_bin`] is the giant-graph memory tier: two chunked
//! streaming passes (count, then place) build the transposed CSR
//! directly, so peak RSS during construction is the CSR arrays plus
//! O(n) bookkeeping — never an 8-byte-per-edge list on top. Its failure
//! modes are the typed [`BinGraphError`], so ingestion pipelines can
//! match on *what* broke instead of grepping message strings.

use std::io::{BufRead, BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::Path;

use anyhow::Context;

use super::{Csr, EdgeList, NodeId};
use crate::Result;

/// Load a SNAP-style text edge list: one `src dst` (or `src\tdst`) pair
/// per line; `#`-prefixed lines are comments. Node ids must be < n if
/// `n` is given, otherwise n = max id + 1.
pub fn load_edgelist_text(path: impl AsRef<Path>, n: Option<usize>) -> Result<EdgeList> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening {}", path.as_ref().display()))?;
    let mut edges: Vec<(NodeId, NodeId)> = Vec::new();
    let mut max_id: NodeId = 0;
    for (lineno, line) in BufReader::new(f).lines().enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        let mut it = t.split_whitespace();
        let parse = |s: Option<&str>| -> Result<NodeId> {
            s.context("missing field")?
                .parse::<NodeId>()
                .with_context(|| format!("line {}: bad node id", lineno + 1))
        };
        let s = parse(it.next())?;
        let d = parse(it.next())?;
        max_id = max_id.max(s).max(d);
        edges.push((s, d));
    }
    let n = n.unwrap_or(max_id as usize + 1);
    EdgeList::from_edges(n, edges)
}

/// Write a SNAP-style text edge list with a header comment.
pub fn save_edgelist_text(el: &EdgeList, path: impl AsRef<Path>) -> Result<()> {
    let f = std::fs::File::create(path.as_ref())?;
    let mut w = BufWriter::new(f);
    writeln!(w, "# asyncpr edge list: n={} m={}", el.n(), el.len())?;
    for &(s, d) in el.edges() {
        writeln!(w, "{s}\t{d}")?;
    }
    Ok(())
}

const BIN_MAGIC: &[u8; 8] = b"APRGRAPH";

/// Header size of the binary format: magic + u64 n + u64 m.
const BIN_HEADER: u64 = 8 + 8 + 8;

/// Typed failure modes of the binary-graph readers.
///
/// [`stream_csr_from_bin`] returns these directly; [`load_edgelist_bin`]
/// wraps them through `anyhow` (the vendored shim carries the `Display`
/// text, so the historical message substrings — "node-id space",
/// "size overflows", "truncated or corrupt" — survive for callers that
/// still grep).
#[derive(Debug)]
pub enum BinGraphError {
    /// Underlying I/O failure (open/stat/read).
    Io(std::io::Error),
    /// The file does not start with the `APRGRAPH` magic.
    BadMagic,
    /// Header `n` exceeds the u32 node-id space.
    OversizedN { n: u64 },
    /// Header `m` is so large the implied byte size overflows u64.
    SizeOverflow { m: u64 },
    /// Header `(n, m)` disagrees with the actual file length
    /// (truncated file, trailing garbage, or a lying header).
    SizeMismatch { n: u64, m: u64, want_len: u64, file_len: u64 },
    /// Edge record `record` (0-based) references a node id `>= n`.
    NodeOutOfRange { src: u32, dst: u32, n: u64, record: u64 },
    /// The header promises more edges than the forced compact u32
    /// rowptr tier can address (`m > u32::MAX`).
    CompactOverflow { m: u64 },
    /// A single node's streamed in-degree overflowed the u32 counter
    /// (only reachable with `>= 2^32` duplicate records to one node).
    DegreeOverflow { node: u32 },
}

impl std::fmt::Display for BinGraphError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BinGraphError::Io(e) => write!(f, "graph io: {e}"),
            BinGraphError::BadMagic => write!(f, "not an asyncpr graph file"),
            BinGraphError::OversizedN { n } => write!(
                f,
                "graph header claims n={n}, beyond the u32 node-id space (corrupt file?)"
            ),
            BinGraphError::SizeOverflow { m } => {
                write!(f, "graph header claims m={m} edges; size overflows")
            }
            BinGraphError::SizeMismatch { n, m, want_len, file_len } => write!(
                f,
                "graph file is {file_len} bytes but header (n={n}, m={m}) requires {want_len}: \
                 truncated or corrupt"
            ),
            BinGraphError::NodeOutOfRange { src, dst, n, record } => write!(
                f,
                "edge record {record} is ({src}, {dst}), outside the declared n={n}"
            ),
            BinGraphError::CompactOverflow { m } => write!(
                f,
                "graph header claims m={m} edges; the compact u32 row-pointer tier addresses \
                 at most {} — use the wide layout",
                u32::MAX
            ),
            BinGraphError::DegreeOverflow { node } => {
                write!(f, "node {node}: streamed in-degree overflows the u32 counter")
            }
        }
    }
}

impl std::error::Error for BinGraphError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            BinGraphError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for BinGraphError {
    fn from(e: std::io::Error) -> Self {
        BinGraphError::Io(e)
    }
}

/// Compact binary: magic, u64 n, u64 m, then m (u32,u32) LE pairs.
pub fn save_edgelist_bin(el: &EdgeList, path: impl AsRef<Path>) -> Result<()> {
    save_edgelist_bin_iter(path, el.n(), el.len() as u64, el.edges().iter().copied())
}

/// Write the binary format from an edge iterator without materializing
/// an edge list — the giant-graph generator path (an R-MAT stream pipes
/// straight to disk). The header carries `n` and the promised record
/// count `m` up front; the iterator must yield exactly `m` in-bounds
/// records (checked, so a lying iterator cannot produce a file the
/// readers would reject as corrupt).
pub fn save_edgelist_bin_iter(
    path: impl AsRef<Path>,
    n: usize,
    m: u64,
    edges: impl Iterator<Item = (NodeId, NodeId)>,
) -> Result<()> {
    let f = std::fs::File::create(path.as_ref())?;
    let mut w = BufWriter::new(f);
    w.write_all(BIN_MAGIC)?;
    w.write_all(&(n as u64).to_le_bytes())?;
    w.write_all(&m.to_le_bytes())?;
    let mut written = 0u64;
    for (s, d) in edges {
        anyhow::ensure!(
            (s as usize) < n && (d as usize) < n,
            "edge ({s}, {d}) out of bounds for n={n}"
        );
        let mut rec = [0u8; 8];
        rec[0..4].copy_from_slice(&s.to_le_bytes());
        rec[4..8].copy_from_slice(&d.to_le_bytes());
        w.write_all(&rec)?;
        written += 1;
    }
    anyhow::ensure!(
        written == m,
        "edge iterator yielded {written} records, header promised {m}"
    );
    w.flush()?;
    Ok(())
}

/// Read and sanity-check the 24-byte header: magic, `n` in the u32
/// node-id space, and an `m` whose byte size is representable. The
/// file-size agreement is checked separately ([`check_bin_size`]) so
/// callers can interpose checks that must precede it.
fn read_bin_header(r: &mut impl Read) -> std::result::Result<(u64, u64), BinGraphError> {
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != BIN_MAGIC {
        return Err(BinGraphError::BadMagic);
    }
    let mut u64buf = [0u8; 8];
    r.read_exact(&mut u64buf)?;
    let n = u64::from_le_bytes(u64buf);
    r.read_exact(&mut u64buf)?;
    let m = u64::from_le_bytes(u64buf);
    // node ids are u32, so any readable file has n <= 2^32
    if n > u64::from(u32::MAX) + 1 {
        return Err(BinGraphError::OversizedN { n });
    }
    m.checked_mul(8)
        .and_then(|b| b.checked_add(BIN_HEADER))
        .ok_or(BinGraphError::SizeOverflow { m })?;
    Ok((n, m))
}

/// Validate the header against the actual file length BEFORE any
/// `m`-sized allocation, so a corrupt or truncated file fails with a
/// readable error instead of attempting a massive reservation (a
/// 16-byte header flip could otherwise request exabytes).
fn check_bin_size(n: u64, m: u64, file_len: u64) -> std::result::Result<(), BinGraphError> {
    // the multiplication was overflow-checked by read_bin_header
    let want_len = m * 8 + BIN_HEADER;
    if want_len != file_len {
        return Err(BinGraphError::SizeMismatch { n, m, want_len, file_len });
    }
    Ok(())
}

/// Load the binary format written by [`save_edgelist_bin`].
pub fn load_edgelist_bin(path: impl AsRef<Path>) -> Result<EdgeList> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening {}", path.as_ref().display()))?;
    let file_len = f
        .metadata()
        .with_context(|| format!("stat {}", path.as_ref().display()))?
        .len();
    let mut r = BufReader::new(f);
    let (n, m) = read_bin_header(&mut r)?;
    check_bin_size(n, m, file_len)?;
    let n = n as usize;
    let m = m as usize;
    let mut edges = Vec::with_capacity(m);
    let mut pair = [0u8; 8];
    for _ in 0..m {
        r.read_exact(&mut pair)?;
        let s = u32::from_le_bytes(pair[0..4].try_into().unwrap());
        let d = u32::from_le_bytes(pair[4..8].try_into().unwrap());
        edges.push((s, d));
    }
    EdgeList::from_edges(n, edges)
}

/// Options for [`stream_csr_from_bin`].
#[derive(Debug, Clone)]
pub struct StreamCsrOptions {
    /// Force a row-pointer width: `Some(true)` requires the compact u32
    /// tier (typed [`BinGraphError::CompactOverflow`] if the header's
    /// `m` cannot fit), `Some(false)` forces the wide usize layout,
    /// `None` (the default) narrows automatically by nnz.
    pub compact: Option<bool>,
    /// Read-chunk size in bytes (default 1 MiB). Any value `>= 1`
    /// works: an edge record straddling a read boundary is carried
    /// into the next chunk.
    pub chunk_bytes: usize,
}

impl Default for StreamCsrOptions {
    fn default() -> Self {
        StreamCsrOptions { compact: None, chunk_bytes: 1 << 20 }
    }
}

/// Stream the record payload of an open binary edge file in
/// `chunk`-byte reads, invoking `rec(record_index, src, dst)` per edge.
/// The 8-byte records are NOT assumed aligned to read boundaries — the
/// partial tail of each chunk (up to 7 bytes) is carried to the front
/// of the next one.
fn for_each_record(
    f: &mut std::fs::File,
    m: u64,
    chunk: usize,
    mut rec: impl FnMut(u64, u32, u32) -> std::result::Result<(), BinGraphError>,
) -> std::result::Result<(), BinGraphError> {
    f.seek(SeekFrom::Start(BIN_HEADER))?;
    let chunk = chunk.max(1);
    // room for one carried partial record ahead of each chunk
    let mut buf = vec![0u8; chunk + 8];
    let mut have = 0usize;
    let mut seen = 0u64;
    while seen < m {
        let got = f.read(&mut buf[have..have + chunk])?;
        if got == 0 {
            // the size check passed, so this means the file shrank
            // between stat and read
            return Err(BinGraphError::Io(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "edge payload ended early",
            )));
        }
        have += got;
        let full = (have / 8).min((m - seen) as usize);
        for i in 0..full {
            let b = &buf[i * 8..i * 8 + 8];
            let s = u32::from_le_bytes(b[0..4].try_into().unwrap());
            let d = u32::from_le_bytes(b[4..8].try_into().unwrap());
            rec(seen, s, d)?;
            seen += 1;
        }
        buf.copy_within(full * 8..have, 0);
        have -= full * 8;
    }
    Ok(())
}

/// Build the transposed, normalized CSR straight from a binary edge
/// file with two streaming passes, never materializing the edge list.
///
/// Pass 1 counts per-destination in-degrees (duplicates included) and
/// validates every node id; pass 2 re-reads the file and scatters each
/// source into its transposed row; rows are then sorted and
/// deduplicated in place and the weights derived from the deduped
/// out-degrees. Peak memory is the CSR arrays plus O(n) counters — the
/// `Csr::from_edgelist(&load_edgelist_bin(..)?)` route pays an extra
/// 8 bytes/edge for the intermediate list, which at web scale is the
/// dominant allocation. The result is bit-identical to that route.
pub fn stream_csr_from_bin(
    path: impl AsRef<Path>,
    opts: &StreamCsrOptions,
) -> std::result::Result<Csr, BinGraphError> {
    let mut f = std::fs::File::open(path.as_ref())?;
    let file_len = f.metadata()?.len();
    let (n64, m64) = read_bin_header(&mut f)?;
    if opts.compact == Some(true) && m64 > u64::from(u32::MAX) {
        // checked against the header BEFORE the size check, so a forced
        // compact build rejects an over-wide graph up front (a real
        // 2^32-edge file passes the size check and would otherwise only
        // fail deep into construction)
        return Err(BinGraphError::CompactOverflow { m: m64 });
    }
    check_bin_size(n64, m64, file_len)?;
    let n = n64 as usize;
    let m = m64 as usize;

    // pass 1: in-degrees (with duplicates) + id validation
    let mut indeg = vec![0u32; n];
    for_each_record(&mut f, m64, opts.chunk_bytes, |record, s, d| {
        if u64::from(s) >= n64 || u64::from(d) >= n64 {
            return Err(BinGraphError::NodeOutOfRange { src: s, dst: d, n: n64, record });
        }
        indeg[d as usize] = indeg[d as usize]
            .checked_add(1)
            .ok_or(BinGraphError::DegreeOverflow { node: d })?;
        Ok(())
    })?;

    let mut rowptr = vec![0usize; n + 1];
    for i in 0..n {
        rowptr[i + 1] = rowptr[i] + indeg[i] as usize;
    }
    drop(indeg);

    // pass 2: scatter sources into their transposed rows
    let mut cols = vec![0u32; m];
    let mut cursor: Vec<usize> = rowptr[..n].to_vec();
    for_each_record(&mut f, m64, opts.chunk_bytes, |record, s, d| {
        // ids were validated in pass 1; re-check in case the file
        // changed between the passes (a stale cursor would otherwise
        // scribble across row boundaries)
        if u64::from(s) >= n64 || u64::from(d) >= n64 {
            return Err(BinGraphError::NodeOutOfRange { src: s, dst: d, n: n64, record });
        }
        let c = &mut cursor[d as usize];
        cols[*c] = s;
        *c += 1;
        Ok(())
    })?;
    drop(cursor);

    // sort + dedup each row in place behind a global write cursor
    // (w <= row start always, so the compaction never clobbers an
    // unread entry)
    let mut w = 0usize;
    let mut lo = 0usize;
    let mut new_rowptr = vec![0usize; n + 1];
    for i in 0..n {
        let hi = rowptr[i + 1];
        cols[lo..hi].sort_unstable();
        let mut prev: Option<u32> = None;
        for idx in lo..hi {
            let c = cols[idx];
            if prev != Some(c) {
                cols[w] = c;
                w += 1;
                prev = Some(c);
            }
        }
        new_rowptr[i + 1] = w;
        lo = hi;
    }
    drop(rowptr);
    cols.truncate(w);
    cols.shrink_to_fit();

    // out-degrees on the deduped edge set, then dangling and weights
    let mut outdeg = vec![0u32; n];
    for &c in &cols {
        outdeg[c as usize] += 1;
    }
    let dangling: Vec<NodeId> =
        (0..n as NodeId).filter(|&i| outdeg[i as usize] == 0).collect();
    let vals: Vec<f32> = cols.iter().map(|&c| 1.0 / outdeg[c as usize] as f32).collect();
    let mut csr = Csr::from_raw_parts(n, new_rowptr, cols, vals, dangling, outdeg);
    if let Some(compact) = opts.compact {
        csr.set_compact_rowptr(compact);
    }
    Ok(csr)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    fn tmpdir() -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!(
            "asyncpr_io_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn text_roundtrip() {
        let d = tmpdir();
        let el = generators::erdos_renyi(100, 300, 1);
        let p = d.join("g.txt");
        save_edgelist_text(&el, &p).unwrap();
        let back = load_edgelist_text(&p, Some(100)).unwrap();
        assert_eq!(el, back);
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn text_infers_n_and_skips_comments() {
        let d = tmpdir();
        let p = d.join("g2.txt");
        std::fs::write(&p, "# comment\n0 5\n\n3\t2\n").unwrap();
        let el = load_edgelist_text(&p, None).unwrap();
        assert_eq!(el.n(), 6);
        assert_eq!(el.edges(), &[(0, 5), (3, 2)]);
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn text_rejects_bad_lines() {
        let d = tmpdir();
        let p = d.join("g3.txt");
        std::fs::write(&p, "0 x\n").unwrap();
        assert!(load_edgelist_text(&p, None).is_err());
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn bin_roundtrip() {
        let d = tmpdir();
        let el = generators::erdos_renyi(1000, 5000, 2);
        let p = d.join("g.bin");
        save_edgelist_bin(&el, &p).unwrap();
        let back = load_edgelist_bin(&p).unwrap();
        assert_eq!(el, back);
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn bin_iter_writer_matches_slice_writer() {
        let d = tmpdir();
        let el = generators::erdos_renyi(200, 700, 8);
        let p1 = d.join("slice.bin");
        let p2 = d.join("iter.bin");
        save_edgelist_bin(&el, &p1).unwrap();
        save_edgelist_bin_iter(&p2, el.n(), el.len() as u64, el.edges().iter().copied())
            .unwrap();
        assert_eq!(std::fs::read(&p1).unwrap(), std::fs::read(&p2).unwrap());
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn bin_iter_writer_rejects_count_and_bounds_lies() {
        let d = tmpdir();
        let p = d.join("lie.bin");
        let err = save_edgelist_bin_iter(&p, 4, 3, [(0u32, 1u32)].into_iter())
            .unwrap_err()
            .to_string();
        assert!(err.contains("promised"), "{err}");
        let err = save_edgelist_bin_iter(&p, 4, 1, [(0u32, 9u32)].into_iter())
            .unwrap_err()
            .to_string();
        assert!(err.contains("out of bounds"), "{err}");
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn bin_rejects_truncated_header_vs_size() {
        // regression: a header claiming a huge edge count must fail on
        // the size check, not attempt the allocation
        let d = tmpdir();
        let p = d.join("huge.bin");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(BIN_MAGIC);
        bytes.extend_from_slice(&100u64.to_le_bytes()); // n
        bytes.extend_from_slice(&u64::MAX.to_le_bytes()); // m: absurd
        std::fs::write(&p, &bytes).unwrap();
        let err = format!("{:#}", load_edgelist_bin(&p).unwrap_err());
        assert!(err.contains("overflows") || err.contains("truncated"), "{err}");
        // the streaming path reports the same condition, typed
        let err = stream_csr_from_bin(&p, &StreamCsrOptions::default()).unwrap_err();
        assert!(matches!(err, BinGraphError::SizeOverflow { .. }), "{err}");
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn bin_rejects_size_mismatch_both_ways() {
        let d = tmpdir();
        let el = generators::erdos_renyi(50, 200, 9);
        let p = d.join("g.bin");
        save_edgelist_bin(&el, &p).unwrap();
        let good = std::fs::read(&p).unwrap();
        // truncated payload
        std::fs::write(&p, &good[..good.len() - 5]).unwrap();
        let err = format!("{:#}", load_edgelist_bin(&p).unwrap_err());
        assert!(err.contains("truncated or corrupt"), "{err}");
        let terr = stream_csr_from_bin(&p, &StreamCsrOptions::default()).unwrap_err();
        assert!(matches!(terr, BinGraphError::SizeMismatch { .. }), "{terr}");
        // trailing garbage
        let mut padded = good.clone();
        padded.extend_from_slice(b"junk");
        std::fs::write(&p, &padded).unwrap();
        let err = format!("{:#}", load_edgelist_bin(&p).unwrap_err());
        assert!(err.contains("truncated or corrupt"), "{err}");
        let terr = stream_csr_from_bin(&p, &StreamCsrOptions::default()).unwrap_err();
        assert!(matches!(terr, BinGraphError::SizeMismatch { .. }), "{terr}");
        // pristine file still loads
        std::fs::write(&p, &good).unwrap();
        assert_eq!(load_edgelist_bin(&p).unwrap(), el);
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn bin_rejects_oversized_n() {
        let d = tmpdir();
        let p = d.join("bign.bin");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(BIN_MAGIC);
        bytes.extend_from_slice(&(u64::from(u32::MAX) + 2).to_le_bytes()); // n too big
        bytes.extend_from_slice(&0u64.to_le_bytes()); // m
        std::fs::write(&p, &bytes).unwrap();
        let err = format!("{:#}", load_edgelist_bin(&p).unwrap_err());
        assert!(err.contains("node-id space"), "{err}");
        let terr = stream_csr_from_bin(&p, &StreamCsrOptions::default()).unwrap_err();
        assert!(matches!(terr, BinGraphError::OversizedN { .. }), "{terr}");
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn bin_rejects_wrong_magic() {
        let d = tmpdir();
        let p = d.join("bad.bin");
        std::fs::write(&p, b"NOTAGRPH
").unwrap();
        assert!(load_edgelist_bin(&p).is_err());
        let terr = stream_csr_from_bin(&p, &StreamCsrOptions::default()).unwrap_err();
        assert!(matches!(terr, BinGraphError::BadMagic), "{terr}");
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn stream_csr_matches_edgelist_build() {
        let d = tmpdir();
        // parallel edges + dangling nodes + a self-loop, to hit dedup
        // and every weight path
        let mut el = generators::erdos_renyi(300, 1200, 5);
        el.push(7, 7);
        el.push(0, 299);
        el.push(0, 299);
        let p = d.join("g.bin");
        save_edgelist_bin(&el, &p).unwrap();
        let want = Csr::from_edgelist(&el).unwrap();
        let got = stream_csr_from_bin(&p, &StreamCsrOptions::default()).unwrap();
        assert_eq!(got, want);
        assert!(got.rowptr_is_compact());
        got.validate().unwrap();
        // forced widths read the same structure
        let wide =
            stream_csr_from_bin(&p, &StreamCsrOptions { compact: Some(false), chunk_bytes: 1 << 20 })
                .unwrap();
        assert!(!wide.rowptr_is_compact());
        assert_eq!(wide, want);
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn stream_csr_chunk_boundary_straddle() {
        // chunk sizes that are NOT multiples of the 8-byte record force
        // a record to straddle every read boundary; 1-byte chunks are
        // the degenerate worst case
        let d = tmpdir();
        let el = generators::erdos_renyi(64, 500, 11);
        let p = d.join("g.bin");
        save_edgelist_bin(&el, &p).unwrap();
        let want = Csr::from_edgelist(&el).unwrap();
        for chunk_bytes in [1usize, 5, 7, 13, 8 * 10 + 3] {
            let got = stream_csr_from_bin(&p, &StreamCsrOptions { compact: None, chunk_bytes })
                .unwrap();
            assert_eq!(got, want, "chunk_bytes={chunk_bytes}");
        }
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn stream_csr_rejects_out_of_range_node_ids() {
        let d = tmpdir();
        let p = d.join("oob.bin");
        // hand-built file: n=3, m=2, second record's dst out of range
        let mut bytes = Vec::new();
        bytes.extend_from_slice(BIN_MAGIC);
        bytes.extend_from_slice(&3u64.to_le_bytes());
        bytes.extend_from_slice(&2u64.to_le_bytes());
        for (s, d) in [(0u32, 1u32), (1, 7)] {
            bytes.extend_from_slice(&s.to_le_bytes());
            bytes.extend_from_slice(&d.to_le_bytes());
        }
        std::fs::write(&p, &bytes).unwrap();
        let err = stream_csr_from_bin(&p, &StreamCsrOptions::default()).unwrap_err();
        match err {
            BinGraphError::NodeOutOfRange { src, dst, n, record } => {
                assert_eq!((src, dst, n, record), (1, 7, 3, 1));
            }
            other => panic!("want NodeOutOfRange, got {other}"),
        }
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn stream_csr_forced_compact_rejects_overwide_header() {
        // a header promising 2^32 edges cannot take the u32 rowptr
        // tier; the typed error fires BEFORE the size check, so a tiny
        // test file suffices
        let d = tmpdir();
        let p = d.join("wide.bin");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(BIN_MAGIC);
        bytes.extend_from_slice(&4u64.to_le_bytes());
        bytes.extend_from_slice(&(u64::from(u32::MAX) + 1).to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        let forced = StreamCsrOptions { compact: Some(true), chunk_bytes: 1 << 20 };
        let err = stream_csr_from_bin(&p, &forced).unwrap_err();
        assert!(matches!(err, BinGraphError::CompactOverflow { m } if m == u64::from(u32::MAX) + 1), "{err}");
        // without the forced width the same file fails the size check
        let err = stream_csr_from_bin(&p, &StreamCsrOptions::default()).unwrap_err();
        assert!(matches!(err, BinGraphError::SizeMismatch { .. }), "{err}");
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn stream_csr_empty_graph() {
        let d = tmpdir();
        let p = d.join("empty.bin");
        save_edgelist_bin(&EdgeList::new(5), &p).unwrap();
        let csr = stream_csr_from_bin(&p, &StreamCsrOptions::default()).unwrap();
        assert_eq!(csr.n(), 5);
        assert_eq!(csr.nnz(), 0);
        assert_eq!(csr.dangling().len(), 5);
        std::fs::remove_dir_all(&d).ok();
    }
}
