//! Graph IO: text edge lists (SNAP style, what the Stanford-Web data
//! ships as), and a compact binary format for fast reload of generated
//! graphs.

use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::Context;

use super::{EdgeList, NodeId};
use crate::Result;

/// Load a SNAP-style text edge list: one `src dst` (or `src\tdst`) pair
/// per line; `#`-prefixed lines are comments. Node ids must be < n if
/// `n` is given, otherwise n = max id + 1.
pub fn load_edgelist_text(path: impl AsRef<Path>, n: Option<usize>) -> Result<EdgeList> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening {}", path.as_ref().display()))?;
    let mut edges: Vec<(NodeId, NodeId)> = Vec::new();
    let mut max_id: NodeId = 0;
    for (lineno, line) in BufReader::new(f).lines().enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        let mut it = t.split_whitespace();
        let parse = |s: Option<&str>| -> Result<NodeId> {
            s.context("missing field")?
                .parse::<NodeId>()
                .with_context(|| format!("line {}: bad node id", lineno + 1))
        };
        let s = parse(it.next())?;
        let d = parse(it.next())?;
        max_id = max_id.max(s).max(d);
        edges.push((s, d));
    }
    let n = n.unwrap_or(max_id as usize + 1);
    EdgeList::from_edges(n, edges)
}

/// Write a SNAP-style text edge list with a header comment.
pub fn save_edgelist_text(el: &EdgeList, path: impl AsRef<Path>) -> Result<()> {
    let f = std::fs::File::create(path.as_ref())?;
    let mut w = BufWriter::new(f);
    writeln!(w, "# asyncpr edge list: n={} m={}", el.n(), el.len())?;
    for &(s, d) in el.edges() {
        writeln!(w, "{s}\t{d}")?;
    }
    Ok(())
}

const BIN_MAGIC: &[u8; 8] = b"APRGRAPH";

/// Compact binary: magic, u64 n, u64 m, then m (u32,u32) LE pairs.
pub fn save_edgelist_bin(el: &EdgeList, path: impl AsRef<Path>) -> Result<()> {
    let f = std::fs::File::create(path.as_ref())?;
    let mut w = BufWriter::new(f);
    w.write_all(BIN_MAGIC)?;
    w.write_all(&(el.n() as u64).to_le_bytes())?;
    w.write_all(&(el.len() as u64).to_le_bytes())?;
    for &(s, d) in el.edges() {
        w.write_all(&s.to_le_bytes())?;
        w.write_all(&d.to_le_bytes())?;
    }
    Ok(())
}

/// Load the binary format written by [`save_edgelist_bin`].
pub fn load_edgelist_bin(path: impl AsRef<Path>) -> Result<EdgeList> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening {}", path.as_ref().display()))?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != BIN_MAGIC {
        anyhow::bail!("not an asyncpr graph file");
    }
    let mut u64buf = [0u8; 8];
    r.read_exact(&mut u64buf)?;
    let n = u64::from_le_bytes(u64buf) as usize;
    r.read_exact(&mut u64buf)?;
    let m = u64::from_le_bytes(u64buf) as usize;
    let mut edges = Vec::with_capacity(m);
    let mut pair = [0u8; 8];
    for _ in 0..m {
        r.read_exact(&mut pair)?;
        let s = u32::from_le_bytes(pair[0..4].try_into().unwrap());
        let d = u32::from_le_bytes(pair[4..8].try_into().unwrap());
        edges.push((s, d));
    }
    EdgeList::from_edges(n, edges)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    fn tmpdir() -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!(
            "asyncpr_io_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn text_roundtrip() {
        let d = tmpdir();
        let el = generators::erdos_renyi(100, 300, 1);
        let p = d.join("g.txt");
        save_edgelist_text(&el, &p).unwrap();
        let back = load_edgelist_text(&p, Some(100)).unwrap();
        assert_eq!(el, back);
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn text_infers_n_and_skips_comments() {
        let d = tmpdir();
        let p = d.join("g2.txt");
        std::fs::write(&p, "# comment\n0 5\n\n3\t2\n").unwrap();
        let el = load_edgelist_text(&p, None).unwrap();
        assert_eq!(el.n(), 6);
        assert_eq!(el.edges(), &[(0, 5), (3, 2)]);
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn text_rejects_bad_lines() {
        let d = tmpdir();
        let p = d.join("g3.txt");
        std::fs::write(&p, "0 x\n").unwrap();
        assert!(load_edgelist_text(&p, None).is_err());
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn bin_roundtrip() {
        let d = tmpdir();
        let el = generators::erdos_renyi(1000, 5000, 2);
        let p = d.join("g.bin");
        save_edgelist_bin(&el, &p).unwrap();
        let back = load_edgelist_bin(&p).unwrap();
        assert_eq!(el, back);
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn bin_rejects_wrong_magic() {
        let d = tmpdir();
        let p = d.join("bad.bin");
        std::fs::write(&p, b"NOTAGRPH
").unwrap();
        assert!(load_edgelist_bin(&p).is_err());
        std::fs::remove_dir_all(&d).ok();
    }
}
