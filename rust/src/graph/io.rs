//! Graph IO: text edge lists (SNAP style, what the Stanford-Web data
//! ships as), and a compact binary format for fast reload of generated
//! graphs.

use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::Context;

use super::{EdgeList, NodeId};
use crate::Result;

/// Load a SNAP-style text edge list: one `src dst` (or `src\tdst`) pair
/// per line; `#`-prefixed lines are comments. Node ids must be < n if
/// `n` is given, otherwise n = max id + 1.
pub fn load_edgelist_text(path: impl AsRef<Path>, n: Option<usize>) -> Result<EdgeList> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening {}", path.as_ref().display()))?;
    let mut edges: Vec<(NodeId, NodeId)> = Vec::new();
    let mut max_id: NodeId = 0;
    for (lineno, line) in BufReader::new(f).lines().enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        let mut it = t.split_whitespace();
        let parse = |s: Option<&str>| -> Result<NodeId> {
            s.context("missing field")?
                .parse::<NodeId>()
                .with_context(|| format!("line {}: bad node id", lineno + 1))
        };
        let s = parse(it.next())?;
        let d = parse(it.next())?;
        max_id = max_id.max(s).max(d);
        edges.push((s, d));
    }
    let n = n.unwrap_or(max_id as usize + 1);
    EdgeList::from_edges(n, edges)
}

/// Write a SNAP-style text edge list with a header comment.
pub fn save_edgelist_text(el: &EdgeList, path: impl AsRef<Path>) -> Result<()> {
    let f = std::fs::File::create(path.as_ref())?;
    let mut w = BufWriter::new(f);
    writeln!(w, "# asyncpr edge list: n={} m={}", el.n(), el.len())?;
    for &(s, d) in el.edges() {
        writeln!(w, "{s}\t{d}")?;
    }
    Ok(())
}

const BIN_MAGIC: &[u8; 8] = b"APRGRAPH";

/// Compact binary: magic, u64 n, u64 m, then m (u32,u32) LE pairs.
pub fn save_edgelist_bin(el: &EdgeList, path: impl AsRef<Path>) -> Result<()> {
    let f = std::fs::File::create(path.as_ref())?;
    let mut w = BufWriter::new(f);
    w.write_all(BIN_MAGIC)?;
    w.write_all(&(el.n() as u64).to_le_bytes())?;
    w.write_all(&(el.len() as u64).to_le_bytes())?;
    for &(s, d) in el.edges() {
        w.write_all(&s.to_le_bytes())?;
        w.write_all(&d.to_le_bytes())?;
    }
    Ok(())
}

/// Header size of the binary format: magic + u64 n + u64 m.
const BIN_HEADER: u64 = 8 + 8 + 8;

/// Load the binary format written by [`save_edgelist_bin`].
///
/// The `n`/`m` header is validated against the actual file size BEFORE
/// any `m`-sized allocation, so a corrupt or truncated file fails with
/// a readable error instead of attempting a massive `Vec::with_capacity`
/// (a 16-byte header flip could otherwise request exabytes).
pub fn load_edgelist_bin(path: impl AsRef<Path>) -> Result<EdgeList> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening {}", path.as_ref().display()))?;
    let file_len = f
        .metadata()
        .with_context(|| format!("stat {}", path.as_ref().display()))?
        .len();
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != BIN_MAGIC {
        anyhow::bail!("not an asyncpr graph file");
    }
    let mut u64buf = [0u8; 8];
    r.read_exact(&mut u64buf)?;
    let n = u64::from_le_bytes(u64buf);
    r.read_exact(&mut u64buf)?;
    let m = u64::from_le_bytes(u64buf);
    // node ids are u32, so any readable file has n <= 2^32
    if n > u64::from(u32::MAX) + 1 {
        anyhow::bail!("graph header claims n={n}, beyond the u32 node-id space (corrupt file?)");
    }
    let want_len = m
        .checked_mul(8)
        .and_then(|b| b.checked_add(BIN_HEADER))
        .ok_or_else(|| anyhow::anyhow!("graph header claims m={m} edges; size overflows"))?;
    if want_len != file_len {
        anyhow::bail!(
            "graph file is {file_len} bytes but header (n={n}, m={m}) requires {want_len}: \
             truncated or corrupt"
        );
    }
    let n = n as usize;
    let m = m as usize;
    let mut edges = Vec::with_capacity(m);
    let mut pair = [0u8; 8];
    for _ in 0..m {
        r.read_exact(&mut pair)?;
        let s = u32::from_le_bytes(pair[0..4].try_into().unwrap());
        let d = u32::from_le_bytes(pair[4..8].try_into().unwrap());
        edges.push((s, d));
    }
    EdgeList::from_edges(n, edges)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    fn tmpdir() -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!(
            "asyncpr_io_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn text_roundtrip() {
        let d = tmpdir();
        let el = generators::erdos_renyi(100, 300, 1);
        let p = d.join("g.txt");
        save_edgelist_text(&el, &p).unwrap();
        let back = load_edgelist_text(&p, Some(100)).unwrap();
        assert_eq!(el, back);
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn text_infers_n_and_skips_comments() {
        let d = tmpdir();
        let p = d.join("g2.txt");
        std::fs::write(&p, "# comment\n0 5\n\n3\t2\n").unwrap();
        let el = load_edgelist_text(&p, None).unwrap();
        assert_eq!(el.n(), 6);
        assert_eq!(el.edges(), &[(0, 5), (3, 2)]);
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn text_rejects_bad_lines() {
        let d = tmpdir();
        let p = d.join("g3.txt");
        std::fs::write(&p, "0 x\n").unwrap();
        assert!(load_edgelist_text(&p, None).is_err());
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn bin_roundtrip() {
        let d = tmpdir();
        let el = generators::erdos_renyi(1000, 5000, 2);
        let p = d.join("g.bin");
        save_edgelist_bin(&el, &p).unwrap();
        let back = load_edgelist_bin(&p).unwrap();
        assert_eq!(el, back);
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn bin_rejects_truncated_header_vs_size() {
        // regression: a header claiming a huge edge count must fail on
        // the size check, not attempt the allocation
        let d = tmpdir();
        let p = d.join("huge.bin");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(BIN_MAGIC);
        bytes.extend_from_slice(&100u64.to_le_bytes()); // n
        bytes.extend_from_slice(&u64::MAX.to_le_bytes()); // m: absurd
        std::fs::write(&p, &bytes).unwrap();
        let err = format!("{:#}", load_edgelist_bin(&p).unwrap_err());
        assert!(err.contains("overflows") || err.contains("truncated"), "{err}");
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn bin_rejects_size_mismatch_both_ways() {
        let d = tmpdir();
        let el = generators::erdos_renyi(50, 200, 9);
        let p = d.join("g.bin");
        save_edgelist_bin(&el, &p).unwrap();
        let good = std::fs::read(&p).unwrap();
        // truncated payload
        std::fs::write(&p, &good[..good.len() - 5]).unwrap();
        let err = format!("{:#}", load_edgelist_bin(&p).unwrap_err());
        assert!(err.contains("truncated or corrupt"), "{err}");
        // trailing garbage
        let mut padded = good.clone();
        padded.extend_from_slice(b"junk");
        std::fs::write(&p, &padded).unwrap();
        let err = format!("{:#}", load_edgelist_bin(&p).unwrap_err());
        assert!(err.contains("truncated or corrupt"), "{err}");
        // pristine file still loads
        std::fs::write(&p, &good).unwrap();
        assert_eq!(load_edgelist_bin(&p).unwrap(), el);
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn bin_rejects_oversized_n() {
        let d = tmpdir();
        let p = d.join("bign.bin");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(BIN_MAGIC);
        bytes.extend_from_slice(&(u64::from(u32::MAX) + 2).to_le_bytes()); // n too big
        bytes.extend_from_slice(&0u64.to_le_bytes()); // m
        std::fs::write(&p, &bytes).unwrap();
        let err = format!("{:#}", load_edgelist_bin(&p).unwrap_err());
        assert!(err.contains("node-id space"), "{err}");
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn bin_rejects_wrong_magic() {
        let d = tmpdir();
        let p = d.join("bad.bin");
        std::fs::write(&p, b"NOTAGRPH
").unwrap();
        assert!(load_edgelist_bin(&p).is_err());
        std::fs::remove_dir_all(&d).ok();
    }
}
