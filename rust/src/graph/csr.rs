//! Compressed sparse row adjacency — the canonical in-memory form.
//!
//! `Csr` here stores the *transposed, degree-normalized* matrix the
//! PageRank iteration multiplies by: row i lists (source page j,
//! weight 1/deg(j)) for every page j linking to i. That is exactly the
//! `P^T` of the paper's `S = P^T + w d^T`, so one [`Csr::spmv`] is the
//! sparse part of eq. (4)/(6).

use super::{EdgeList, NodeId};
use crate::Result;

/// Transposed, normalized link matrix in CSR form plus dangling info.
#[derive(Debug, Clone, PartialEq)]
pub struct Csr {
    n: usize,
    /// Row pointer, len n+1. Row i (in-links of page i) spans
    /// `cols[rowptr[i]..rowptr[i+1]]`.
    rowptr: Vec<usize>,
    /// Source page of each entry.
    cols: Vec<NodeId>,
    /// Weight of each entry: 1/outdeg(source).
    vals: Vec<f32>,
    /// Pages with zero out-degree (the paper's dangling vector d).
    dangling: Vec<NodeId>,
    /// Out-degree per page (on the ORIGINAL orientation).
    outdeg: Vec<u32>,
}

impl Csr {
    /// Build the normalized transposed matrix from an edge list.
    /// Duplicate edges are collapsed (adjacency is 0/1); self-loops are
    /// kept, matching the usual PageRank treatment of the raw crawl.
    pub fn from_edgelist(el: &EdgeList) -> Result<Self> {
        let n = el.n();
        // dedup: sort by (dst, src) so transposed rows come out sorted
        let mut pairs: Vec<(NodeId, NodeId)> = el.edges().to_vec();
        pairs.sort_unstable_by_key(|&(s, d)| (d, s));
        pairs.dedup();

        // out-degrees on the deduped edge set
        let mut outdeg = vec![0u32; n];
        for &(s, _) in &pairs {
            outdeg[s as usize] += 1;
        }
        let dangling: Vec<NodeId> = (0..n as NodeId)
            .filter(|&i| outdeg[i as usize] == 0)
            .collect();

        let mut rowptr = vec![0usize; n + 1];
        for &(_, d) in &pairs {
            rowptr[d as usize + 1] += 1;
        }
        for i in 0..n {
            rowptr[i + 1] += rowptr[i];
        }
        let mut cols = Vec::with_capacity(pairs.len());
        let mut vals = Vec::with_capacity(pairs.len());
        for &(s, _) in &pairs {
            cols.push(s);
            vals.push(1.0 / outdeg[s as usize] as f32);
        }
        Ok(Csr { n, rowptr, cols, vals, dangling, outdeg })
    }

    /// Assemble a CSR from already-built parts — the splice path of
    /// `DeltaGraph::merge_csr`, which rebuilds only dirty rows and
    /// copies the rest verbatim. Debug builds re-validate the full
    /// structural invariants; release builds trust the splicer (the
    /// property suite pins splice == rebuild bit-for-bit).
    pub(crate) fn from_raw_parts(
        n: usize,
        rowptr: Vec<usize>,
        cols: Vec<NodeId>,
        vals: Vec<f32>,
        dangling: Vec<NodeId>,
        outdeg: Vec<u32>,
    ) -> Csr {
        let csr = Csr { n, rowptr, cols, vals, dangling, outdeg };
        if cfg!(debug_assertions) {
            csr.validate().expect("spliced CSR violates structural invariants");
        }
        csr
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Number of stored nonzeros (== deduped edge count).
    pub fn nnz(&self) -> usize {
        self.cols.len()
    }

    pub fn dangling(&self) -> &[NodeId] {
        &self.dangling
    }

    pub fn outdeg(&self) -> &[u32] {
        &self.outdeg
    }

    /// In-degree of page i (row length in this orientation).
    #[inline]
    pub fn row_len(&self, i: usize) -> usize {
        self.rowptr[i + 1] - self.rowptr[i]
    }

    /// (sources, weights) of row i.
    #[inline]
    pub fn row(&self, i: usize) -> (&[NodeId], &[f32]) {
        let lo = self.rowptr[i];
        let hi = self.rowptr[i + 1];
        (&self.cols[lo..hi], &self.vals[lo..hi])
    }

    /// y = (P^T) x restricted to rows [row_lo, row_hi).
    ///
    /// This is the native (non-artifact) hot path; the PJRT artifact
    /// computes the same thing through the Pallas kernel.
    pub fn spmv_range(&self, x: &[f32], row_lo: usize, row_hi: usize, y: &mut [f32]) {
        debug_assert_eq!(x.len(), self.n);
        debug_assert_eq!(y.len(), row_hi - row_lo);
        // NOTE §Perf: a 4-accumulator unrolled variant was tried and
        // REVERTED — web rows average ~8 nonzeros, so the unroll's
        // prologue/epilogue cost exceeded the gather-latency win
        // (1.91 ms vs 1.57 ms per p=4 block step).
        for (yi, i) in y.iter_mut().zip(row_lo..row_hi) {
            let lo = self.rowptr[i];
            let hi = self.rowptr[i + 1];
            let mut acc = 0.0f32;
            for (c, v) in self.cols[lo..hi].iter().zip(&self.vals[lo..hi]) {
                acc += v * x[*c as usize];
            }
            *yi = acc;
        }
    }

    /// Full y = (P^T) x.
    pub fn spmv(&self, x: &[f32], y: &mut [f32]) {
        self.spmv_range(x, 0, self.n, y)
    }

    /// Dangling mass d·x (sum of x over dangling pages).
    pub fn dangling_dot(&self, x: &[f32]) -> f32 {
        self.dangling.iter().map(|&i| x[i as usize]).sum()
    }

    /// Column sums of P^T (i.e., row sums of P): 1.0 for non-dangling
    /// pages, 0.0 for dangling. Used by validation tests.
    pub fn column_sums(&self) -> Vec<f64> {
        let mut sums = vec![0.0f64; self.n];
        for i in 0..self.n {
            let (cols, vals) = self.row(i);
            for (c, v) in cols.iter().zip(vals) {
                sums[*c as usize] += *v as f64;
            }
        }
        sums
    }

    /// Validate structural invariants (sorted rows, weight consistency,
    /// stochastic columns). Used by tests and `repro generate --check`.
    pub fn validate(&self) -> Result<()> {
        if self.rowptr.len() != self.n + 1 || *self.rowptr.last().unwrap() != self.nnz() {
            anyhow::bail!("rowptr malformed");
        }
        for i in 0..self.n {
            if self.rowptr[i] > self.rowptr[i + 1] {
                anyhow::bail!("rowptr not monotone at {i}");
            }
            let (cols, vals) = self.row(i);
            for w in cols.windows(2) {
                if w[0] >= w[1] {
                    anyhow::bail!("row {i} not strictly sorted");
                }
            }
            for (c, v) in cols.iter().zip(vals) {
                let want = 1.0 / self.outdeg[*c as usize] as f32;
                if (v - want).abs() > 1e-7 {
                    anyhow::bail!("row {i}: weight {v} != 1/outdeg {want}");
                }
            }
        }
        for (j, s) in self.column_sums().iter().enumerate() {
            let want = if self.outdeg[j] == 0 { 0.0 } else { 1.0 };
            if (s - want).abs() > 1e-4 {
                anyhow::bail!("column {j} sums to {s}, want {want}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// 4-page toy web: 0->1, 0->2, 1->2, 2->0, 3 dangling.
    fn toy() -> Csr {
        let el = EdgeList::from_edges(4, vec![(0, 1), (0, 2), (1, 2), (2, 0)]).unwrap();
        Csr::from_edgelist(&el).unwrap()
    }

    #[test]
    fn builds_transposed_normalized() {
        let g = toy();
        assert_eq!(g.n(), 4);
        assert_eq!(g.nnz(), 4);
        assert_eq!(g.dangling(), &[3]);
        assert_eq!(g.outdeg(), &[2, 1, 1, 0]);
        // row 0 (in-links of 0): from 2 with weight 1/1
        assert_eq!(g.row(0), (&[2][..], &[1.0][..]));
        // row 2 (in-links of 2): from 0 (1/2) and 1 (1/1)
        let (c, v) = g.row(2);
        assert_eq!(c, &[0, 1]);
        assert_eq!(v, &[0.5, 1.0]);
        g.validate().unwrap();
    }

    #[test]
    fn dedups_parallel_edges() {
        let el = EdgeList::from_edges(2, vec![(0, 1), (0, 1), (0, 1)]).unwrap();
        let g = Csr::from_edgelist(&el).unwrap();
        assert_eq!(g.nnz(), 1);
        assert_eq!(g.outdeg(), &[1, 0]);
    }

    #[test]
    fn spmv_matches_dense() {
        let g = toy();
        let x = [0.1f32, 0.2, 0.3, 0.4];
        let mut y = [0.0f32; 4];
        g.spmv(&x, &mut y);
        // dense P^T rows: r0: x2; r1: 0.5 x0; r2: 0.5 x0 + x1; r3: 0
        let want = [0.3, 0.05, 0.05 + 0.2, 0.0];
        for (a, b) in y.iter().zip(&want) {
            assert!((a - b).abs() < 1e-6, "{y:?} vs {want:?}");
        }
    }

    #[test]
    fn spmv_range_is_slice_of_full() {
        let g = toy();
        let x = [0.4f32, 0.3, 0.2, 0.1];
        let mut full = [0.0f32; 4];
        g.spmv(&x, &mut full);
        let mut part = [0.0f32; 2];
        g.spmv_range(&x, 1, 3, &mut part);
        assert_eq!(&full[1..3], &part);
    }

    #[test]
    fn dangling_dot() {
        let g = toy();
        assert_eq!(g.dangling_dot(&[0.1, 0.2, 0.3, 0.4]), 0.4);
    }

    #[test]
    fn column_sums_stochastic() {
        let g = toy();
        let s = g.column_sums();
        assert!((s[0] - 1.0).abs() < 1e-6);
        assert!((s[1] - 1.0).abs() < 1e-6);
        assert!((s[2] - 1.0).abs() < 1e-6);
        assert_eq!(s[3], 0.0);
    }

    #[test]
    fn empty_graph_all_dangling() {
        let g = Csr::from_edgelist(&EdgeList::new(3)).unwrap();
        assert_eq!(g.nnz(), 0);
        assert_eq!(g.dangling().len(), 3);
        g.validate().unwrap();
    }
}
