//! Compressed sparse row adjacency — the canonical in-memory form.
//!
//! `Csr` here stores the *transposed, degree-normalized* matrix the
//! PageRank iteration multiplies by: row i lists (source page j,
//! weight 1/deg(j)) for every page j linking to i. That is exactly the
//! `P^T` of the paper's `S = P^T + w d^T`, so one [`Csr::spmv`] is the
//! sparse part of eq. (4)/(6).
//!
//! # Memory tier
//!
//! The row pointer is stored at the narrowest width that can address
//! the nonzeros: `u32` offsets while `nnz <= u32::MAX` (every graph the
//! paper's single-box scale targets), widening to `usize` beyond. The
//! width is an internal representation choice — every accessor,
//! `spmv`, and the `merge_csr` splice path go through the same API, and
//! [`PartialEq`] compares row pointers by value, not width. Builders
//! pick the width automatically; [`Csr::set_compact_rowptr`] forces one
//! (the equivalence proptests pin narrow == wide bit-for-bit).

use super::{EdgeList, NodeId};
use crate::Result;

/// Row-pointer offsets into `cols`/`vals`, stored at adaptive width.
#[derive(Debug, Clone)]
enum RowPtr {
    /// u32 offsets — valid while `nnz <= u32::MAX`; half the rowptr
    /// bytes of the wide layout on 64-bit targets.
    Narrow(Vec<u32>),
    /// Full-width offsets — the fallback for `nnz > u32::MAX`.
    Wide(Vec<usize>),
}

impl RowPtr {
    /// Adopt a freshly built offset vector at the narrowest valid
    /// width. `v` is monotone by construction (the builders produce
    /// prefix sums), so the last entry is the maximum.
    fn from_usize(v: Vec<usize>) -> RowPtr {
        match v.last() {
            Some(&nnz) if nnz <= u32::MAX as usize => {
                RowPtr::Narrow(v.into_iter().map(|o| o as u32).collect())
            }
            _ => RowPtr::Wide(v),
        }
    }

    #[inline]
    fn at(&self, i: usize) -> usize {
        match self {
            RowPtr::Narrow(v) => v[i] as usize,
            RowPtr::Wide(v) => v[i],
        }
    }

    fn len(&self) -> usize {
        match self {
            RowPtr::Narrow(v) => v.len(),
            RowPtr::Wide(v) => v.len(),
        }
    }

    fn heap_bytes(&self) -> usize {
        match self {
            RowPtr::Narrow(v) => v.len() * std::mem::size_of::<u32>(),
            RowPtr::Wide(v) => v.len() * std::mem::size_of::<usize>(),
        }
    }
}

/// Width-blind equality: a narrow and a wide rowptr holding the same
/// offsets are the same row structure.
impl PartialEq for RowPtr {
    fn eq(&self, other: &Self) -> bool {
        match (self, other) {
            (RowPtr::Narrow(a), RowPtr::Narrow(b)) => a == b,
            (RowPtr::Wide(a), RowPtr::Wide(b)) => a == b,
            (RowPtr::Narrow(a), RowPtr::Wide(b)) | (RowPtr::Wide(b), RowPtr::Narrow(a)) => {
                a.len() == b.len() && a.iter().zip(b).all(|(&x, &y)| x as usize == y)
            }
        }
    }
}

/// Offset element the width-generic row loops read through.
trait RowOffset: Copy {
    fn ix(self) -> usize;
}

impl RowOffset for u32 {
    #[inline]
    fn ix(self) -> usize {
        self as usize
    }
}

impl RowOffset for usize {
    #[inline]
    fn ix(self) -> usize {
        self
    }
}

/// The width-monomorphized spmv hot loop (one match per call, not per
/// row — the branch would otherwise sit inside the gather loop).
fn spmv_rows<T: RowOffset>(
    rowptr: &[T],
    cols: &[NodeId],
    vals: &[f32],
    x: &[f32],
    row_lo: usize,
    row_hi: usize,
    y: &mut [f32],
) {
    // NOTE §Perf: a 4-accumulator unrolled variant was tried and
    // REVERTED — web rows average ~8 nonzeros, so the unroll's
    // prologue/epilogue cost exceeded the gather-latency win
    // (1.91 ms vs 1.57 ms per p=4 block step).
    for (yi, i) in y.iter_mut().zip(row_lo..row_hi) {
        let lo = rowptr[i].ix();
        let hi = rowptr[i + 1].ix();
        let mut acc = 0.0f32;
        for (c, v) in cols[lo..hi].iter().zip(&vals[lo..hi]) {
            acc += v * x[*c as usize];
        }
        *yi = acc;
    }
}

/// Transposed, normalized link matrix in CSR form plus dangling info.
#[derive(Debug, Clone, PartialEq)]
pub struct Csr {
    n: usize,
    /// Row pointer, len n+1, width-adaptive. Row i (in-links of page i)
    /// spans `cols[rowptr.at(i)..rowptr.at(i+1)]`.
    rowptr: RowPtr,
    /// Source page of each entry.
    cols: Vec<NodeId>,
    /// Weight of each entry: 1/outdeg(source).
    vals: Vec<f32>,
    /// Pages with zero out-degree (the paper's dangling vector d).
    dangling: Vec<NodeId>,
    /// Out-degree per page (on the ORIGINAL orientation).
    outdeg: Vec<u32>,
}

impl Csr {
    /// Build the normalized transposed matrix from an edge list.
    /// Duplicate edges are collapsed (adjacency is 0/1); self-loops are
    /// kept, matching the usual PageRank treatment of the raw crawl.
    ///
    /// Borrowing forces one copy of the edges (the sort needs an owned
    /// buffer); [`from_edgelist_owned`](Self::from_edgelist_owned)
    /// consumes the list and sorts it in place instead — the variant
    /// the memory-bound paths use.
    pub fn from_edgelist(el: &EdgeList) -> Result<Self> {
        Self::from_pairs(el.n(), el.edges().to_vec())
    }

    /// [`from_edgelist`](Self::from_edgelist) without the edge copy:
    /// consumes the list and sorts its buffer in place, so peak memory
    /// during the build is the edge buffer itself plus the CSR arrays —
    /// never 2× the edges.
    pub fn from_edgelist_owned(el: EdgeList) -> Result<Self> {
        let n = el.n();
        Self::from_pairs(n, el.into_edges())
    }

    fn from_pairs(n: usize, mut pairs: Vec<(NodeId, NodeId)>) -> Result<Self> {
        // dedup: sort by (dst, src) so transposed rows come out sorted;
        // in-place on the caller's buffer — no transient clone
        pairs.sort_unstable_by_key(|&(s, d)| (d, s));
        pairs.dedup();

        // out-degrees on the deduped edge set
        let mut outdeg = vec![0u32; n];
        for &(s, _) in &pairs {
            outdeg[s as usize] += 1;
        }
        let dangling: Vec<NodeId> = (0..n as NodeId)
            .filter(|&i| outdeg[i as usize] == 0)
            .collect();

        let mut rowptr = vec![0usize; n + 1];
        for &(_, d) in &pairs {
            rowptr[d as usize + 1] += 1;
        }
        for i in 0..n {
            rowptr[i + 1] += rowptr[i];
        }
        let mut cols = Vec::with_capacity(pairs.len());
        let mut vals = Vec::with_capacity(pairs.len());
        for &(s, _) in &pairs {
            cols.push(s);
            vals.push(1.0 / outdeg[s as usize] as f32);
        }
        Ok(Csr { n, rowptr: RowPtr::from_usize(rowptr), cols, vals, dangling, outdeg })
    }

    /// Assemble a CSR from already-built parts — the splice path of
    /// `DeltaGraph::merge_csr`, which rebuilds only dirty rows and
    /// copies the rest verbatim. The rowptr narrows automatically when
    /// the nonzeros fit u32 offsets. Debug builds re-validate the full
    /// structural invariants; release builds trust the splicer (the
    /// property suite pins splice == rebuild bit-for-bit).
    pub(crate) fn from_raw_parts(
        n: usize,
        rowptr: Vec<usize>,
        cols: Vec<NodeId>,
        vals: Vec<f32>,
        dangling: Vec<NodeId>,
        outdeg: Vec<u32>,
    ) -> Csr {
        let csr = Csr { n, rowptr: RowPtr::from_usize(rowptr), cols, vals, dangling, outdeg };
        if cfg!(debug_assertions) {
            csr.validate().expect("spliced CSR violates structural invariants");
        }
        csr
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Number of stored nonzeros (== deduped edge count).
    pub fn nnz(&self) -> usize {
        self.cols.len()
    }

    /// Is the row pointer at the compact u32 width?
    pub fn rowptr_is_compact(&self) -> bool {
        matches!(self.rowptr, RowPtr::Narrow(_))
    }

    /// Force the row-pointer width: `true` narrows to u32 offsets
    /// (panics if `nnz > u32::MAX`), `false` widens to usize. The
    /// logical structure is untouched — equality, `spmv`, splices, and
    /// partitioning read identically through either width; this exists
    /// so the equivalence tests (and `--compact-csr`-style overrides)
    /// can pin a specific layout.
    pub fn set_compact_rowptr(&mut self, compact: bool) {
        match (&self.rowptr, compact) {
            (RowPtr::Wide(v), true) => {
                assert!(
                    self.cols.len() <= u32::MAX as usize,
                    "nnz {} does not fit u32 row offsets",
                    self.cols.len()
                );
                self.rowptr = RowPtr::Narrow(v.iter().map(|&o| o as u32).collect());
            }
            (RowPtr::Narrow(v), false) => {
                self.rowptr = RowPtr::Wide(v.iter().map(|&o| o as usize).collect());
            }
            _ => {}
        }
    }

    /// Heap bytes of the materialized structure (rowptr at its actual
    /// width + cols + vals + dangling + outdeg).
    pub fn heap_bytes(&self) -> usize {
        self.rowptr.heap_bytes()
            + self.cols.len() * std::mem::size_of::<NodeId>()
            + self.vals.len() * std::mem::size_of::<f32>()
            + self.dangling.len() * std::mem::size_of::<NodeId>()
            + self.outdeg.len() * std::mem::size_of::<u32>()
    }

    /// What [`heap_bytes`](Self::heap_bytes) would read with the wide
    /// (usize) rowptr layout — the dense-layout estimate the giant
    /// bench compares the compact build against.
    pub fn heap_bytes_wide(&self) -> usize {
        self.heap_bytes() - self.rowptr.heap_bytes()
            + self.rowptr.len() * std::mem::size_of::<usize>()
    }

    pub fn dangling(&self) -> &[NodeId] {
        &self.dangling
    }

    pub fn outdeg(&self) -> &[u32] {
        &self.outdeg
    }

    /// In-degree of page i (row length in this orientation).
    #[inline]
    pub fn row_len(&self, i: usize) -> usize {
        self.rowptr.at(i + 1) - self.rowptr.at(i)
    }

    /// (sources, weights) of row i.
    #[inline]
    pub fn row(&self, i: usize) -> (&[NodeId], &[f32]) {
        let lo = self.rowptr.at(i);
        let hi = self.rowptr.at(i + 1);
        (&self.cols[lo..hi], &self.vals[lo..hi])
    }

    /// y = (P^T) x restricted to rows [row_lo, row_hi).
    ///
    /// This is the native (non-artifact) hot path; the PJRT artifact
    /// computes the same thing through the Pallas kernel.
    pub fn spmv_range(&self, x: &[f32], row_lo: usize, row_hi: usize, y: &mut [f32]) {
        debug_assert_eq!(x.len(), self.n);
        debug_assert_eq!(y.len(), row_hi - row_lo);
        match &self.rowptr {
            RowPtr::Narrow(rp) => spmv_rows(rp, &self.cols, &self.vals, x, row_lo, row_hi, y),
            RowPtr::Wide(rp) => spmv_rows(rp, &self.cols, &self.vals, x, row_lo, row_hi, y),
        }
    }

    /// Full y = (P^T) x.
    pub fn spmv(&self, x: &[f32], y: &mut [f32]) {
        self.spmv_range(x, 0, self.n, y)
    }

    /// Dangling mass d·x (sum of x over dangling pages).
    pub fn dangling_dot(&self, x: &[f32]) -> f32 {
        self.dangling.iter().map(|&i| x[i as usize]).sum()
    }

    /// Column sums of P^T (i.e., row sums of P): 1.0 for non-dangling
    /// pages, 0.0 for dangling. Used by validation tests.
    pub fn column_sums(&self) -> Vec<f64> {
        let mut sums = vec![0.0f64; self.n];
        for i in 0..self.n {
            let (cols, vals) = self.row(i);
            for (c, v) in cols.iter().zip(vals) {
                sums[*c as usize] += *v as f64;
            }
        }
        sums
    }

    /// Validate structural invariants (sorted rows, weight consistency,
    /// stochastic columns) at either rowptr width. Used by tests and
    /// `repro generate --check`.
    pub fn validate(&self) -> Result<()> {
        if self.rowptr.len() != self.n + 1 || self.rowptr.at(self.n) != self.nnz() {
            anyhow::bail!("rowptr malformed");
        }
        if let RowPtr::Narrow(_) = self.rowptr {
            // Narrow requires every offset to fit; monotone offsets make
            // the last one the witness, and it equals nnz (checked above)
            if self.nnz() > u32::MAX as usize {
                anyhow::bail!("narrow rowptr cannot address nnz {}", self.nnz());
            }
        }
        for i in 0..self.n {
            if self.rowptr.at(i) > self.rowptr.at(i + 1) {
                anyhow::bail!("rowptr not monotone at {i}");
            }
            let (cols, vals) = self.row(i);
            for w in cols.windows(2) {
                if w[0] >= w[1] {
                    anyhow::bail!("row {i} not strictly sorted");
                }
            }
            for (c, v) in cols.iter().zip(vals) {
                let want = 1.0 / self.outdeg[*c as usize] as f32;
                if (v - want).abs() > 1e-7 {
                    anyhow::bail!("row {i}: weight {v} != 1/outdeg {want}");
                }
            }
        }
        for (j, s) in self.column_sums().iter().enumerate() {
            let want = if self.outdeg[j] == 0 { 0.0 } else { 1.0 };
            if (s - want).abs() > 1e-4 {
                anyhow::bail!("column {j} sums to {s}, want {want}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// 4-page toy web: 0->1, 0->2, 1->2, 2->0, 3 dangling.
    fn toy() -> Csr {
        let el = EdgeList::from_edges(4, vec![(0, 1), (0, 2), (1, 2), (2, 0)]).unwrap();
        Csr::from_edgelist(&el).unwrap()
    }

    #[test]
    fn builds_transposed_normalized() {
        let g = toy();
        assert_eq!(g.n(), 4);
        assert_eq!(g.nnz(), 4);
        assert_eq!(g.dangling(), &[3]);
        assert_eq!(g.outdeg(), &[2, 1, 1, 0]);
        // row 0 (in-links of 0): from 2 with weight 1/1
        assert_eq!(g.row(0), (&[2][..], &[1.0][..]));
        // row 2 (in-links of 2): from 0 (1/2) and 1 (1/1)
        let (c, v) = g.row(2);
        assert_eq!(c, &[0, 1]);
        assert_eq!(v, &[0.5, 1.0]);
        g.validate().unwrap();
    }

    #[test]
    fn dedups_parallel_edges() {
        let el = EdgeList::from_edges(2, vec![(0, 1), (0, 1), (0, 1)]).unwrap();
        let g = Csr::from_edgelist(&el).unwrap();
        assert_eq!(g.nnz(), 1);
        assert_eq!(g.outdeg(), &[1, 0]);
    }

    #[test]
    fn spmv_matches_dense() {
        let g = toy();
        let x = [0.1f32, 0.2, 0.3, 0.4];
        let mut y = [0.0f32; 4];
        g.spmv(&x, &mut y);
        // dense P^T rows: r0: x2; r1: 0.5 x0; r2: 0.5 x0 + x1; r3: 0
        let want = [0.3, 0.05, 0.05 + 0.2, 0.0];
        for (a, b) in y.iter().zip(&want) {
            assert!((a - b).abs() < 1e-6, "{y:?} vs {want:?}");
        }
    }

    #[test]
    fn spmv_range_is_slice_of_full() {
        let g = toy();
        let x = [0.4f32, 0.3, 0.2, 0.1];
        let mut full = [0.0f32; 4];
        g.spmv(&x, &mut full);
        let mut part = [0.0f32; 2];
        g.spmv_range(&x, 1, 3, &mut part);
        assert_eq!(&full[1..3], &part);
    }

    #[test]
    fn dangling_dot() {
        let g = toy();
        assert_eq!(g.dangling_dot(&[0.1, 0.2, 0.3, 0.4]), 0.4);
    }

    #[test]
    fn column_sums_stochastic() {
        let g = toy();
        let s = g.column_sums();
        assert!((s[0] - 1.0).abs() < 1e-6);
        assert!((s[1] - 1.0).abs() < 1e-6);
        assert!((s[2] - 1.0).abs() < 1e-6);
        assert_eq!(s[3], 0.0);
    }

    #[test]
    fn empty_graph_all_dangling() {
        let g = Csr::from_edgelist(&EdgeList::new(3)).unwrap();
        assert_eq!(g.nnz(), 0);
        assert_eq!(g.dangling().len(), 3);
        g.validate().unwrap();
    }

    #[test]
    fn builds_compact_by_default_and_widths_compare_equal() {
        let g = toy();
        assert!(g.rowptr_is_compact(), "small graphs must take the u32 tier");
        let mut wide = g.clone();
        wide.set_compact_rowptr(false);
        assert!(!wide.rowptr_is_compact());
        wide.validate().unwrap();
        // width is representation, not identity
        assert_eq!(g, wide);
        // and the footprint ordering is what the tier exists for
        assert!(wide.heap_bytes() > g.heap_bytes());
        assert_eq!(g.heap_bytes_wide(), wide.heap_bytes());
        assert_eq!(wide.heap_bytes_wide(), wide.heap_bytes());
        // round-trip back to compact restores the exact layout
        let mut back = wide.clone();
        back.set_compact_rowptr(true);
        assert!(back.rowptr_is_compact());
        assert_eq!(back.heap_bytes(), g.heap_bytes());
    }

    #[test]
    fn wide_rowptr_reads_identically() {
        let el = EdgeList::from_edges(4, vec![(0, 1), (0, 2), (1, 2), (2, 0)]).unwrap();
        let g = Csr::from_edgelist(&el).unwrap();
        let mut wide = g.clone();
        wide.set_compact_rowptr(false);
        for i in 0..g.n() {
            assert_eq!(g.row(i), wide.row(i));
            assert_eq!(g.row_len(i), wide.row_len(i));
        }
        let x = [0.4f32, 0.3, 0.2, 0.1];
        let (mut y0, mut y1) = ([0.0f32; 4], [0.0f32; 4]);
        g.spmv(&x, &mut y0);
        wide.spmv(&x, &mut y1);
        assert_eq!(y0, y1);
    }

    #[test]
    fn owned_build_matches_borrowed() {
        let el = EdgeList::from_edges(5, vec![(0, 1), (2, 3), (2, 3), (4, 0), (1, 4)]).unwrap();
        let a = Csr::from_edgelist(&el).unwrap();
        let b = Csr::from_edgelist_owned(el).unwrap();
        assert_eq!(a, b);
    }
}
