//! Web-graph substrate: structures, generators, and IO.
//!
//! The paper's matrices are built from a crawl-derived adjacency matrix
//! (Stanford-Web: 281,903 pages, 2,312,497 links, 172 dangling). We
//! implement the full pipeline: edge lists → CSR (by source) →
//! transposed CSR (the `P^T` the iteration multiplies by) → padded
//! ELLPACK with virtual-row splitting (the accelerator layout, see
//! DESIGN.md §Hardware-Adaptation).
//!
//! Since the original dataset is not redistributable with this repo,
//! [`generators::stanford_web_like`] synthesizes a power-law web graph
//! with matched node count, edge count, and dangling-page count
//! (substitution documented in DESIGN.md §3). Real crawls can be loaded
//! through [`io`].

mod csr;
mod edgelist;
mod ell;
pub mod generators;
pub mod io;
mod stats;

pub use csr::Csr;
pub use edgelist::EdgeList;
pub use ell::{Ell, EllBlock};
pub use stats::GraphStats;

/// Node index type. u32 caps us at ~4.2e9 pages, far above the paper's
/// 2.8e5 and comfortably above anything a single host holds anyway.
pub type NodeId = u32;
