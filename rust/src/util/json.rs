//! Minimal JSON parser — substrate built from scratch because the
//! offline build environment carries no serde. Supports the complete
//! JSON grammar (objects, arrays, strings with escapes, numbers,
//! booleans, null); enough for `artifacts/manifest.json` and report
//! files, with precise error positions for debuggability.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset into the input.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse a complete JSON document (trailing whitespace allowed).
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }

    // ---- typed accessors (None on type mismatch) ----

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object member access: `v.get("key")`.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Serialize back to compact JSON text (used by report emitters).
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32))
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(&format!("unexpected character '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            self.i += 1;
                            let code = self.hex4()?;
                            // surrogate pair handling
                            let ch = if (0xD800..0xDC00).contains(&code) {
                                if self.b[self.i..].starts_with(b"\\u") {
                                    self.i += 2;
                                    let low = self.hex4()?;
                                    let c = 0x10000
                                        + ((code - 0xD800) << 10)
                                        + (low - 0xDC00);
                                    char::from_u32(c)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(code)
                            };
                            s.push(ch.ok_or_else(|| self.err("invalid \\u escape"))?);
                            continue; // hex4 advanced i already
                        }
                        _ => return Err(self.err("invalid escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let txt = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| self.err("invalid \\u escape"))?;
        let v = u32::from_str_radix(txt, 16).map_err(|_| self.err("invalid \\u escape"))?;
        self.i += 4;
        Ok(v)
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = Json::parse(r#""é\t\\\" 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é\t\\\" 😀");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("{\"a\":1} x").is_err());
        assert!(Json::parse("\"abc").is_err());
    }

    #[test]
    fn roundtrip_compact() {
        let src = r#"{"arr":[1,2.5,"s"],"b":true,"n":null}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn typed_accessors() {
        let v = Json::parse(r#"{"n": 7, "f": 7.5}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_usize(), Some(7));
        assert_eq!(v.get("f").unwrap().as_usize(), None);
        assert_eq!(v.get("f").unwrap().as_f64(), Some(7.5));
        assert!(v.get("missing").is_none());
    }
}
