//! Micro-benchmark harness — substrate built from scratch (criterion is
//! unavailable offline). Provides warmup, repeated timed runs, and
//! mean/σ/min/max reporting; `benches/*.rs` (harness = false) binaries
//! use it and print the paper's table rows.

use std::time::{Duration, Instant};

/// Result of one benchmark: timings over the measured iterations.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub stddev: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl BenchStats {
    pub fn report(&self) -> String {
        format!(
            "{:<40} {:>10.3?} ±{:>9.3?}  (min {:.3?}, max {:.3?}, n={})",
            self.name, self.mean, self.stddev, self.min, self.max, self.iters
        )
    }
}

/// Benchmark runner. Honors `BENCH_FAST=1` (few iterations — used by
/// `cargo test`-adjacent smoke runs) to keep CI time bounded.
pub struct Bench {
    warmup: usize,
    iters: usize,
}

impl Default for Bench {
    fn default() -> Self {
        if std::env::var("BENCH_FAST").ok().as_deref() == Some("1") {
            Bench { warmup: 1, iters: 3 }
        } else {
            Bench { warmup: 2, iters: 10 }
        }
    }
}

impl Bench {
    pub fn new(warmup: usize, iters: usize) -> Self {
        Bench { warmup, iters }
    }

    /// Time `f` (which should perform one complete unit of work) after
    /// warmup, and return stats.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchStats {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed());
        }
        stats(name, &samples)
    }
}

fn stats(name: &str, samples: &[Duration]) -> BenchStats {
    let n = samples.len().max(1) as f64;
    let secs: Vec<f64> = samples.iter().map(|d| d.as_secs_f64()).collect();
    let mean = secs.iter().sum::<f64>() / n;
    let var = secs.iter().map(|s| (s - mean).powi(2)).sum::<f64>() / n;
    BenchStats {
        name: name.to_string(),
        iters: samples.len(),
        mean: Duration::from_secs_f64(mean),
        stddev: Duration::from_secs_f64(var.sqrt()),
        min: *samples.iter().min().unwrap_or(&Duration::ZERO),
        max: *samples.iter().max().unwrap_or(&Duration::ZERO),
    }
}

/// Markdown table emitter for paper-style rows.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity");
        self.rows.push(cells.to_vec());
    }

    pub fn to_markdown(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(&widths) {
                s.push_str(&format!(" {:<w$} |", c, w = w));
            }
            s
        };
        let mut out = fmt_row(&self.header);
        out.push('\n');
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{:-<w$}|", "", w = w + 2));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let b = Bench::new(1, 3);
        let mut count = 0;
        let s = b.run("noop", || count += 1);
        assert_eq!(count, 4); // warmup + iters
        assert_eq!(s.iters, 3);
        assert!(s.report().contains("noop"));
    }

    #[test]
    fn stats_sane() {
        let samples = vec![Duration::from_millis(10); 5];
        let s = stats("x", &samples);
        assert_eq!(s.mean, Duration::from_millis(10));
        assert_eq!(s.stddev, Duration::ZERO);
        assert_eq!(s.min, s.max);
    }

    #[test]
    fn table_markdown() {
        let mut t = Table::new(&["procs", "iters"]);
        t.row(&["2".into(), "44".into()]);
        let md = t.to_markdown();
        assert!(md.contains("| procs | iters |"));
        assert!(md.contains("| 2     | 44    |"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_rejects_bad_arity() {
        let mut t = Table::new(&["a"]);
        t.row(&["1".into(), "2".into()]);
    }
}
