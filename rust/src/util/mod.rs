//! From-scratch substrates the offline build requires: JSON parsing
//! (no serde), deterministic PRNG (no rand), and a micro-benchmark
//! harness (no criterion). Each is small, fully tested, and used
//! across the crate.

pub mod harness;
pub mod json;
pub mod rng;

pub use harness::{Bench, BenchStats, Table};
pub use json::Json;
pub use rng::Rng;
