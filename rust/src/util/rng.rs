//! Deterministic PRNG — substrate built from scratch (no `rand` in the
//! offline build). SplitMix64 for seeding, xoshiro256++ for the stream:
//! the standard pairing, fast and statistically solid for simulation
//! use (graph generation, network jitter, property tests).
//!
//! Every experiment takes an explicit `u64` seed so tables regenerate
//! bit-identically; see DESIGN.md §3 (determinism substitution).

/// xoshiro256++ seeded via SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed deterministically from a u64.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw u64.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 top bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    ///
    /// Generated from 24 mantissa bits directly — *not* by narrowing
    /// [`f64`](Self::f64): an f64 draw in `[1 − 2⁻²⁵, 1)` rounds up to
    /// exactly `1.0f32` under nearest-even, violating the half-open
    /// contract (and indexing one-past-end when scaled by a length).
    /// The largest value here is `(2²⁴−1)/2²⁴ < 1`, which is exact in
    /// f32, so the contract holds for every bit pattern.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        // 24 top bits -> [0,1)
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, bound) via Lemire's method (unbiased).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo, "empty range");
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Bernoulli(p).
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (single value; the twin is dropped
    /// to keep the state machine simple — simulation use only).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Exponential with rate lambda (inter-arrival jitter in simnet).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        -self.f64().max(1e-300).ln() / lambda
    }

    /// Integer sample from a discrete power law P(k) ∝ k^(-gamma) on
    /// [kmin, kmax], via inverse-CDF on the continuous approximation.
    /// This is the out-degree law for web graphs (Broder et al. report
    /// gamma ≈ 2.72 for in-degree, ≈ 2.1 for out-degree).
    pub fn power_law(&mut self, kmin: f64, kmax: f64, gamma: f64) -> f64 {
        assert!(kmin > 0.0 && kmax > kmin && gamma > 1.0);
        let u = self.f64();
        let a = 1.0 - gamma;
        let lo = kmin.powf(a);
        let hi = kmax.powf(a);
        (lo + u * (hi - lo)).powf(1.0 / a)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates on
    /// an index map; O(k) memory for k << n via hash map).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_distinct: k > n");
        use std::collections::HashMap;
        let mut swapped: HashMap<usize, usize> = HashMap::new();
        let mut out = Vec::with_capacity(k);
        for i in 0..k {
            let j = self.range(i, n);
            let vi = *swapped.get(&i).unwrap_or(&i);
            let vj = *swapped.get(&j).unwrap_or(&j);
            out.push(vj);
            swapped.insert(j, vi);
        }
        out
    }

    /// Derive an independent stream (for per-UE rngs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f32_boundary_mapping_is_exhaustively_half_open() {
        // the regression this guards against: narrowing an f64 draw in
        // [1 − 2⁻²⁵, 1) rounds up to exactly 1.0f32 under nearest-even
        let worst_f64 = 1.0 - 0.25 / (1u64 << 23) as f64; // 1 − 2⁻²⁵
        assert_eq!(worst_f64 as f32, 1.0f32, "narrowing must stay a faithful repro of the bug");

        // exhaustive over the top mantissa patterns (where rounding
        // could reach 1.0) and the bottom ones (the zero boundary)
        let scale = 1.0f32 / (1u64 << 24) as f32;
        for m in (0u64..4096).chain(((1u64 << 24) - 4096)..(1u64 << 24)) {
            let x = m as f32 * scale;
            assert!((0.0..1.0).contains(&x), "mantissa {m:#x} -> {x}");
        }
        let max = ((1u64 << 24) - 1) as f32 * scale;
        assert_eq!(max, 1.0 - scale, "largest draw is (2²⁴−1)/2²⁴ exactly");

        // and the method implements exactly that mapping on the top
        // 24 bits of the raw stream
        let mut r = Rng::new(42);
        let mut probe = r.clone();
        for _ in 0..10_000 {
            let raw = probe.next_u64();
            let x = r.f32();
            assert_eq!(x, (raw >> 40) as f32 * scale);
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_bounds() {
        let mut r = Rng::new(4);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn uniform_mean_close() {
        let mut r = Rng::new(5);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn power_law_in_range() {
        let mut r = Rng::new(6);
        for _ in 0..1000 {
            let k = r.power_law(1.0, 100.0, 2.1);
            assert!((1.0..=100.0).contains(&k));
        }
    }

    #[test]
    fn sample_distinct_is_distinct() {
        let mut r = Rng::new(8);
        for _ in 0..50 {
            let s = r.sample_distinct(20, 10);
            let mut t = s.clone();
            t.sort_unstable();
            t.dedup();
            assert_eq!(t.len(), 10, "{s:?}");
            assert!(s.iter().all(|&x| x < 20));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut ys = xs.clone();
        ys.sort_unstable();
        assert_eq!(ys, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(10);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn fork_streams_independent_prefixes() {
        let mut base = Rng::new(11);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        let av: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let bv: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(av, bv);
    }
}
