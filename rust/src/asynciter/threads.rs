//! Real-thread execution backend — the paper's actual implementation
//! style (§5.1: computation objects on threads, non-blocking sends via
//! thread-wrapped blocking channels, bounded queues, a monitor running
//! the Figure-1 protocol).
//!
//! Where [`super::SimEngine`] runs the cluster under a deterministic
//! virtual clock (used for every paper table), `ThreadEngine` runs the
//! same block operators on actual OS threads with `std::sync::mpsc`
//! channels and wall-clock time — the deployment path for a real
//! multicore host, and a cross-check that the asynchronous iteration
//! converges under genuine nondeterministic interleaving.
//!
//! Design notes:
//! * fragments flow through bounded channels; a full channel DROPS the
//!   fragment (the §6 cancellation window, in its simplest form) —
//!   asynchronous iterations tolerate loss, so this is safe;
//! * workers own `NativeBlockOp`s (PJRT handles are not `Send`; the
//!   artifact path stays on the simulator / main thread);
//! * the monitor thread runs the same `MonitorTermination` state
//!   machine used by the simulator.
//!
//! The second backend in this module, [`run_threaded_push`], runs the
//! residual-push solver ([`crate::stream::ShardedPush`]) on the same
//! thread/channel fabric but with the opposite loss discipline:
//! residual fragments are additive, so a full channel *defers* instead
//! of dropping, and the gathered state is exact whatever the schedule.
//! Its channels also carry the intra-epoch work-stealing protocol
//! ([`PushThreadOptions::steal`]): steal requests, and grants that
//! transfer row ownership with the same never-lost in-flight
//! accounting as the fragments.
//!
//! With [`PushThreadOptions::net`] set, the same worker loop routes its
//! entire exchange — fragments, steal traffic, head frames, §4.2
//! control — over a [`crate::net`] transport as serialized wire frames
//! instead of mpsc channels, with the in-flight release re-routed
//! through the monitor as explicit Ack frames (the serialized form of
//! the DIVERGE-before-acknowledge discipline — see the `PushLink` /
//! `TermSide` internals below).

use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, AtomicU8, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Barrier, Mutex};
use std::time::Instant;

use crate::net::{
    LoopbackEndpoint, LoopbackNet, NetConfig, SendFail, Transport, WireHeadFrame, WireMsg, WireRow,
};
use crate::obs::{Event, EventKind, EventRing, EventTotals, Sample, TraceCollector, MONITOR_TRACK};
use crate::pagerank::PagerankProblem;
use crate::stream::{
    certify_frames, shard_frame, DeltaGraph, HeadList, ResidualFragment, ShardHeadFrame,
    ShardedPush, StealGrant, StolenRow, TopKCertificate, TopKGoal, TopKTracker,
};
use crate::termination::{
    term_channel, MonitorPort, MonitorTermination, TermMsg, TermPort, WireMonitor,
    WorkerTermination,
};

/// Options for a threaded run.
#[derive(Debug, Clone)]
pub struct ThreadRunOptions {
    pub tol: f32,
    pub pc_max_worker: u32,
    pub pc_max_monitor: u32,
    /// Per-link fragment queue depth; a full queue drops the fragment.
    pub channel_depth: usize,
    /// Hard wall-clock cap.
    pub timeout: std::time::Duration,
    /// Minimum wall time per iteration. Real deployments have heavy
    /// per-iteration compute (the paper: ~1.3 s of SpMV); on an
    /// oversubscribed test host a floor keeps the OS scheduler
    /// interleaving workers, so DIVERGE messages can actually race
    /// STOP the way they do on a real cluster.
    pub min_iteration_interval: std::time::Duration,
}

impl Default for ThreadRunOptions {
    fn default() -> Self {
        ThreadRunOptions {
            tol: 1e-6,
            // stricter than the simulator's paper setting: real threads
            // iterate microseconds apart, so a little persistence guards
            // against converging on a not-yet-imported view
            pc_max_worker: 3,
            pc_max_monitor: 1,
            channel_depth: 2,
            timeout: std::time::Duration::from_secs(60),
            min_iteration_interval: std::time::Duration::from_micros(200),
        }
    }
}

/// Outcome of a threaded run.
#[derive(Debug, Clone)]
pub struct ThreadRunMetrics {
    pub iters: Vec<u64>,
    /// Fragments dropped on full channels, per sender.
    pub dropped: Vec<u64>,
    pub wall: std::time::Duration,
    pub x: Vec<f32>,
    pub final_global_residual: f32,
}

struct Fragment {
    src: usize,
    data: Vec<f32>,
}

/// Run the asynchronous iteration on real threads (one per UE, plus the
/// Figure-1 monitor inline on the coordinator thread).
pub fn run_threaded(
    problem: &Arc<PagerankProblem>,
    blocks: &[(usize, usize)],
    opts: &ThreadRunOptions,
) -> ThreadRunMetrics {
    let p = blocks.len();
    assert!(p >= 1);
    let n = problem.n();
    assert_eq!(blocks[0].0, 0);
    assert_eq!(blocks[p - 1].1, n);

    let stop = Arc::new(AtomicBool::new(false));
    // all workers start iterating together (the paper's §5.1 launch
    // phase distributes data first); without this, thread-startup skew
    // lets the first worker converge on frozen data before its peers
    // have produced a single fragment
    let start = Arc::new(std::sync::Barrier::new(p));
    let t0 = Instant::now();

    // fragment channels: frag_tx[dst][src] -> frag_rx[dst]
    let mut frag_tx: Vec<Vec<SyncSender<Fragment>>> = Vec::with_capacity(p);
    let mut frag_rx: Vec<Option<Receiver<Fragment>>> = Vec::with_capacity(p);
    for _ in 0..p {
        let (tx, rx) = sync_channel::<Fragment>(opts.channel_depth.max(1) * p);
        frag_tx.push(vec![tx; p]);
        frag_rx.push(Some(rx));
    }
    // control channel to the monitor
    let (ctl_tx, ctl_rx) = sync_channel::<(usize, TermMsg)>(p * 8);

    let results: Vec<_> = std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(p);
        for ue in 0..p {
            let (lo, hi) = blocks[ue];
            let problem = Arc::clone(problem);
            let stop = Arc::clone(&stop);
            let ctl_tx = ctl_tx.clone();
            // senders to every peer's inbox slot for this src
            let peers: Vec<(usize, SyncSender<Fragment>)> = (0..p)
                .filter(|&j| j != ue)
                .map(|j| (j, frag_tx[j][ue].clone()))
                .collect();
            let rx = frag_rx[ue].take().unwrap();
            let opts = opts.clone();
            let start = Arc::clone(&start);
            handles.push(scope.spawn(move || {
                start.wait();
                let mut x = problem.uniform_start();
                let mut out = vec![0.0f32; hi - lo];
                let mut term = WorkerTermination::new(opts.pc_max_worker);
                let mut iters = 0u64;
                let mut dropped = 0u64;
                let deadline = Instant::now() + opts.timeout;
                while !stop.load(Ordering::Relaxed) && Instant::now() < deadline {
                    let iter_start = Instant::now();
                    // import everything currently queued (non-blocking)
                    while let Ok(frag) = rx.try_recv() {
                        let (flo, fhi) = blocks[frag.src];
                        x[flo..fhi].copy_from_slice(&frag.data);
                    }
                    // one local update (eq. 6)
                    problem.apply_google_range(&x, lo, hi, &mut out);
                    let resid = crate::pagerank::l1_diff(&out, &x[lo..hi]);
                    x[lo..hi].copy_from_slice(&out);
                    iters += 1;
                    // non-blocking sends; full queue == cancelled thread
                    for (_, tx) in &peers {
                        match tx.try_send(Fragment { src: ue, data: out.clone() }) {
                            Ok(()) => {}
                            Err(TrySendError::Full(_)) => dropped += 1,
                            Err(TrySendError::Disconnected(_)) => {}
                        }
                    }
                    if let Some(msg) = term.on_iteration(resid < opts.tol) {
                        let _ = ctl_tx.try_send((ue, msg));
                    }
                    let spent = iter_start.elapsed();
                    if spent < opts.min_iteration_interval {
                        std::thread::sleep(opts.min_iteration_interval - spent);
                    }
                }
                (iters, dropped, x)
            }));
        }
        drop(ctl_tx);

        // Figure-1 monitor, inline
        let mut monitor = MonitorTermination::new(p, opts.pc_max_monitor);
        let deadline = Instant::now() + opts.timeout;
        while !stop.load(Ordering::Relaxed) && Instant::now() < deadline {
            match ctl_rx.recv_timeout(std::time::Duration::from_millis(5)) {
                Ok((ue, msg)) => {
                    if monitor.on_message(ue, msg) {
                        stop.store(true, Ordering::Relaxed);
                    }
                }
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        stop.store(true, Ordering::Relaxed);
        handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
    });

    // assemble the final vector from each worker's own block
    let mut x = vec![0.0f32; n];
    let mut iters = Vec::with_capacity(p);
    let mut dropped = Vec::with_capacity(p);
    for (ue, (it, dr, xw)) in results.into_iter().enumerate() {
        let (lo, hi) = blocks[ue];
        x[lo..hi].copy_from_slice(&xw[lo..hi]);
        iters.push(it);
        dropped.push(dr);
    }
    let mut scratch = vec![0.0f32; n];
    problem.apply_google(&x, &mut scratch);
    let resid = crate::pagerank::l1_diff(&scratch, &x);

    ThreadRunMetrics {
        iters,
        dropped,
        wall: t0.elapsed(),
        x,
        final_global_residual: resid,
    }
}

// ---------------------------------------------------------------------
// Residual-push backend: true distributed D-Iteration on threads.
// ---------------------------------------------------------------------

/// How the multi-shard monitor of [`run_threaded_push`] decides the
/// run is globally done.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TermMode {
    /// The paper's §4.2 persistence-counter protocol (Figure 1):
    /// workers announce CONVERGE after [`PushThreadOptions::pc_max`]
    /// persistent locally-converged rounds, retract with DIVERGE the
    /// moment residual mass arrives, and the monitor STOPs only when
    /// every worker's last word was CONVERGE. Sound: a protocol STOP
    /// implies the exact gathered residual is under `tol` (see the
    /// "Termination" section of ARCHITECTURE.md for the argument).
    Protocol,
    /// The legacy quiet-window heuristic: stop after
    /// [`PushThreadOptions::quiet_checks`] consecutive monitor samples
    /// saw the published residual sum under `tol` with nothing in
    /// flight. Unsound under worker stalls — a descheduled worker's
    /// *stale* published estimate hides mass it has applied but not
    /// yet re-published — and kept only as a raceable baseline.
    Quiet,
}

impl TermMode {
    /// Stable display name (CLI value, stream-table cell).
    pub fn name(self) -> &'static str {
        match self {
            TermMode::Protocol => "protocol",
            TermMode::Quiet => "quiet",
        }
    }
}

/// Why a [`run_threaded_push`] run stopped. Exactly one cause wins per
/// run (first writer), reported in [`PushThreadMetrics::stop_cause`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum StopCause {
    /// The §4.2 monitor issued STOP: every worker announced CONVERGE
    /// and none retracted. Implies exact residual < tol.
    Protocol = 0,
    /// The quiet-window heuristic fired ([`TermMode::Quiet`] only).
    /// Does NOT imply convergence — check the exact residual.
    QuietWindow = 1,
    /// The monitor stopped on a tentative top-k certificate
    /// ([`PushThreadOptions::topk`]).
    TopK = 2,
    /// A worker exhausted its slice of
    /// [`PushThreadOptions::max_pushes`].
    Budget = 3,
    /// The wall-clock [`PushThreadOptions::timeout`] fired.
    Timeout = 4,
    /// The single-shard fast path's deterministic drain ran itself dry
    /// (no monitor involved).
    Converged = 5,
}

impl StopCause {
    /// Stable display name (stream-table cell, bench JSON).
    pub fn name(self) -> &'static str {
        match self {
            StopCause::Protocol => "protocol",
            StopCause::QuietWindow => "quiet",
            StopCause::TopK => "topk",
            StopCause::Budget => "budget",
            StopCause::Timeout => "timeout",
            StopCause::Converged => "converged",
        }
    }

    fn from_u8(v: u8) -> Option<StopCause> {
        match v {
            0 => Some(StopCause::Protocol),
            1 => Some(StopCause::QuietWindow),
            2 => Some(StopCause::TopK),
            3 => Some(StopCause::Budget),
            4 => Some(StopCause::Timeout),
            5 => Some(StopCause::Converged),
            _ => None,
        }
    }
}

/// Sentinel for "no stop cause recorded yet" in the shared cell.
const CAUSE_UNSET: u8 = u8::MAX;

/// Record `cause` if no cause won yet — the first stop decision of a
/// run is the one reported, later racers are ignored. MUST be called
/// *before* the corresponding `stop.store(true)`: the soundness claim
/// for [`StopCause::Protocol`] leans on "no worker exited the round
/// loop before the protocol's deciding CONVERGE was processed", which
/// holds exactly because every stop is preceded by its cause.
fn record_stop_cause(cell: &AtomicU8, cause: StopCause) {
    let _ = cell.compare_exchange(CAUSE_UNSET, cause as u8, Ordering::AcqRel, Ordering::Acquire);
}

/// Fault injection for termination experiments
/// ([`PushThreadOptions::inject_stall`]): the chosen worker sleeps once,
/// mid-solve — after importing its inbox, before draining/publishing.
/// That window is exactly where the quiet-window heuristic is unsound
/// (the worker holds freshly-applied residual its *published* estimate
/// does not show), and where the §4.2 protocol provably is not (the
/// stalled worker simply never announces).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StallInjection {
    /// Worker (shard index) to stall. Out-of-range indices stall
    /// nobody.
    pub worker: usize,
    /// Round at which the sleep happens (0 = before the worker's first
    /// drain, i.e. before it ever publishes an estimate).
    pub after_rounds: u64,
    /// Sleep length in milliseconds.
    pub ms: u64,
}

/// Options for a threaded residual-push run.
#[derive(Debug, Clone)]
pub struct PushThreadOptions {
    /// Global residual target
    /// `Σ_s (‖r_s‖₁ + |uni_s|·|B_s|/n + |pv_s|·vshare_s/Σv) < tol`
    /// (the `pv` term is zero on the uniform path).
    pub tol: f64,
    /// Local pushes each shard spends between channel services.
    pub round_pushes: u64,
    /// Per-inbox fragment queue depth multiplier (actual depth is
    /// `channel_depth * shards`); a full queue defers the fragment —
    /// it is re-accumulated locally and retried, never dropped.
    pub channel_depth: usize,
    /// Hard wall-clock cap (the run stays correct when it fires: the
    /// gathered state is exact, just not converged).
    pub timeout: std::time::Duration,
    /// Total push budget across all shards (safety cap, split evenly
    /// per worker; the first worker to exhaust its slice stops the
    /// run). The state stays exact when it fires.
    pub max_pushes: u64,
    /// How the monitor decides the run converged: the §4.2
    /// persistence-counter protocol (default) or the legacy
    /// quiet-window heuristic. Orthogonal stop reasons — budget,
    /// timeout, tentative top-k certificates — fire under either mode.
    pub term: TermMode,
    /// Worker-side persistence counter for [`TermMode::Protocol`]: a
    /// worker announces CONVERGE only after this many *consecutive*
    /// rounds with its conservative local estimate under `tol/s` and
    /// none of its own sends still in flight. The monitor's own
    /// counter is pinned at 1 (see [`MonitorPort`]).
    pub pc_max: u32,
    /// Consecutive quiet monitor samples required before stopping
    /// ([`TermMode::Quiet`] only; guards against the publish/apply
    /// race around fragment hand-off — but not against stalled
    /// workers, which is why the protocol is the default).
    pub quiet_checks: u32,
    /// Fault injection: stall one worker mid-solve (termination tests
    /// and the `--term` race; `None` in production use).
    pub inject_stall: Option<StallInjection>,
    /// When set, re-balance the shard bounds before spawning workers if
    /// churn has skewed the per-shard out-nnz beyond this factor of the
    /// ideal share ([`ShardedPush::rebalance`]) — the epoch-resident
    /// path's answer to hubs arriving in one shard's row range.
    pub rebalance_factor: Option<f64>,
    /// Intra-epoch work stealing: an idle worker (empty bucket queue,
    /// drained inbox) asks the most-loaded peer — by the published
    /// pressure signal: local queued residual, weighted up by the
    /// top-k hit backlog when a serving goal is armed — for a slice of
    /// its hottest rows, and the victim transfers ownership over the
    /// same bounded channels the residual fragments ride. Grants are
    /// counted in the in-flight accounting (the monitor neither
    /// quiet-stops nor certifies while rows are mid-migration) and a
    /// grant that meets a full channel is restored to the victim —
    /// like fragments, never lost. Complements the *between-epoch*
    /// re-balancer ([`ShardedPush::rebalance`]): rebalancing fixes
    /// durable nnz skew, stealing fixes transient residual skew inside
    /// one solve.
    pub steal: bool,
    /// Rows per steal grant (only with [`steal`](Self::steal)).
    pub steal_batch: usize,
    /// Serving-path early stop: workers stream per-shard head-candidate
    /// frames to the monitor alongside their residual estimates, and
    /// the run winds down as soon as the merged frames *tentatively*
    /// certify this top-k goal (see [`crate::stream::TopKTracker`]).
    /// Tentative because worker frames are asynchronous snapshots — the
    /// caller must re-check on the gathered/settled state (an exact
    /// [`TopKTracker::check_sharded`] call) and resume if the exact
    /// check fails. Ignored on the single-shard fast path (drive that
    /// with [`crate::stream::solve_certified_sharded`] instead).
    ///
    /// [`TopKTracker::check_sharded`]: crate::stream::TopKTracker::check_sharded
    pub topk: Option<TopKGoal>,
    /// Observability sink ([`crate::obs`]): when set, each worker
    /// records typed events into its own lock-free ring (one track per
    /// shard, relaxed-atomic cursor) and the monitor samples the
    /// published residual / queued-mass / in-flight / pressure boards
    /// into the residual-decay time series. `None` (the default) keeps
    /// the per-push hot path untouched — nothing records from inside
    /// `drain`, so the disabled cost is structurally zero. Falls back
    /// to the collector attached to the state
    /// ([`ShardedPush::attach_trace`]) when unset.
    pub trace: Option<Arc<TraceCollector>>,
    /// Process-boundary mode: when set, the exchange rides a throttled
    /// [`crate::net::LoopbackNet`] (bandwidth/latency curves from the
    /// config's [`crate::simnet::ClusterProfile`], plus its
    /// deterministic fault schedule) as serialized wire frames instead
    /// of mpsc channels. Termination control crosses the same fabric,
    /// and the in-flight release travels through the monitor as Ack
    /// frames so the DIVERGE-before-acknowledge ordering survives the
    /// loss of the single shared control queue. Ignored on the
    /// single-shard fast path (one shard has no wire).
    pub net: Option<NetConfig>,
}

impl Default for PushThreadOptions {
    fn default() -> Self {
        PushThreadOptions {
            tol: 1e-10,
            round_pushes: 4096,
            channel_depth: 4,
            timeout: std::time::Duration::from_secs(30),
            max_pushes: u64::MAX,
            term: TermMode::Protocol,
            pc_max: 3,
            quiet_checks: 3,
            inject_stall: None,
            rebalance_factor: None,
            steal: false,
            steal_batch: 64,
            topk: None,
            trace: None,
            net: None,
        }
    }
}

/// Outcome of a threaded residual-push run.
#[derive(Debug, Clone)]
pub struct PushThreadMetrics {
    /// Pushes performed per shard.
    pub shard_pushes: Vec<u64>,
    /// Drain/exchange rounds per shard.
    pub rounds: Vec<u64>,
    /// Residual fragments delivered per shard.
    pub fragments_sent: Vec<u64>,
    /// Fragments deferred on a full channel (retried later) per shard.
    pub fragments_deferred: Vec<u64>,
    /// Rows each shard adopted through steal grants (all zero unless
    /// [`PushThreadOptions::steal`]).
    pub stolen_rows: Vec<u64>,
    /// Steal grants each shard issued as a victim.
    pub steal_grants: Vec<u64>,
    /// Rounds each worker spent idle (nothing pushed, nothing
    /// received) — the quiet-window stalls work stealing exists to
    /// eliminate; the steal-vs-static bench reads this.
    pub idle_rounds: Vec<u64>,
    pub wall: std::time::Duration,
    /// Exact residual mass after the run (re-tallied, outboxes
    /// delivered).
    pub residual: f64,
    /// Whether `residual < tol` — when false (timeout or a premature
    /// quiet window), the caller finishes the solve sequentially; the
    /// state is exact either way.
    pub converged: bool,
    /// Whether the pre-run skew check migrated the shard bounds
    /// (only with [`PushThreadOptions::rebalance_factor`]).
    pub rebalanced: bool,
    /// Whether the monitor cut the run on a *tentative* top-k
    /// certification (only with [`PushThreadOptions::topk`]; the caller
    /// re-checks exactly on the settled state).
    pub topk_stopped: bool,
    /// Why the run stopped — exactly one cause per run, the first stop
    /// decision made. [`StopCause::Protocol`] implies `converged`.
    pub stop_cause: StopCause,
    /// CONVERGE announcements the workers shipped to the §4.2 monitor
    /// (zero under [`TermMode::Quiet`] and on the single-shard path).
    pub term_converge: u64,
    /// DIVERGE retractions the workers shipped — each one is a
    /// premature stop the protocol prevented and the quiet window
    /// could have taken.
    pub term_diverge: u64,
    /// Per-shard drained event totals (indexed like `shard_pushes`),
    /// populated when a trace collector was attached
    /// ([`PushThreadOptions::trace`]); `None` otherwise. Totals are
    /// lifetime counters, exact even when a ring overflowed.
    pub events: Option<Vec<EventTotals>>,
}

/// What travels on a push worker's inbox channel: residual mass, a
/// steal request (no mass — just the thief's id), or a steal grant
/// (rows mid-migration; counted in flight like fragments). Mass-bearing
/// messages carry their origin so the receiver can release the
/// *sender's* per-origin in-flight slot — the counter the §4.2
/// announce predicate reads ("none of MY sends still unapplied").
enum PushMsg {
    Frag { src: usize, frag: ResidualFragment },
    StealRequest { thief: usize },
    Grant { src: usize, grant: StealGrant },
}

/// What one push worker hands back when it joins.
struct PushWorkerStats {
    pushes: u64,
    rounds: u64,
    sent: u64,
    deferred: u64,
    stolen_in: u64,
    grants_out: u64,
    idle: u64,
    /// CONVERGE / DIVERGE messages this worker shipped (protocol mode).
    term_converge: u64,
    term_diverge: u64,
}

/// The steal-policy pressure signal a worker publishes (and a victim
/// re-evaluates before granting): *grantable* queued residual — home
/// rows only, since adopted rows are never re-stolen — weighted up by
/// the top-k hit backlog when a serving goal is armed (a shard
/// churning the head is the one whose rows the certificate waits on).
/// Thief selection and victim defense MUST use this same quantity, or
/// a thief could keep targeting a peer that is guaranteed to refuse
/// and stall out its patience window for nothing.
#[inline]
fn steal_pressure(stealable_r_l1: f64, hit_backlog: usize, round_budget: u64, topk: bool) -> f64 {
    if topk {
        stealable_r_l1 * (1.0 + hit_backlog as f64 / round_budget as f64)
    } else {
        stealable_r_l1
    }
}

/// Invalidate a worker's serving-path head state around an ownership
/// move (rows granted away, or a grant adopted): the published frame
/// is cleared *before* the rows can appear in another shard's frame —
/// so the monitor never merges a node twice — and the local pool
/// restarts with a full rescan. One place, because the grant-issue and
/// grant-receipt paths must never drift apart.
fn reset_head_tracking(
    frame: &Mutex<Option<ShardHeadFrame>>,
    head_list: &mut Option<HeadList>,
    frame_due: &mut bool,
    goal: Option<TopKGoal>,
) {
    if head_list.is_some() {
        *frame.lock().unwrap() = None;
        *head_list = goal.map(|gl| HeadList::new(gl.pool_cap()));
        *frame_due = true;
    }
}

/// A failed data send, with the message handed back for deferral.
/// `Full`/`Down` are retryable (mpsc backpressure, loopback cap, or an
/// injected disconnect window); `Gone` means the receiving side is gone
/// for good (mpsc disconnect) — restore silently, no retry counting.
enum Bounce {
    Full(PushMsg),
    Down(PushMsg),
    Gone(PushMsg),
}

fn row_to_wire(r: StolenRow) -> WireRow {
    WireRow { node: r.node, p: r.p, r: r.r, touched: r.touched }
}

fn row_from_wire(w: WireRow) -> StolenRow {
    StolenRow { node: w.node, p: w.p, r: w.r, touched: w.touched }
}

fn push_to_wire(msg: PushMsg) -> WireMsg {
    match msg {
        PushMsg::Frag { src, frag } => WireMsg::Frag { src: src as u32, frag },
        PushMsg::StealRequest { thief } => WireMsg::StealRequest { thief: thief as u32 },
        PushMsg::Grant { src, grant } => WireMsg::Grant {
            src: src as u32,
            rows: grant.rows.into_iter().map(row_to_wire).collect(),
        },
    }
}

fn push_from_wire(msg: WireMsg) -> Option<PushMsg> {
    match msg {
        WireMsg::Frag { src, frag } => Some(PushMsg::Frag { src: src as usize, frag }),
        WireMsg::StealRequest { thief } => {
            Some(PushMsg::StealRequest { thief: thief as usize })
        }
        WireMsg::Grant { src, rows } => Some(PushMsg::Grant {
            src: src as usize,
            grant: StealGrant { rows: rows.into_iter().map(row_from_wire).collect() },
        }),
        _ => None,
    }
}

fn frame_to_wire(f: &ShardHeadFrame) -> WireHeadFrame {
    WireHeadFrame {
        entries: f.entries.clone(),
        rest_bound: f.rest_bound,
        r_plus: f.r_plus,
        r_minus: f.r_minus,
        unk_plus: f.unk_plus,
        unk_minus: f.unk_minus,
    }
}

fn frame_from_wire(w: WireHeadFrame) -> ShardHeadFrame {
    ShardHeadFrame {
        entries: w.entries,
        rest_bound: w.rest_bound,
        r_plus: w.r_plus,
        r_minus: w.r_minus,
        unk_plus: w.unk_plus,
        unk_minus: w.unk_minus,
    }
}

/// One worker's view of the exchange fabric: the classic mpsc channels,
/// or a [`crate::net`] transport endpoint carrying the same message set
/// as serialized frames. The worker loop is written against this enum
/// so the two modes cannot drift apart.
enum PushLink {
    Mpsc { txs: Vec<SyncSender<PushMsg>>, rx: Receiver<PushMsg> },
    Net(LoopbackEndpoint),
}

impl PushLink {
    /// Non-blocking send of a data message toward worker `dst`.
    fn try_send(&mut self, dst: usize, msg: PushMsg) -> Result<(), Bounce> {
        match self {
            PushLink::Mpsc { txs, .. } => txs[dst].try_send(msg).map_err(|e| match e {
                TrySendError::Full(m) => Bounce::Full(m),
                TrySendError::Disconnected(m) => Bounce::Gone(m),
            }),
            PushLink::Net(ep) => ep.try_send(dst, push_to_wire(msg)).map_err(|e| match e {
                SendFail::Full(m) => {
                    Bounce::Full(push_from_wire(m).expect("data frame bounced back intact"))
                }
                SendFail::Down(m) => {
                    Bounce::Down(push_from_wire(m).expect("data frame bounced back intact"))
                }
            }),
        }
    }

    /// Next queued data message for this worker, if any. Non-data wire
    /// frames are not addressed to workers; any that show up anyway are
    /// skipped rather than trusted.
    fn try_recv(&mut self) -> Option<PushMsg> {
        match self {
            PushLink::Mpsc { rx, .. } => rx.try_recv().ok(),
            PushLink::Net(ep) => loop {
                match ep.try_recv() {
                    Some(w) => {
                        if let Some(m) = push_from_wire(w) {
                            return Some(m);
                        }
                    }
                    None => return None,
                }
            },
        }
    }

    /// Ship a control/snapshot frame to endpoint `dst` (net mode only;
    /// a no-op over mpsc, where control rides its own channel). The
    /// loopback enqueues control unbounded and drops only droppable
    /// head frames, so the result needs no handling.
    fn send_control(&mut self, dst: usize, msg: WireMsg) {
        if let PushLink::Net(ep) = self {
            let _ = ep.try_send(dst, msg);
        }
    }

    /// Make everything in flight deliverable (end-of-run gather must
    /// not wait out injected delays). No-op over mpsc.
    fn flush(&mut self) {
        if let PushLink::Net(ep) = self {
            ep.flush();
        }
    }
}

/// One worker's side of the §4.2 termination control: off (quiet
/// mode), a [`TermPort`] on the shared unbounded channel (mpsc mode),
/// or a bare [`WorkerTermination`] whose verdicts the caller serializes
/// onto its own wire link (net mode — the link's per-producer FIFO
/// replaces the shared queue's ordering).
enum TermSide {
    Off,
    Port(TermPort),
    Wire { term: WorkerTermination, converge: u64, diverge: u64 },
}

impl TermSide {
    /// Feed one round's verdict. Port mode ships the message itself;
    /// wire mode returns it for the caller to frame and send.
    fn on_round(&mut self, locally_converged: bool) -> Option<TermMsg> {
        match self {
            TermSide::Off => None,
            TermSide::Port(p) => p.on_round(locally_converged),
            TermSide::Wire { term, converge, diverge } => {
                let msg = term.on_iteration(locally_converged)?;
                match msg {
                    TermMsg::Converge => *converge += 1,
                    TermMsg::Diverge => *diverge += 1,
                    TermMsg::Stop => unreachable!("workers never send STOP"),
                }
                Some(msg)
            }
        }
    }

    fn converge_sent(&self) -> u64 {
        match self {
            TermSide::Off => 0,
            TermSide::Port(p) => p.converge_sent(),
            TermSide::Wire { converge, .. } => *converge,
        }
    }

    fn diverge_sent(&self) -> u64 {
        match self {
            TermSide::Off => 0,
            TermSide::Port(p) => p.diverge_sent(),
            TermSide::Wire { diverge, .. } => *diverge,
        }
    }
}

/// Receiver-side half of the protocol's safety discipline, for both
/// transports. Residual mass from `src` was just applied by worker
/// `id`, so a previously-announced CONVERGE must be retracted NOW,
/// strictly before the sender's per-origin in-flight slot is released:
///
/// * mpsc mode — the DIVERGE is enqueued on the shared control channel
///   and the counters are decremented right here, after it; the
///   channel's FIFO makes the monitor process the retraction before
///   any CONVERGE the release enables.
/// * net mode — there is no shared queue, so the release itself is
///   re-routed through the monitor: the DIVERGE frame (if any) and
///   then an Ack frame go out on THIS worker's link, in that order,
///   and the monitor decrements the counters only when it processes
///   the Ack. Per-producer FIFO on the link guarantees it sees the
///   retraction first — the serialized form of the same ordering.
#[allow(clippy::too_many_arguments)]
fn ack_mass(
    term: &mut TermSide,
    link: &mut PushLink,
    net_mode: bool,
    monitor_ep: usize,
    id: usize,
    src: usize,
    origin_inflight: &[AtomicI64],
    in_flight: &AtomicI64,
    tw: &Option<(Arc<TraceCollector>, Arc<EventRing>)>,
) {
    if let Some(msg) = term.on_round(false) {
        if let Some((tr, ring)) = tw {
            let ev = Event { t_us: tr.now_us(), kind: EventKind::TermDiverge, a: 1, v: 0.0 };
            ring.record(ev);
        }
        if net_mode {
            link.send_control(
                monitor_ep,
                WireMsg::Term { src: id as u32, msg, inflight: Vec::new() },
            );
        }
    }
    if net_mode {
        link.send_control(monitor_ep, WireMsg::Ack { peer: src as u32 });
    } else {
        origin_inflight[src].fetch_sub(1, Ordering::AcqRel);
        in_flight.fetch_sub(1, Ordering::AcqRel);
    }
}

/// Run the sharded residual-push solver on real OS threads — the
/// distributed D-Iteration counterpart of [`run_threaded`].
///
/// Where [`run_threaded`] workers ship their *whole rank fragment*
/// every iteration (and a full queue drops it — newer supersedes
/// older), push workers ship only the **residual mass** their pushes
/// created for out-of-shard rows. Residuals are additive and
/// conservative, so a full channel just defers the fragment: the mass
/// re-accumulates in the sender's outbox and ships in the next round's
/// merged batch. Nothing is ever lost, which is what lets the final
/// gathered state stay *exact* (mass conserved to float accumulation)
/// no matter how the OS interleaves the workers — only the *schedule*
/// is nondeterministic, never the invariant.
///
/// Termination ([`PushThreadOptions::term`]): by default the run stops
/// through the paper's §4.2 persistence-counter protocol — each worker
/// feeds a [`TermPort`] with `local estimate < tol/s ∧ inbox drained ∧
/// none of its own sends in flight`, announces CONVERGE after
/// [`pc_max`](PushThreadOptions::pc_max) persistent rounds, retracts
/// with DIVERGE *before* acknowledging any received mass, and an
/// inline [`MonitorPort`] issues STOP once every worker's last word
/// was CONVERGE. That ordering makes a protocol STOP imply the exact
/// gathered residual is under `tol`. [`TermMode::Quiet`] keeps the old
/// quiet-window heuristic (published sums under `tol`,
/// [`quiet_checks`](PushThreadOptions::quiet_checks) samples in a row)
/// for stop-time/wasted-push races — it can stop early under a stalled
/// worker, which [`PushThreadOptions::inject_stall`] demonstrates on
/// demand. Either way the returned `converged` flag reports the
/// *exact* post-gather residual, and [`PushThreadMetrics::stop_cause`]
/// says which rule fired; callers polish sequentially when `converged`
/// is false.
pub fn run_threaded_push(
    g: &DeltaGraph,
    state: &mut ShardedPush,
    opts: &PushThreadOptions,
) -> PushThreadMetrics {
    assert_eq!(state.n(), g.n(), "sharded state sized to a different graph");
    assert!(opts.tol > 0.0, "tol must be positive");
    let t0 = Instant::now();
    // epoch-resident callers leave the state in place across churn; the
    // entry skew check is where the bounds catch up with the degree
    // distribution (shard count may change — read it after)
    let rebalanced = match opts.rebalance_factor {
        Some(f) => state.rebalance(g, f),
        None => false,
    };
    // observability: explicit option wins, else whatever collector the
    // caller attached to the state; None = record nothing anywhere
    let trace = opts.trace.clone().or_else(|| state.trace_handle());
    let s = state.shard_count();
    let deadline = t0 + opts.timeout;
    if s == 1 {
        // no peers, no channels: the deterministic drain is the run —
        // sliced so the timeout and the push budget still apply
        let step = opts.round_pushes.max(1);
        let mut pushes = 0u64;
        let mut rounds = 0u64;
        let (residual, converged, stop_cause) = loop {
            let remaining = opts.max_pushes.saturating_sub(pushes);
            if remaining == 0 {
                break (state.residual_exact(), false, StopCause::Budget);
            }
            let st = state.solve(g, opts.tol, step.min(remaining));
            pushes += st.pushes;
            rounds += st.rounds;
            if st.converged || st.pushes == 0 {
                // pushes == 0 without the flag means the deterministic
                // drain ran dry at drift level — still a natural finish
                break (st.residual, st.converged, StopCause::Converged);
            }
            if Instant::now() >= deadline {
                break (st.residual, st.converged, StopCause::Timeout);
            }
        };
        // close the residual-decay series with the exact final value
        // (matches the returned `residual` by construction)
        let events = trace.as_ref().map(|tr| {
            tr.push_sample(Sample {
                t_us: tr.now_us(),
                shard: 0,
                residual,
                queued: state.shards[0].r_l1,
                in_flight: 0,
                pressure: 0.0,
            });
            vec![tr.totals_for(0)]
        });
        return PushThreadMetrics {
            shard_pushes: vec![pushes],
            rounds: vec![rounds],
            fragments_sent: vec![0],
            fragments_deferred: vec![0],
            stolen_rows: vec![0],
            steal_grants: vec![0],
            idle_rounds: vec![0],
            wall: t0.elapsed(),
            residual,
            converged,
            rebalanced,
            topk_stopped: false,
            stop_cause,
            term_converge: 0,
            term_diverge: 0,
            events,
        };
    }

    let tol = opts.tol;
    let alpha = state.alpha();
    let goal = opts.topk;
    let steal = opts.steal && s >= 2;
    let steal_batch = opts.steal_batch.max(1);
    let local_target = 0.5 * tol / s as f64;
    // a peer is worth robbing (and worth defending its own work) only
    // while its queued residual comfortably exceeds its drain target —
    // migrating rows in the convergence tail would be pure overhead
    let steal_floor = 16.0 * local_target;
    let round_budget = opts.round_pushes.max(1);
    // per-worker slice of the global push budget; s * floor never
    // exceeds the requested total (a budget below the shard count
    // rounds down to zero work, it does not overshoot)
    let worker_budget = opts.max_pushes / s as u64;
    let stop = Arc::new(AtomicBool::new(false));
    // first stop decision wins; read back into the metrics after join
    let stop_cause = Arc::new(AtomicU8::new(CAUSE_UNSET));
    // fragments handed to a channel but not yet applied by the
    // receiver — counted so the monitor never declares quiet while
    // mass is in flight
    let in_flight = Arc::new(AtomicI64::new(0));
    // the same accounting, split by ORIGIN: slot `w` counts sends
    // worker `w` handed to a channel that no receiver has applied yet.
    // The §4.2 announce predicate reads its own slot — a worker may
    // only claim convergence once every fragment/grant it shipped has
    // landed, so shipped mass is always covered by somebody's
    // termination state (sender until applied, receiver after).
    let origin_inflight: Arc<Vec<AtomicI64>> =
        Arc::new((0..s).map(|_| AtomicI64::new(0)).collect());
    // §4.2 control channel: unbounded on purpose (a lost or delayed
    // DIVERGE would break the protocol's soundness — see
    // `termination::channel`); created in both modes, used in Protocol
    let (ctl_tx, ctl_rx) = term_channel();
    let protocol = opts.term == TermMode::Protocol;
    let pc_max = opts.pc_max.max(1);
    let stall = opts.inject_stall;
    let published: Arc<Vec<AtomicU64>> =
        Arc::new((0..s).map(|_| AtomicU64::new(f64::MAX.to_bits())).collect());
    // per-shard queue-pressure board for the steal policy: local queued
    // residual, weighted up by the top-k hit backlog when a serving
    // goal is armed (a shard churning the head is the one whose rows
    // the certificate is waiting on)
    let pressure: Arc<Vec<AtomicU64>> =
        Arc::new((0..s).map(|_| AtomicU64::new(0f64.to_bits())).collect());
    // queued-mass board for the residual-decay sampler (materialized
    // local ‖r‖₁ per shard) — only maintained while a trace collector
    // is attached, so the untraced path publishes nothing extra
    let queued_board: Option<Arc<Vec<AtomicU64>>> = trace
        .as_ref()
        .map(|_| Arc::new((0..s).map(|_| AtomicU64::new(0f64.to_bits())).collect()));
    // per-shard head-candidate frames for the serving-path monitor
    // (None until the owning worker's first publish)
    let head_frames: Arc<Vec<Mutex<Option<ShardHeadFrame>>>> =
        Arc::new((0..s).map(|_| Mutex::new(None)).collect());
    // bumped on every grant issue AND adoption: the monitor's frame
    // collection is not atomic across the per-shard mutexes, so a row
    // migrating mid-collection could appear in a stale victim snapshot
    // AND the thief's fresh one — the generation check discards any
    // sample a migration raced, keeping tentative certificates free of
    // duplicated nodes
    let steal_gen = Arc::new(AtomicU64::new(0));
    let topk_stop = Arc::new(AtomicBool::new(false));
    // all senders stop before this barrier; inboxes are drained after
    // it, so no fragment can be stranded in a dead channel
    let drained = Arc::new(Barrier::new(s));

    // one inbox per shard, every peer holds a sender to it
    let mut txs: Vec<SyncSender<PushMsg>> = Vec::with_capacity(s);
    let mut rxs: Vec<Option<Receiver<PushMsg>>> = Vec::with_capacity(s);
    for _ in 0..s {
        let (tx, rx) = sync_channel::<PushMsg>(opts.channel_depth.max(1) * s);
        txs.push(tx);
        rxs.push(Some(rx));
    }
    // net mode: the s worker endpoints plus one monitor endpoint ride a
    // throttled loopback fabric instead; the mpsc pairs above stay
    // unused (cheap) so the two paths share one construction site
    let net_mode = opts.net.is_some();
    let monitor_ep = s;
    let net_fab = opts
        .net
        .as_ref()
        .map(|cfg| LoopbackNet::new(s + 1, cfg, opts.channel_depth.max(1) * s));
    let mut mon_link = net_fab.as_ref().map(|n| n.endpoint(monitor_ep));
    let mut links: Vec<Option<PushLink>> = (0..s)
        .map(|id| {
            Some(match &net_fab {
                Some(n) => PushLink::Net(n.endpoint(id)),
                None => PushLink::Mpsc { txs: txs.clone(), rx: rxs[id].take().unwrap() },
            })
        })
        .collect();

    let results: Vec<PushWorkerStats> = std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(s);
        for (id, shard) in state.shards.iter_mut().enumerate() {
            let mut link = links[id].take().unwrap();
            let stop = Arc::clone(&stop);
            let stop_cause = Arc::clone(&stop_cause);
            let in_flight = Arc::clone(&in_flight);
            let origin_inflight = Arc::clone(&origin_inflight);
            let ctl_tx = ctl_tx.clone();
            let published = Arc::clone(&published);
            let pressure = Arc::clone(&pressure);
            let head_frames = Arc::clone(&head_frames);
            let steal_gen = Arc::clone(&steal_gen);
            let drained = Arc::clone(&drained);
            // this worker's event ring: track id == shard id, and the
            // worker is the ring's single producer (cached Arc — the
            // loop never takes the collector's mutex)
            let tw = trace.as_ref().map(|tr| (Arc::clone(tr), tr.ring(id)));
            let queued_board = queued_board.clone();
            handles.push(scope.spawn(move || {
                let p0 = shard.pushes();
                let mut rounds = 0u64;
                let mut sent = 0u64;
                let mut deferred = 0u64;
                let mut stolen_in = 0u64;
                let mut grants_out = 0u64;
                let mut idle = 0u64;
                // steal bookkeeping: peers that asked us for rows this
                // round, and our own outstanding request (one at a
                // time, dropped after a patience window so a victim
                // that went quiet cannot wedge us)
                let mut thieves: Vec<usize> = Vec::new();
                let mut outstanding: Option<(usize, u64)> = None;
                // serving path: this worker's head-candidate pool, fed
                // by the shard's hit stream (first refresh scans the
                // shard, later ones are O(hits))
                let mut head_list = goal.map(|gl| HeadList::new(gl.pool_cap()));
                let mut frame_due = true;
                // steal generation stamped on the last wire frame we
                // published (net mode; MAX forces the first publish)
                let mut last_pub_gen = u64::MAX;
                // §4.2 side: off in quiet mode, a channel port in mpsc
                // mode, a bare state machine whose verdicts ride this
                // worker's own wire link in net mode — fed every round
                // and on every mass receipt either way
                let mut term_side = if !protocol {
                    TermSide::Off
                } else if net_mode {
                    TermSide::Wire {
                        term: WorkerTermination::new(pc_max),
                        converge: 0,
                        diverge: 0,
                    }
                } else {
                    TermSide::Port(TermPort::new(id, pc_max, ctl_tx.clone()))
                };
                loop {
                    // import everything queued by the peers
                    let mut received = false;
                    while let Some(msg) = link.try_recv() {
                        match msg {
                            PushMsg::Frag { src, frag } => {
                                shard.apply_fragment(&frag);
                                // retract BEFORE releasing the sender's
                                // in-flight slot: the transport
                                // preserves our enqueue order, so the
                                // monitor sees this DIVERGE before any
                                // CONVERGE the sender bases on the
                                // release (which in net mode travels as
                                // an Ack frame behind it)
                                ack_mass(
                                    &mut term_side,
                                    &mut link,
                                    net_mode,
                                    monitor_ep,
                                    id,
                                    src,
                                    &origin_inflight,
                                    &in_flight,
                                    &tw,
                                );
                                received = true;
                            }
                            PushMsg::StealRequest { thief } => thieves.push(thief),
                            PushMsg::Grant { src, grant } => {
                                steal_gen.fetch_add(1, Ordering::AcqRel);
                                outstanding = None;
                                // our pool predates the adoption; start
                                // clean so the stolen rows are scanned in
                                reset_head_tracking(
                                    &head_frames[id],
                                    &mut head_list,
                                    &mut frame_due,
                                    goal,
                                );
                                stolen_in += shard.adopt_rows(grant) as u64;
                                // same DIVERGE-before-release discipline
                                // as fragments: adopted rows carry mass
                                ack_mass(
                                    &mut term_side,
                                    &mut link,
                                    net_mode,
                                    monitor_ep,
                                    id,
                                    src,
                                    &origin_inflight,
                                    &in_flight,
                                    &tw,
                                );
                                received = true;
                            }
                        }
                    }
                    // fault injection: sleep in exactly the window where
                    // a stale published estimate hides applied mass
                    if let Some(st) = stall {
                        if st.worker == id && rounds == st.after_rounds {
                            std::thread::sleep(std::time::Duration::from_millis(st.ms));
                        }
                    }
                    if stop.load(Ordering::Acquire) || Instant::now() >= deadline {
                        break;
                    }
                    // drain the local bucket queue, honoring this
                    // worker's slice of the global push budget
                    // (saturating: steal-adopted rows migrate push
                    // credit, so `spent` can legitimately exceed the
                    // per-worker slice)
                    let spent = shard.pushes() - p0;
                    let budget = round_budget.min(worker_budget.saturating_sub(spent));
                    let pushed = shard.drain(g, local_target, budget);
                    if shard.pushes() - p0 >= worker_budget {
                        // budget exhausted: wind the whole run down
                        record_stop_cause(&stop_cause, StopCause::Budget);
                        stop.store(true, Ordering::Release);
                    }
                    if pushed > 0 {
                        if let Some((tr, ring)) = &tw {
                            ring.record(Event {
                                t_us: tr.now_us(),
                                kind: EventKind::PushBatch,
                                a: pushed,
                                v: shard.r_l1,
                            });
                        }
                    }
                    // ship the outboxes; a full (or injected-down) link
                    // defers, never drops
                    for j in 0..s {
                        if j == id {
                            shard.absorb_self_uniform();
                            continue;
                        }
                        if let Some(frag) = shard.take_fragment(j) {
                            let frag_len = frag.entries.len() as f64;
                            in_flight.fetch_add(1, Ordering::AcqRel);
                            origin_inflight[id].fetch_add(1, Ordering::AcqRel);
                            match link.try_send(j, PushMsg::Frag { src: id, frag }) {
                                Ok(()) => {
                                    sent += 1;
                                    if let Some((tr, ring)) = &tw {
                                        ring.record(Event {
                                            t_us: tr.now_us(),
                                            kind: EventKind::FragSend,
                                            a: j as u64,
                                            v: frag_len,
                                        });
                                    }
                                }
                                Err(Bounce::Full(PushMsg::Frag { frag, .. }))
                                | Err(Bounce::Down(PushMsg::Frag { frag, .. })) => {
                                    origin_inflight[id].fetch_sub(1, Ordering::AcqRel);
                                    in_flight.fetch_sub(1, Ordering::AcqRel);
                                    shard.restore_fragment(j, frag);
                                    deferred += 1;
                                    if let Some((tr, ring)) = &tw {
                                        ring.record(Event {
                                            t_us: tr.now_us(),
                                            kind: EventKind::FragDefer,
                                            a: j as u64,
                                            v: frag_len,
                                        });
                                    }
                                }
                                Err(Bounce::Gone(PushMsg::Frag { frag, .. })) => {
                                    origin_inflight[id].fetch_sub(1, Ordering::AcqRel);
                                    in_flight.fetch_sub(1, Ordering::AcqRel);
                                    shard.restore_fragment(j, frag);
                                }
                                Err(_) => unreachable!("send returns the sent message"),
                            }
                        }
                    }
                    // serve steal requests with our hottest queued rows;
                    // the grant rides the same bounded channel and is
                    // restored on a full one — ownership, like residual,
                    // is never lost in flight
                    if steal && !thieves.is_empty() {
                        for thief in std::mem::take(&mut thieves) {
                            // defend with the SAME pressure formula the
                            // board publishes: a peer that picked us off
                            // the board only sees a refusal when we
                            // genuinely drained in the meantime
                            let pressure_now = steal_pressure(
                                shard.stealable_r_l1(),
                                shard.head_hits.len(),
                                round_budget,
                                goal.is_some(),
                            );
                            if thief == id || pressure_now <= steal_floor {
                                continue;
                            }
                            let grant = match shard.steal_out(thief, steal_batch) {
                                Some(g) => g,
                                None => continue,
                            };
                            let grant_rows = grant.rows.len() as f64;
                            reset_head_tracking(
                                &head_frames[id],
                                &mut head_list,
                                &mut frame_due,
                                goal,
                            );
                            in_flight.fetch_add(1, Ordering::AcqRel);
                            origin_inflight[id].fetch_add(1, Ordering::AcqRel);
                            steal_gen.fetch_add(1, Ordering::AcqRel);
                            match link.try_send(thief, PushMsg::Grant { src: id, grant }) {
                                Ok(()) => {
                                    grants_out += 1;
                                    if let Some((tr, ring)) = &tw {
                                        ring.record(Event {
                                            t_us: tr.now_us(),
                                            kind: EventKind::StealGrant,
                                            a: thief as u64,
                                            v: grant_rows,
                                        });
                                    }
                                }
                                Err(Bounce::Full(PushMsg::Grant { grant, .. }))
                                | Err(Bounce::Down(PushMsg::Grant { grant, .. }))
                                | Err(Bounce::Gone(PushMsg::Grant { grant, .. })) => {
                                    origin_inflight[id].fetch_sub(1, Ordering::AcqRel);
                                    in_flight.fetch_sub(1, Ordering::AcqRel);
                                    shard.restore_grant(grant);
                                    // the pre-send reset cleared our
                                    // frame and pool; re-arm them again
                                    // now the rows are back home, so the
                                    // next published frame is rebuilt
                                    // WITH the restored rows — the
                                    // serving monitor must never merge a
                                    // frame that predates the restore
                                    reset_head_tracking(
                                        &head_frames[id],
                                        &mut head_list,
                                        &mut frame_due,
                                        goal,
                                    );
                                }
                                Err(_) => unreachable!("send returns the sent message"),
                            }
                        }
                    }
                    if let Some(hl) = head_list.as_mut() {
                        // net mode re-stamps even an unchanged frame
                        // when a migration elsewhere bumped the steal
                        // generation (this shard's rows were not part
                        // of it, so the content is still exact — only
                        // the stamp aged out), and heartbeats every 64
                        // rounds because a congested link may have
                        // dropped the last snapshot
                        let gen_now = steal_gen.load(Ordering::Acquire);
                        let restamp =
                            net_mode && (gen_now != last_pub_gen || rounds % 64 == 0);
                        if frame_due || pushed > 0 || received || restamp {
                            let frame = shard_frame(hl, shard, None);
                            if net_mode {
                                // the frame travels as a wire snapshot,
                                // stamped with the steal generation at
                                // capture time so the monitor can
                                // discard anything a migration raced
                                link.send_control(
                                    monitor_ep,
                                    WireMsg::HeadFrame {
                                        src: id as u32,
                                        gen: gen_now,
                                        frame: frame_to_wire(&frame),
                                    },
                                );
                                last_pub_gen = gen_now;
                            } else {
                                *head_frames[id].lock().unwrap() = Some(frame);
                            }
                            frame_due = false;
                        }
                    }
                    let estimate = shard.residual_estimate();
                    published[id].store(estimate.to_bits(), Ordering::Release);
                    {
                        // §4.2 local convergence check: conservative
                        // estimate (materialized + outbox mass) under
                        // this worker's tol share, the inbox drained at
                        // the top of this round, and nothing WE sent
                        // still unapplied — shipped mass stays covered
                        // by the receiver's state machine, not ours
                        let own = origin_inflight[id].load(Ordering::Acquire);
                        if let Some(msg) = term_side.on_round(estimate < tol / s as f64 && own == 0)
                        {
                            match msg {
                                TermMsg::Converge => {
                                    if let Some((tr, ring)) = &tw {
                                        ring.record(Event {
                                            t_us: tr.now_us(),
                                            kind: EventKind::TermConverge,
                                            a: pc_max as u64,
                                            v: estimate,
                                        });
                                    }
                                }
                                TermMsg::Diverge => {
                                    if let Some((tr, ring)) = &tw {
                                        ring.record(Event {
                                            t_us: tr.now_us(),
                                            kind: EventKind::TermDiverge,
                                            a: 0,
                                            v: estimate,
                                        });
                                    }
                                }
                                TermMsg::Stop => unreachable!("workers never send STOP"),
                            }
                            if net_mode {
                                // frame carries this worker's own
                                // in-flight count — the SAME value the
                                // predicate above used, so an honest
                                // CONVERGE always ships an empty list
                                // and can never be downgraded
                                let inflight = if own > 0 {
                                    vec![(id as u32, own as u64)]
                                } else {
                                    Vec::new()
                                };
                                link.send_control(
                                    monitor_ep,
                                    WireMsg::Term { src: id as u32, msg, inflight },
                                );
                            }
                        }
                    }
                    if let Some(qb) = &queued_board {
                        qb[id].store(shard.r_l1.to_bits(), Ordering::Release);
                    }
                    let p_now = steal_pressure(
                        shard.stealable_r_l1(),
                        shard.head_hits.len(),
                        round_budget,
                        goal.is_some(),
                    );
                    pressure[id].store(p_now.to_bits(), Ordering::Release);
                    rounds += 1;
                    if let Some((_, due)) = outstanding {
                        if rounds >= due {
                            outstanding = None;
                        }
                    }
                    if pushed == 0 && !received {
                        idle += 1;
                        if let Some((tr, ring)) = &tw {
                            ring.record(Event {
                                t_us: tr.now_us(),
                                kind: EventKind::IdleRound,
                                a: idle,
                                v: shard.r_l1,
                            });
                        }
                        // locally quiet: ask the deepest peer for work
                        // (one outstanding request at a time), then let
                        // the peers have the cores
                        if steal && outstanding.is_none() {
                            let mut best: Option<usize> = None;
                            let mut best_p = steal_floor;
                            for j in 0..s {
                                if j == id {
                                    continue;
                                }
                                let pj = f64::from_bits(pressure[j].load(Ordering::Acquire));
                                if pj > best_p {
                                    best_p = pj;
                                    best = Some(j);
                                }
                            }
                            if let Some(victim) = best {
                                // recorded BEFORE the send so the
                                // thief's request timestamp strictly
                                // precedes the victim's grant (the
                                // pairing invariant the proptests
                                // check); an undelivered request
                                // leaves a harmless unmatched event
                                if let Some((tr, ring)) = &tw {
                                    ring.record(Event {
                                        t_us: tr.now_us(),
                                        kind: EventKind::StealRequest,
                                        a: victim as u64,
                                        v: 0.0,
                                    });
                                }
                                if link
                                    .try_send(victim, PushMsg::StealRequest { thief: id })
                                    .is_ok()
                                {
                                    outstanding = Some((victim, rounds + 64));
                                }
                            }
                        }
                        std::thread::sleep(std::time::Duration::from_micros(50));
                    }
                }
                // every worker reaches this barrier before anyone's
                // final drain, and nobody sends after it — so the drain
                // below observes every fragment and grant ever sent
                drained.wait();
                // net mode: make every injected delay/disconnect window
                // deliverable NOW — the final drain must observe all
                // shipped mass, not wait out a 200ms fault schedule
                link.flush();
                while let Some(msg) = link.try_recv() {
                    match msg {
                        PushMsg::Frag { src, frag } => {
                            shard.apply_fragment(&frag);
                            if !net_mode {
                                origin_inflight[src].fetch_sub(1, Ordering::AcqRel);
                                in_flight.fetch_sub(1, Ordering::AcqRel);
                            }
                        }
                        PushMsg::StealRequest { .. } => {}
                        PushMsg::Grant { src, grant } => {
                            stolen_in += shard.adopt_rows(grant) as u64;
                            if !net_mode {
                                origin_inflight[src].fetch_sub(1, Ordering::AcqRel);
                                in_flight.fetch_sub(1, Ordering::AcqRel);
                            }
                        }
                    }
                }
                PushWorkerStats {
                    pushes: shard.pushes() - p0,
                    rounds,
                    sent,
                    deferred,
                    stolen_in,
                    grants_out,
                    idle,
                    term_converge: term_side.converge_sent(),
                    term_diverge: term_side.diverge_sent(),
                }
            }));
        }

        // inline monitor. Protocol mode: drain the §4.2 control
        // channel and STOP when every worker's last word was CONVERGE.
        // Quiet mode: published residual under tol with no fragments
        // in flight, persisted across consecutive samples. With a
        // top-k goal either mode additionally merges the workers' head
        // frames and stops the moment they certify — tentatively,
        // since the frames are asynchronous snapshots; the caller
        // re-checks exactly on the settled state.
        let mut quiet = 0u32;
        let mut mport = (protocol && !net_mode).then(|| MonitorPort::new(s, ctl_rx));
        // net mode: the control traffic arrives on the monitor's own
        // wire endpoint instead — §4.2 frames feed a WireMonitor
        // (hardened central log), Ack frames release the in-flight
        // accounting the workers re-routed through us, and head frames
        // land here as generation-stamped snapshots
        let mut wire_mon = (protocol && net_mode).then(|| WireMonitor::new(s));
        let mut wire_stop = false;
        let mut net_frames: Vec<Option<(u64, ShardHeadFrame)>> = (0..s).map(|_| None).collect();
        // monitor-side observability: its own event track, plus the
        // periodic residual-decay sweep over the published boards
        let mon = trace.as_ref().map(|tr| (Arc::clone(tr), tr.ring(MONITOR_TRACK)));
        let sample_every =
            trace.as_ref().map(|tr| tr.sample_interval_us()).unwrap_or(u64::MAX);
        let mut last_sample = 0u64;
        while !stop.load(Ordering::Acquire) && Instant::now() < deadline {
            std::thread::sleep(std::time::Duration::from_micros(300));
            // drain the wire first: this single-threaded loop is what
            // turns per-producer FIFO into protocol soundness — a
            // worker's DIVERGE is always processed here before the Ack
            // it queued behind it, so no release can outrun its
            // retraction
            if let Some(ml) = mon_link.as_mut() {
                while let Some(msg) = ml.try_recv() {
                    match msg {
                        WireMsg::Ack { peer } => {
                            let p = peer as usize;
                            if p < s {
                                origin_inflight[p].fetch_sub(1, Ordering::AcqRel);
                                in_flight.fetch_sub(1, Ordering::AcqRel);
                            }
                        }
                        WireMsg::Term { src, msg, inflight } => {
                            if let Some(wm) = wire_mon.as_mut() {
                                let nz = inflight.iter().any(|&(_, c)| c > 0);
                                if wm.on_message(src as usize, msg, nz) {
                                    wire_stop = true;
                                }
                            }
                        }
                        WireMsg::HeadFrame { src, gen, frame } => {
                            let i = src as usize;
                            if i < s {
                                net_frames[i] = Some((gen, frame_from_wire(frame)));
                            }
                        }
                        _ => {}
                    }
                }
            }
            if let Some((tr, _)) = &mon {
                let now = tr.now_us();
                if now.saturating_sub(last_sample) >= sample_every {
                    last_sample = now;
                    let infl = in_flight.load(Ordering::Acquire);
                    for i in 0..s {
                        let resid = f64::from_bits(published[i].load(Ordering::Acquire));
                        if resid == f64::MAX {
                            continue; // worker hasn't published yet
                        }
                        tr.push_sample(Sample {
                            t_us: now,
                            shard: i as u32,
                            residual: resid,
                            queued: queued_board
                                .as_ref()
                                .map(|qb| f64::from_bits(qb[i].load(Ordering::Acquire)))
                                .unwrap_or(0.0),
                            in_flight: infl,
                            pressure: f64::from_bits(pressure[i].load(Ordering::Acquire)),
                        });
                    }
                }
            }
            if let Some(gl) = goal {
                if in_flight.load(Ordering::Acquire) == 0 {
                    let gen0 = steal_gen.load(Ordering::Acquire);
                    let frames: Vec<ShardHeadFrame> = if net_mode {
                        // wire snapshots: every shard must have sent a
                        // frame stamped with the CURRENT steal
                        // generation — a stale stamp means a migration
                        // raced the capture, so the set is discarded
                        // (the in-flight gate stays exact here: it is
                        // maintained by this loop's own Ack processing)
                        if net_frames
                            .iter()
                            .all(|f| matches!(f, Some((g, _)) if *g == gen0))
                        {
                            net_frames
                                .iter()
                                .filter_map(|f| f.as_ref().map(|(_, fr)| fr.clone()))
                                .collect()
                        } else {
                            Vec::new()
                        }
                    } else {
                        head_frames
                            .iter()
                            .filter_map(|m| m.lock().unwrap().clone())
                            .collect()
                    };
                    // a migration that raced the (non-atomic) collection
                    // could put one row in a stale victim snapshot AND
                    // the thief's fresh frame — discard such samples
                    if frames.len() == s
                        && in_flight.load(Ordering::Acquire) == 0
                        && steal_gen.load(Ordering::Acquire) == gen0
                    {
                        let certified =
                            certify_frames(&frames, gl.k, alpha).certified(gl.order);
                        if let Some((tr, ring)) = &mon {
                            ring.record(Event {
                                t_us: tr.now_us(),
                                kind: EventKind::CertCheck,
                                a: certified as u64,
                                v: frames.len() as f64,
                            });
                        }
                        if certified {
                            record_stop_cause(&stop_cause, StopCause::TopK);
                            topk_stop.store(true, Ordering::Release);
                            stop.store(true, Ordering::Release);
                            continue;
                        }
                    }
                }
            }
            if let Some(wm) = &wire_mon {
                // net-mode §4.2: the frames were already fed into the
                // WireMonitor by the drain above; act on its verdict
                if wire_stop {
                    record_stop_cause(&stop_cause, StopCause::Protocol);
                    if let Some((tr, ring)) = &mon {
                        ring.record(Event {
                            t_us: tr.now_us(),
                            kind: EventKind::TermStop,
                            a: wm.messages_seen(),
                            v: 0.0,
                        });
                    }
                    stop.store(true, Ordering::Release);
                }
                continue;
            }
            if let Some(mp) = mport.as_mut() {
                if mp.poll() {
                    record_stop_cause(&stop_cause, StopCause::Protocol);
                    if let Some((tr, ring)) = &mon {
                        ring.record(Event {
                            t_us: tr.now_us(),
                            kind: EventKind::TermStop,
                            a: mp.messages_seen(),
                            v: 0.0,
                        });
                    }
                    stop.store(true, Ordering::Release);
                }
                continue;
            }
            // quiet-window heuristic (TermMode::Quiet). The f64::MAX
            // never-published sentinels are skipped explicitly: a
            // worker that exits before its first publish (zero budget
            // slice, instant deadline) must not wedge the detector
            // until the full timeout — and an all-sentinel board is
            // not quiet, it is silent
            let mut total = 0.0f64;
            let mut published_shards = 0usize;
            for slot in published.iter() {
                let v = f64::from_bits(slot.load(Ordering::Acquire));
                if v == f64::MAX {
                    continue;
                }
                published_shards += 1;
                total += v;
            }
            // the in-flight gate only exists in-process: a real network
            // has no global in-flight register, so the net-tier quiet
            // heuristic runs without it — exactly the unsoundness the
            // premature-quiet regression test demonstrates and the
            // §4.2 protocol closes
            let infl_ok = net_mode || in_flight.load(Ordering::Acquire) == 0;
            if published_shards > 0 && total < tol && infl_ok {
                quiet += 1;
                if let Some((tr, ring)) = &mon {
                    ring.record(Event {
                        t_us: tr.now_us(),
                        kind: EventKind::QuietWindow,
                        a: quiet as u64,
                        v: total,
                    });
                }
                if quiet >= opts.quiet_checks.max(1) {
                    record_stop_cause(&stop_cause, StopCause::QuietWindow);
                    stop.store(true, Ordering::Release);
                }
            } else {
                quiet = 0;
            }
        }
        // falling out of the loop without a recorded cause means the
        // wall clock cut the run
        record_stop_cause(&stop_cause, StopCause::Timeout);
        stop.store(true, Ordering::Release);
        handles
            .into_iter()
            .map(|h| h.join().expect("push worker panicked"))
            .collect()
    });

    let mut shard_pushes = Vec::with_capacity(s);
    let mut rounds = Vec::with_capacity(s);
    let mut fragments_sent = Vec::with_capacity(s);
    let mut fragments_deferred = Vec::with_capacity(s);
    let mut stolen_rows = Vec::with_capacity(s);
    let mut steal_grants = Vec::with_capacity(s);
    let mut idle_rounds = Vec::with_capacity(s);
    let mut term_converge = 0u64;
    let mut term_diverge = 0u64;
    for w in results {
        shard_pushes.push(w.pushes);
        rounds.push(w.rounds);
        fragments_sent.push(w.sent);
        fragments_deferred.push(w.deferred);
        stolen_rows.push(w.stolen_in);
        steal_grants.push(w.grants_out);
        idle_rounds.push(w.idle);
        term_converge += w.term_converge;
        term_diverge += w.term_diverge;
    }
    // reconcile ownership bookkeeping with what the workers actually
    // migrated (each worker only saw its own side of each grant)
    let total_stolen: u64 = stolen_rows.iter().sum();
    if total_stolen > 0 {
        state.note_steals(total_stolen, steal_grants.iter().sum());
    }
    // anything still parked in outboxes (deferred at the cut-off, or
    // forwards for rows that moved mid-run) is delivered
    // deterministically before the exact re-tally (dense: the
    // converged flag must not ride on drifted increments)
    state.exchange();
    if goal.is_some() {
        // the workers' head lists consumed the shards' hit streams and
        // re-armed the entry floors — detach so any outer tracker
        // rebuilds on its next check and no floor stays armed under
        // later untracked solves
        state.detach_head_tracking();
    }
    let residual = state.residual_recompute();
    // close the residual-decay series with one exact sample per shard:
    // recorded right after the re-tally, so the per-shard finals sum
    // to the returned `residual` bit-for-bit (the acceptance contract
    // the obs proptests pin down)
    let events = trace.as_ref().map(|tr| {
        let t = tr.now_us();
        for (i, sh) in state.shards.iter().enumerate() {
            tr.push_sample(Sample {
                t_us: t,
                shard: i as u32,
                residual: sh.residual_estimate(),
                queued: sh.r_l1,
                in_flight: 0,
                pressure: 0.0,
            });
        }
        (0..s).map(|i| tr.totals_for(i)).collect()
    });
    PushThreadMetrics {
        shard_pushes,
        rounds,
        fragments_sent,
        fragments_deferred,
        stolen_rows,
        steal_grants,
        idle_rounds,
        wall: t0.elapsed(),
        residual,
        converged: residual < opts.tol,
        rebalanced,
        topk_stopped: topk_stop.load(Ordering::Acquire),
        stop_cause: StopCause::from_u8(stop_cause.load(Ordering::Acquire))
            .unwrap_or(StopCause::Timeout),
        term_converge,
        term_diverge,
        events,
    }
}

/// Outcome of [`run_threaded_push_certified`].
#[derive(Debug, Clone)]
pub struct CertifiedRunOutcome {
    /// The last *exact* certificate (head reflects the settled state).
    pub cert: TopKCertificate,
    /// Pushes this call spent when the goal's certificate first held
    /// exactly (`Some(0)` = already certified at entry; `None` = the
    /// run ended — converged, timed out, or exhausted its budget —
    /// without one).
    pub pushes_to_cert: Option<u64>,
    /// Whether `residual < opts.tol` was reached.
    pub converged: bool,
    /// Exact residual at exit.
    pub residual: f64,
    /// Stop cause of the last inner run (`None` when the goal was
    /// already certified at entry and no run happened).
    pub last_stop: Option<StopCause>,
    /// CONVERGE announcements summed over every inner run.
    pub term_converge: u64,
    /// DIVERGE retractions summed over every inner run.
    pub term_diverge: u64,
}

/// The tentative-certify / exact-recheck / resume protocol around
/// [`run_threaded_push`], packaged so every caller gets it right: the
/// monitor's top-k stop is only a *hint* (worker frames are
/// asynchronous snapshots), so each stopped run is re-checked exactly
/// on the settled state via `tracker` and resumed when the proof does
/// not actually hold — bounded attempts, so racing churn near the
/// k-boundary falls through to the caller's finish instead of
/// spinning. `opts.topk` is ignored; the goal comes from `tracker`.
pub fn run_threaded_push_certified(
    g: &DeltaGraph,
    state: &mut ShardedPush,
    tracker: &mut TopKTracker,
    opts: &PushThreadOptions,
) -> CertifiedRunOutcome {
    let goal = tracker.goal();
    let p0 = state.total_pushes();
    let mut cert = tracker.check_sharded(state);
    let mut pushes_to_cert = if cert.certified(goal.order) { Some(0) } else { None };
    let mut converged = false;
    let mut residual = f64::NAN;
    let mut last_stop = None;
    let mut term_converge = 0u64;
    let mut term_diverge = 0u64;
    for _attempt in 0..8 {
        if pushes_to_cert.is_some() {
            break;
        }
        let used = state.total_pushes() - p0;
        let topts = PushThreadOptions {
            topk: Some(goal),
            max_pushes: opts.max_pushes.saturating_sub(used),
            ..opts.clone()
        };
        let tm = run_threaded_push(g, state, &topts);
        last_stop = Some(tm.stop_cause);
        term_converge += tm.term_converge;
        term_diverge += tm.term_diverge;
        cert = tracker.check_sharded(state);
        if cert.certified(goal.order) {
            pushes_to_cert = Some(state.total_pushes() - p0);
        }
        if tm.converged {
            converged = true;
            residual = tm.residual;
            break;
        }
        if !tm.topk_stopped {
            break; // timeout or budget, not a tentative stop: don't loop
        }
    }
    if residual.is_nan() {
        residual = state.residual_recompute();
    }
    CertifiedRunOutcome {
        cert,
        pushes_to_cert,
        converged,
        residual,
        last_stop,
        term_converge,
        term_diverge,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Partitioner;
    use crate::graph::{generators, Csr};
    use crate::pagerank::{kendall_tau, power_method, PowerOptions};

    fn problem(n: usize, seed: u64) -> Arc<PagerankProblem> {
        let el = generators::power_law_web(&generators::WebParams::scaled(n), seed);
        Arc::new(PagerankProblem::new(Csr::from_edgelist(&el).unwrap(), 0.85))
    }

    /// The nondeterministic-interleaving assertions depend on the host
    /// scheduler (a descheduled worker lets its peers go locally quiet
    /// on stale data). Two CI-stability valves: the tau floor is
    /// env-tunable (`ASYNCPR_TAU_MIN`, default generous), and the run
    /// gets a few attempts before the test gives up — one bad schedule
    /// must not fail the suite.
    fn tau_floor() -> f64 {
        std::env::var("ASYNCPR_TAU_MIN")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(0.95)
    }

    #[test]
    fn threaded_run_converges_and_stops() {
        let problem = problem(2_000, 61);
        let blocks = Partitioner::consecutive(problem.n(), 3).blocks();
        let pm = power_method(
            &problem,
            &PowerOptions { tol: 1e-9, max_iters: 5000, record_residuals: false },
        );
        // tighter local threshold: with only 2 host cores the OS can
        // deschedule a worker long enough for its peers to go locally
        // quiet on stale data — exactly the premature-stop the paper's
        // persistence counters mitigate; tol 1e-7 absorbs it
        let opts = ThreadRunOptions { tol: 1e-7, pc_max_worker: 5, ..Default::default() };
        let mut last = (0.0f64, 0.0f32);
        for attempt in 0..3 {
            let m = run_threaded(&problem, &blocks, &opts);
            assert!(m.wall < std::time::Duration::from_secs(55), "hit the timeout");
            assert!(m.iters.iter().all(|&i| i > 0), "{:?}", m.iters);
            let tau = kendall_tau(&m.x, &pm.x);
            last = (tau, m.final_global_residual);
            if m.final_global_residual < 1e-2 && tau > tau_floor() {
                return;
            }
            // diagnostic only (ASYNCPR_DIAG=1): retries are expected
            // scheduler luck, so the suite stays silent by default
            crate::obs::diag(&format!(
                "attempt {attempt}: tau {tau}, resid {} — retrying (scheduler luck)",
                m.final_global_residual
            ));
        }
        panic!("3 attempts failed: tau {}, resid {}", last.0, last.1);
    }

    #[test]
    fn threaded_run_single_ue() {
        let problem = problem(800, 62);
        let blocks = vec![(0, problem.n())];
        let m = run_threaded(&problem, &blocks, &ThreadRunOptions::default());
        assert_eq!(m.iters.len(), 1);
        assert!(m.final_global_residual < 1e-4);
    }

    #[test]
    fn bounded_queues_drop_under_pressure() {
        let problem = problem(3_000, 63);
        let blocks = Partitioner::consecutive(problem.n(), 2).blocks();
        let opts = ThreadRunOptions {
            channel_depth: 1,
            tol: 1e-9, // unreachable in the window: keeps senders free-running
            // long enough to generate queue pressure, short enough for CI
            timeout: std::time::Duration::from_millis(1200),
            ..Default::default()
        };
        let m = run_threaded(&problem, &blocks, &opts);
        // with depth-1 queues and free-running senders, drops are
        // overwhelmingly likely; we only assert the run survived them
        assert!(m.iters.iter().all(|&i| i > 10), "{:?}", m.iters);
        let _ = m.dropped;
    }

    // --- residual-push backend ---

    fn web(n: usize, seed: u64) -> DeltaGraph {
        let el = generators::power_law_web(&generators::WebParams::scaled(n), seed);
        DeltaGraph::from_edgelist(&el)
    }

    #[test]
    fn threaded_push_agrees_with_sequential_and_conserves_mass() {
        let g = web(2_000, 71);
        let tol = 1e-10;
        // sequential single-shard reference, solved tighter so the
        // combined error bound stays under 10x the push tolerance
        let mut seq = crate::stream::PushState::new(g.n(), 0.85);
        seq.begin_epoch();
        let seq_stats = seq.solve(&g, tol * 0.1, u64::MAX);
        assert!(seq_stats.converged);

        let mut sp = ShardedPush::new(&g, 0.85, 4);
        let opts = PushThreadOptions { tol, ..Default::default() };
        let tm = run_threaded_push(&g, &mut sp, &opts);
        assert!(tm.shard_pushes.iter().sum::<u64>() > 0, "no parallel work done");
        assert_eq!(tm.shard_pushes.len(), 4);
        // gather and, if the monitor cut early (timeout/quiet race),
        // finish sequentially — the gathered state is exact either way
        let mut out = crate::stream::PushState::new(g.n(), 0.85);
        out.begin_epoch();
        sp.gather_into(&mut out);
        if !tm.converged {
            let polish = out.solve(&g, tol, u64::MAX);
            assert!(polish.converged);
        }
        let d: f64 = out
            .ranks()
            .iter()
            .zip(seq.ranks())
            .map(|(a, b)| (a - b).abs())
            .sum();
        assert!(d < 10.0 * tol, "threaded vs sequential drift {d:.3e}");
        let mass: f64 = out.ranks().iter().sum();
        assert!((mass - 1.0).abs() < 1e-9, "mass {mass}");
    }

    #[test]
    fn threaded_push_single_shard_falls_back_to_sequential() {
        let g = web(600, 72);
        let mut sp = ShardedPush::new(&g, 0.85, 1);
        let tm = run_threaded_push(&g, &mut sp, &PushThreadOptions::default());
        assert!(tm.converged, "residual {}", tm.residual);
        assert_eq!(tm.shard_pushes.len(), 1);
        assert_eq!(tm.fragments_sent, vec![0]);
    }

    #[test]
    fn threaded_push_topk_stop_is_sound_after_exact_recheck() {
        let g = web(3_000, 74);
        let goal = TopKGoal { k: 16, order: false };
        let mut sp = ShardedPush::new(&g, 0.85, 4);
        let mut tracker = TopKTracker::new(goal);
        let opts = PushThreadOptions { tol: 1e-10, ..Default::default() };
        // the monitor's stop is tentative (asynchronous snapshots); the
        // helper owns the run -> exact check -> resume protocol
        let out = run_threaded_push_certified(&g, &mut sp, &mut tracker, &opts);
        assert!(
            out.cert.set_certified,
            "power-law web must certify k=16 (converged: {})",
            out.converged
        );
        assert!((sp.mass() - 1.0).abs() < 1e-9, "mass {}", sp.mass());
        // soundness: the certified set is the true top-16
        let (xref, _) = crate::stream::power_method_f64(&g, 0.85, 1e-12, 10_000);
        let mut want = crate::pagerank::top_k_ids(&xref, 16);
        let mut got = out.cert.head.clone();
        want.sort_unstable();
        got.sort_unstable();
        assert_eq!(got, want, "certified head != converged reference top-16");
        // and the state remains a working solver after the early cut
        let st = sp.solve(&g, 1e-10, u64::MAX);
        assert!(st.converged);
    }

    /// Converge, then inject churn confined to the LAST shard's row
    /// range — the transient single-shard hot spot intra-epoch work
    /// stealing exists for.
    fn skewed_epoch(g: &mut DeltaGraph, sp: &mut ShardedPush) {
        let bounds = sp.partitioner().bounds().to_vec();
        let (lo, hi) = (bounds[bounds.len() - 2], bounds[bounds.len() - 1]);
        let mut rng = crate::util::Rng::new(75);
        let mut batch = crate::stream::UpdateBatch::default();
        for _ in 0..600 {
            let u = rng.range(lo, hi) as u32;
            let v = rng.range(lo, hi) as u32;
            batch.insert.push((u, v));
        }
        let delta = g.apply(&batch).unwrap();
        sp.begin_epoch();
        sp.apply_batch(g, &delta);
    }

    #[test]
    fn threaded_push_steal_conserves_mass_and_tracks_power() {
        let mut g = web(3_000, 76);
        let mut sp = ShardedPush::new(&g, 0.85, 4);
        let st = sp.solve(&g, 1e-10, u64::MAX);
        assert!(st.converged);
        skewed_epoch(&mut g, &mut sp);
        let opts =
            PushThreadOptions { tol: 1e-10, steal: true, steal_batch: 32, ..Default::default() };
        let tm = run_threaded_push(&g, &mut sp, &opts);
        // whether or not the scheduler produced a steal window, the
        // state must be exact and land on the reference
        assert!((sp.mass() - 1.0).abs() < 1e-9, "mass {}", sp.mass());
        assert_eq!(
            tm.stolen_rows.iter().sum::<u64>(),
            sp.steal_totals().0,
            "metrics vs state steal accounting"
        );
        if !tm.converged {
            let st = sp.solve(&g, 1e-10, u64::MAX);
            assert!(st.converged);
        }
        let (xref, _) = crate::stream::power_method_f64(&g, 0.85, 1e-12, 10_000);
        let d: f64 = sp.ranks().iter().zip(&xref).map(|(a, b)| (a - b).abs()).sum();
        assert!(d < 1e-8, "threaded steal drifted {d:.3e}");
    }

    #[test]
    fn threaded_push_steal_with_topk_stays_sound() {
        // stealing moves head candidates between shards mid-run; the
        // certified set must still be the true top-k
        let mut g = web(3_000, 77);
        let goal = TopKGoal { k: 16, order: false };
        let mut sp = ShardedPush::new(&g, 0.85, 4);
        let st = sp.solve(&g, 1e-10, u64::MAX);
        assert!(st.converged);
        skewed_epoch(&mut g, &mut sp);
        let mut tracker = TopKTracker::new(goal);
        let opts =
            PushThreadOptions { tol: 1e-10, steal: true, steal_batch: 32, ..Default::default() };
        let out = run_threaded_push_certified(&g, &mut sp, &mut tracker, &opts);
        assert!(out.cert.set_certified, "power-law web must certify k=16");
        assert!((sp.mass() - 1.0).abs() < 1e-9, "mass {}", sp.mass());
        let (xref, _) = crate::stream::power_method_f64(&g, 0.85, 1e-12, 10_000);
        let mut want = crate::pagerank::top_k_ids(&xref, 16);
        let mut got = out.cert.head.clone();
        want.sort_unstable();
        got.sort_unstable();
        assert_eq!(got, want, "certified head != reference top-16 under stealing");
    }

    #[test]
    fn threaded_push_timeout_leaves_exact_state() {
        let g = web(4_000, 73);
        let mut sp = ShardedPush::new(&g, 0.85, 4);
        // a timeout too short to converge: the run must come back
        // unconverged with a consistent (mass-conserving) state
        let opts = PushThreadOptions {
            tol: 1e-14,
            timeout: std::time::Duration::from_millis(30),
            ..Default::default()
        };
        let tm = run_threaded_push(&g, &mut sp, &opts);
        assert!((sp.mass() - 1.0).abs() < 1e-9, "mass {}", sp.mass());
        // finishing deterministically still reaches the fixed point
        let st = sp.solve(&g, 1e-10, u64::MAX);
        assert!(st.converged);
        let _ = tm;
    }

    // --- termination protocol & stop-cause regressions ---

    #[test]
    fn threaded_push_budget_below_shard_count_stops_fast() {
        // 3 pushes across 4 workers rounds down to zero-push slices:
        // every worker must exit on its budget before its first
        // publish. Regression for the monitor's sentinel handling — a
        // board of f64::MAX "never published" slots used to read as a
        // huge residual sum, wedging quiet detection until the full
        // timeout instead of letting the run wind down
        let g = web(2_000, 79);
        let mut sp = ShardedPush::new(&g, 0.85, 4);
        let opts = PushThreadOptions {
            tol: 1e-10,
            max_pushes: 3,
            term: TermMode::Quiet,
            timeout: std::time::Duration::from_secs(20),
            ..Default::default()
        };
        let t0 = Instant::now();
        let tm = run_threaded_push(&g, &mut sp, &opts);
        assert!(t0.elapsed() < std::time::Duration::from_secs(5), "run wedged on the timeout");
        assert_eq!(tm.stop_cause, StopCause::Budget);
        assert!(!tm.converged);
        assert_eq!(tm.shard_pushes.iter().sum::<u64>(), 0, "zero slices must spend nothing");
        assert!((sp.mass() - 1.0).abs() < 1e-9, "mass {}", sp.mass());
        // the untouched state is still a working solver
        let st = sp.solve(&g, 1e-10, u64::MAX);
        assert!(st.converged);
    }

    #[test]
    fn threaded_push_steal_low_budget_stays_exact() {
        // steal-heavy run under a budget small enough that workers
        // exhaust their slices mid-migration. Regression for the
        // budget arithmetic: `worker_budget - spent` underflowed in
        // debug builds when a worker overspent its slice by the
        // in-progress drain batch; the saturating form must ride it out
        let mut g = web(3_000, 78);
        let mut sp = ShardedPush::new(&g, 0.85, 4);
        let st = sp.solve(&g, 1e-10, u64::MAX);
        assert!(st.converged);
        skewed_epoch(&mut g, &mut sp);
        let opts = PushThreadOptions {
            tol: 1e-10,
            steal: true,
            steal_batch: 8,
            max_pushes: 1_200,
            ..Default::default()
        };
        let tm = run_threaded_push(&g, &mut sp, &opts);
        assert!(
            tm.shard_pushes.iter().sum::<u64>() <= 1_200,
            "budget overshot: {:?}",
            tm.shard_pushes
        );
        assert!(
            tm.converged || tm.stop_cause == StopCause::Budget,
            "unexpected stop: {:?}",
            tm.stop_cause
        );
        assert!((sp.mass() - 1.0).abs() < 1e-9, "mass {}", sp.mass());
        if !tm.converged {
            let st = sp.solve(&g, 1e-10, u64::MAX);
            assert!(st.converged);
        }
        let (xref, _) = crate::stream::power_method_f64(&g, 0.85, 1e-12, 10_000);
        let d: f64 = sp.ranks().iter().zip(&xref).map(|(a, b)| (a - b).abs()).sum();
        assert!(d < 1e-8, "budget-cut steal run drifted {d:.3e}");
    }

    /// The ISSUE's acceptance scenario, deterministically: one worker
    /// stalls while holding ALL the residual mass, before it ever
    /// publishes an estimate. The quiet window reads the three quiet
    /// peers (the stalled slot is a skipped sentinel) and stops with
    /// the global residual far above tol; the §4.2 protocol cannot —
    /// the stalled worker never announced CONVERGE, so the monitor
    /// waits it out and the run finishes to the fixed point.
    ///
    /// `unpush` (not churn) plants the residual: a real edit's deltas
    /// scatter to out-neighbors across shards, and the awake shards
    /// would ship fragments to the sleeper, parking `in_flight` above
    /// zero and masking the quiet window's unsoundness.
    #[test]
    fn threaded_push_stalled_worker_quiet_premature_protocol_sound() {
        let g = web(3_000, 81);
        let tol = 1e-9;
        let mut sp = ShardedPush::new(&g, 0.85, 4);
        let st = sp.solve(&g, 1e-12, u64::MAX);
        assert!(st.converged, "warm converge");
        let dr = sp.shards[3].unpush(0.5);
        assert!(dr > 1e3 * tol, "perturbation too small to discriminate: {dr:.3e}");
        assert!((sp.mass() - 1.0).abs() < 1e-9, "unpush must conserve mass: {}", sp.mass());
        let stall = StallInjection { worker: 3, after_rounds: 0, ms: 400 };
        let quiet_opts = PushThreadOptions {
            tol,
            term: TermMode::Quiet,
            inject_stall: Some(stall),
            ..Default::default()
        };
        let tm = run_threaded_push(&g, &mut sp, &quiet_opts);
        assert_eq!(tm.stop_cause, StopCause::QuietWindow, "quiet window must have fired");
        assert!(!tm.converged, "the premature stop left residual {:.3e}", tm.residual);
        assert!(tm.residual > tol, "residual {:.3e} vs tol {tol:.0e}", tm.residual);
        assert_eq!(tm.term_converge, 0, "no §4.2 traffic in quiet mode");
        assert!((sp.mass() - 1.0).abs() < 1e-9, "mass {}", sp.mass());

        // same state (the residual survived untouched), same stall —
        // under the protocol the stop is provably sound
        let proto_opts = PushThreadOptions { term: TermMode::Protocol, ..quiet_opts };
        let tm = run_threaded_push(&g, &mut sp, &proto_opts);
        assert_eq!(tm.stop_cause, StopCause::Protocol, "residual {:.3e}", tm.residual);
        assert!(tm.converged, "Protocol stop implies convergence; residual {:.3e}", tm.residual);
        assert!(tm.residual < tol);
        assert!(tm.term_converge >= 4, "every worker announces before STOP");
        assert!((sp.mass() - 1.0).abs() < 1e-9, "mass {}", sp.mass());
    }

    #[test]
    fn grant_restore_rearms_head_frame_tracking() {
        // unit-level walk of the victim's grant-issue / failed-send /
        // restore sequence. Regression: the restore path must re-arm
        // the head tracking AGAIN after `restore_grant` — without it a
        // frame published between the pre-send reset and the bounce
        // (missing the granted rows) would stay current, and the
        // serving monitor could certify a head that silently lost them
        let g = web(2_000, 82);
        let goal = TopKGoal { k: 32, order: false };
        let mut sp = ShardedPush::new(&g, 0.85, 2);
        let st = sp.solve(&g, 1e-10, u64::MAX);
        assert!(st.converged);
        let shard = &mut sp.shards[0];
        // re-queue the hottest home row so the victim has work to grant
        let dr = shard.unpush(0.5);
        assert!(dr > 0.0);
        let frame = Mutex::new(None);
        let mut head_list = Some(HeadList::new(goal.pool_cap()));
        *frame.lock().unwrap() = Some(shard_frame(head_list.as_mut().unwrap(), shard, None));
        let mut frame_due = false; // the worker published its first frame
        let grant = shard.steal_out(1, 4).expect("unpush queued a stealable row");
        let hot = grant
            .rows
            .iter()
            .max_by(|a, b| a.r.abs().partial_cmp(&b.r.abs()).unwrap())
            .unwrap()
            .node;
        reset_head_tracking(&frame, &mut head_list, &mut frame_due, Some(goal));
        assert!(frame.lock().unwrap().is_none(), "pre-send reset must clear the frame");
        assert!(frame_due, "pre-send reset must schedule a rebuild");
        // a frame built while the row is lent must exclude it (the
        // thief reports it) — this is the snapshot that must NOT
        // survive the restore
        let mid = shard_frame(head_list.as_mut().unwrap(), shard, None);
        assert!(mid.entries.iter().all(|&(id, _)| id != hot), "lent row leaked into a frame");
        frame_due = false; // the worker published `mid`
        // the channel was full: the grant bounces home
        shard.restore_grant(grant);
        reset_head_tracking(&frame, &mut head_list, &mut frame_due, Some(goal));
        assert!(frame_due, "restore must re-arm the frame rebuild");
        assert!(frame.lock().unwrap().is_none(), "stale pre-restore frame must not survive");
        let rebuilt = shard_frame(head_list.as_mut().unwrap(), shard, None);
        assert!(
            rebuilt.entries.iter().any(|&(id, _)| id == hot),
            "rebuilt frame must contain the restored hot row"
        );
    }
}
