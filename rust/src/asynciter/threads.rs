//! Real-thread execution backend — the paper's actual implementation
//! style (§5.1: computation objects on threads, non-blocking sends via
//! thread-wrapped blocking channels, bounded queues, a monitor running
//! the Figure-1 protocol).
//!
//! Where [`super::SimEngine`] runs the cluster under a deterministic
//! virtual clock (used for every paper table), `ThreadEngine` runs the
//! same block operators on actual OS threads with `std::sync::mpsc`
//! channels and wall-clock time — the deployment path for a real
//! multicore host, and a cross-check that the asynchronous iteration
//! converges under genuine nondeterministic interleaving.
//!
//! Design notes:
//! * fragments flow through bounded channels; a full channel DROPS the
//!   fragment (the §6 cancellation window, in its simplest form) —
//!   asynchronous iterations tolerate loss, so this is safe;
//! * workers own `NativeBlockOp`s (PJRT handles are not `Send`; the
//!   artifact path stays on the simulator / main thread);
//! * the monitor thread runs the same `MonitorTermination` state
//!   machine used by the simulator.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::Arc;
use std::time::Instant;

use crate::pagerank::PagerankProblem;
use crate::termination::{MonitorTermination, TermMsg, WorkerTermination};

/// Options for a threaded run.
#[derive(Debug, Clone)]
pub struct ThreadRunOptions {
    pub tol: f32,
    pub pc_max_worker: u32,
    pub pc_max_monitor: u32,
    /// Per-link fragment queue depth; a full queue drops the fragment.
    pub channel_depth: usize,
    /// Hard wall-clock cap.
    pub timeout: std::time::Duration,
    /// Minimum wall time per iteration. Real deployments have heavy
    /// per-iteration compute (the paper: ~1.3 s of SpMV); on an
    /// oversubscribed test host a floor keeps the OS scheduler
    /// interleaving workers, so DIVERGE messages can actually race
    /// STOP the way they do on a real cluster.
    pub min_iteration_interval: std::time::Duration,
}

impl Default for ThreadRunOptions {
    fn default() -> Self {
        ThreadRunOptions {
            tol: 1e-6,
            // stricter than the simulator's paper setting: real threads
            // iterate microseconds apart, so a little persistence guards
            // against converging on a not-yet-imported view
            pc_max_worker: 3,
            pc_max_monitor: 1,
            channel_depth: 2,
            timeout: std::time::Duration::from_secs(60),
            min_iteration_interval: std::time::Duration::from_micros(200),
        }
    }
}

/// Outcome of a threaded run.
#[derive(Debug, Clone)]
pub struct ThreadRunMetrics {
    pub iters: Vec<u64>,
    /// Fragments dropped on full channels, per sender.
    pub dropped: Vec<u64>,
    pub wall: std::time::Duration,
    pub x: Vec<f32>,
    pub final_global_residual: f32,
}

struct Fragment {
    src: usize,
    data: Vec<f32>,
}

/// Run the asynchronous iteration on real threads (one per UE, plus the
/// Figure-1 monitor inline on the coordinator thread).
pub fn run_threaded(
    problem: &Arc<PagerankProblem>,
    blocks: &[(usize, usize)],
    opts: &ThreadRunOptions,
) -> ThreadRunMetrics {
    let p = blocks.len();
    assert!(p >= 1);
    let n = problem.n();
    assert_eq!(blocks[0].0, 0);
    assert_eq!(blocks[p - 1].1, n);

    let stop = Arc::new(AtomicBool::new(false));
    // all workers start iterating together (the paper's §5.1 launch
    // phase distributes data first); without this, thread-startup skew
    // lets the first worker converge on frozen data before its peers
    // have produced a single fragment
    let start = Arc::new(std::sync::Barrier::new(p));
    let t0 = Instant::now();

    // fragment channels: frag_tx[dst][src] -> frag_rx[dst]
    let mut frag_tx: Vec<Vec<SyncSender<Fragment>>> = Vec::with_capacity(p);
    let mut frag_rx: Vec<Option<Receiver<Fragment>>> = Vec::with_capacity(p);
    for _ in 0..p {
        let (tx, rx) = sync_channel::<Fragment>(opts.channel_depth.max(1) * p);
        frag_tx.push(vec![tx; p]);
        frag_rx.push(Some(rx));
    }
    // control channel to the monitor
    let (ctl_tx, ctl_rx) = sync_channel::<(usize, TermMsg)>(p * 8);

    let results: Vec<_> = std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(p);
        for ue in 0..p {
            let (lo, hi) = blocks[ue];
            let problem = Arc::clone(problem);
            let stop = Arc::clone(&stop);
            let ctl_tx = ctl_tx.clone();
            // senders to every peer's inbox slot for this src
            let peers: Vec<(usize, SyncSender<Fragment>)> = (0..p)
                .filter(|&j| j != ue)
                .map(|j| (j, frag_tx[j][ue].clone()))
                .collect();
            let rx = frag_rx[ue].take().unwrap();
            let opts = opts.clone();
            let start = Arc::clone(&start);
            handles.push(scope.spawn(move || {
                start.wait();
                let mut x = problem.uniform_start();
                let mut out = vec![0.0f32; hi - lo];
                let mut term = WorkerTermination::new(opts.pc_max_worker);
                let mut iters = 0u64;
                let mut dropped = 0u64;
                let deadline = Instant::now() + opts.timeout;
                while !stop.load(Ordering::Relaxed) && Instant::now() < deadline {
                    let iter_start = Instant::now();
                    // import everything currently queued (non-blocking)
                    while let Ok(frag) = rx.try_recv() {
                        let (flo, fhi) = blocks[frag.src];
                        x[flo..fhi].copy_from_slice(&frag.data);
                    }
                    // one local update (eq. 6)
                    problem.apply_google_range(&x, lo, hi, &mut out);
                    let resid = crate::pagerank::l1_diff(&out, &x[lo..hi]);
                    x[lo..hi].copy_from_slice(&out);
                    iters += 1;
                    // non-blocking sends; full queue == cancelled thread
                    for (_, tx) in &peers {
                        match tx.try_send(Fragment { src: ue, data: out.clone() }) {
                            Ok(()) => {}
                            Err(TrySendError::Full(_)) => dropped += 1,
                            Err(TrySendError::Disconnected(_)) => {}
                        }
                    }
                    if let Some(msg) = term.on_iteration(resid < opts.tol) {
                        let _ = ctl_tx.try_send((ue, msg));
                    }
                    let spent = iter_start.elapsed();
                    if spent < opts.min_iteration_interval {
                        std::thread::sleep(opts.min_iteration_interval - spent);
                    }
                }
                (iters, dropped, x)
            }));
        }
        drop(ctl_tx);

        // Figure-1 monitor, inline
        let mut monitor = MonitorTermination::new(p, opts.pc_max_monitor);
        let deadline = Instant::now() + opts.timeout;
        while !stop.load(Ordering::Relaxed) && Instant::now() < deadline {
            match ctl_rx.recv_timeout(std::time::Duration::from_millis(5)) {
                Ok((ue, msg)) => {
                    if monitor.on_message(ue, msg) {
                        stop.store(true, Ordering::Relaxed);
                    }
                }
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        stop.store(true, Ordering::Relaxed);
        handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
    });

    // assemble the final vector from each worker's own block
    let mut x = vec![0.0f32; n];
    let mut iters = Vec::with_capacity(p);
    let mut dropped = Vec::with_capacity(p);
    for (ue, (it, dr, xw)) in results.into_iter().enumerate() {
        let (lo, hi) = blocks[ue];
        x[lo..hi].copy_from_slice(&xw[lo..hi]);
        iters.push(it);
        dropped.push(dr);
    }
    let mut scratch = vec![0.0f32; n];
    problem.apply_google(&x, &mut scratch);
    let resid = crate::pagerank::l1_diff(&scratch, &x);

    ThreadRunMetrics {
        iters,
        dropped,
        wall: t0.elapsed(),
        x,
        final_global_residual: resid,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Partitioner;
    use crate::graph::{generators, Csr};
    use crate::pagerank::{kendall_tau, power_method, PowerOptions};

    fn problem(n: usize, seed: u64) -> Arc<PagerankProblem> {
        let el = generators::power_law_web(&generators::WebParams::scaled(n), seed);
        Arc::new(PagerankProblem::new(Csr::from_edgelist(&el).unwrap(), 0.85))
    }

    #[test]
    fn threaded_run_converges_and_stops() {
        let problem = problem(2_000, 61);
        let blocks = Partitioner::consecutive(problem.n(), 3).blocks();
        // tighter local threshold: with only 2 host cores the OS can
        // deschedule a worker long enough for its peers to go locally
        // quiet on stale data — exactly the premature-stop the paper's
        // persistence counters mitigate; tol 1e-7 absorbs it
        let opts = ThreadRunOptions { tol: 1e-7, pc_max_worker: 5, ..Default::default() };
        let m = run_threaded(&problem, &blocks, &opts);
        assert!(m.wall < std::time::Duration::from_secs(55), "hit the timeout");
        assert!(m.iters.iter().all(|&i| i > 0), "{:?}", m.iters);
        assert!(
            m.final_global_residual < 1e-2,
            "resid {}",
            m.final_global_residual
        );
        // ranking matches the synchronous reference
        let pm = power_method(
            &problem,
            &PowerOptions { tol: 1e-9, max_iters: 5000, record_residuals: false },
        );
        let tau = kendall_tau(&m.x, &pm.x);
        assert!(tau > 0.97, "tau {tau}"); // nondeterministic interleaving
    }

    #[test]
    fn threaded_run_single_ue() {
        let problem = problem(800, 62);
        let blocks = vec![(0, problem.n())];
        let m = run_threaded(&problem, &blocks, &ThreadRunOptions::default());
        assert_eq!(m.iters.len(), 1);
        assert!(m.final_global_residual < 1e-4);
    }

    #[test]
    fn bounded_queues_drop_under_pressure() {
        let problem = problem(3_000, 63);
        let blocks = Partitioner::consecutive(problem.n(), 2).blocks();
        let opts = ThreadRunOptions {
            channel_depth: 1,
            tol: 1e-9, // run long enough to generate pressure
            timeout: std::time::Duration::from_secs(5),
            ..Default::default()
        };
        let m = run_threaded(&problem, &blocks, &opts);
        // with depth-1 queues and free-running senders, drops are
        // overwhelmingly likely; we only assert the run survived them
        assert!(m.iters.iter().all(|&i| i > 10));
        let _ = m.dropped;
    }
}
