//! Real-thread execution backend — the paper's actual implementation
//! style (§5.1: computation objects on threads, non-blocking sends via
//! thread-wrapped blocking channels, bounded queues, a monitor running
//! the Figure-1 protocol).
//!
//! Where [`super::SimEngine`] runs the cluster under a deterministic
//! virtual clock (used for every paper table), `ThreadEngine` runs the
//! same block operators on actual OS threads with `std::sync::mpsc`
//! channels and wall-clock time — the deployment path for a real
//! multicore host, and a cross-check that the asynchronous iteration
//! converges under genuine nondeterministic interleaving.
//!
//! Design notes:
//! * fragments flow through bounded channels; a full channel DROPS the
//!   fragment (the §6 cancellation window, in its simplest form) —
//!   asynchronous iterations tolerate loss, so this is safe;
//! * workers own `NativeBlockOp`s (PJRT handles are not `Send`; the
//!   artifact path stays on the simulator / main thread);
//! * the monitor thread runs the same `MonitorTermination` state
//!   machine used by the simulator.

use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Barrier, Mutex};
use std::time::Instant;

use crate::pagerank::PagerankProblem;
use crate::stream::{
    certify_frames, shard_frame, DeltaGraph, HeadList, ResidualFragment, ShardHeadFrame,
    ShardedPush, TopKCertificate, TopKGoal, TopKTracker,
};
use crate::termination::{MonitorTermination, TermMsg, WorkerTermination};

/// Options for a threaded run.
#[derive(Debug, Clone)]
pub struct ThreadRunOptions {
    pub tol: f32,
    pub pc_max_worker: u32,
    pub pc_max_monitor: u32,
    /// Per-link fragment queue depth; a full queue drops the fragment.
    pub channel_depth: usize,
    /// Hard wall-clock cap.
    pub timeout: std::time::Duration,
    /// Minimum wall time per iteration. Real deployments have heavy
    /// per-iteration compute (the paper: ~1.3 s of SpMV); on an
    /// oversubscribed test host a floor keeps the OS scheduler
    /// interleaving workers, so DIVERGE messages can actually race
    /// STOP the way they do on a real cluster.
    pub min_iteration_interval: std::time::Duration,
}

impl Default for ThreadRunOptions {
    fn default() -> Self {
        ThreadRunOptions {
            tol: 1e-6,
            // stricter than the simulator's paper setting: real threads
            // iterate microseconds apart, so a little persistence guards
            // against converging on a not-yet-imported view
            pc_max_worker: 3,
            pc_max_monitor: 1,
            channel_depth: 2,
            timeout: std::time::Duration::from_secs(60),
            min_iteration_interval: std::time::Duration::from_micros(200),
        }
    }
}

/// Outcome of a threaded run.
#[derive(Debug, Clone)]
pub struct ThreadRunMetrics {
    pub iters: Vec<u64>,
    /// Fragments dropped on full channels, per sender.
    pub dropped: Vec<u64>,
    pub wall: std::time::Duration,
    pub x: Vec<f32>,
    pub final_global_residual: f32,
}

struct Fragment {
    src: usize,
    data: Vec<f32>,
}

/// Run the asynchronous iteration on real threads (one per UE, plus the
/// Figure-1 monitor inline on the coordinator thread).
pub fn run_threaded(
    problem: &Arc<PagerankProblem>,
    blocks: &[(usize, usize)],
    opts: &ThreadRunOptions,
) -> ThreadRunMetrics {
    let p = blocks.len();
    assert!(p >= 1);
    let n = problem.n();
    assert_eq!(blocks[0].0, 0);
    assert_eq!(blocks[p - 1].1, n);

    let stop = Arc::new(AtomicBool::new(false));
    // all workers start iterating together (the paper's §5.1 launch
    // phase distributes data first); without this, thread-startup skew
    // lets the first worker converge on frozen data before its peers
    // have produced a single fragment
    let start = Arc::new(std::sync::Barrier::new(p));
    let t0 = Instant::now();

    // fragment channels: frag_tx[dst][src] -> frag_rx[dst]
    let mut frag_tx: Vec<Vec<SyncSender<Fragment>>> = Vec::with_capacity(p);
    let mut frag_rx: Vec<Option<Receiver<Fragment>>> = Vec::with_capacity(p);
    for _ in 0..p {
        let (tx, rx) = sync_channel::<Fragment>(opts.channel_depth.max(1) * p);
        frag_tx.push(vec![tx; p]);
        frag_rx.push(Some(rx));
    }
    // control channel to the monitor
    let (ctl_tx, ctl_rx) = sync_channel::<(usize, TermMsg)>(p * 8);

    let results: Vec<_> = std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(p);
        for ue in 0..p {
            let (lo, hi) = blocks[ue];
            let problem = Arc::clone(problem);
            let stop = Arc::clone(&stop);
            let ctl_tx = ctl_tx.clone();
            // senders to every peer's inbox slot for this src
            let peers: Vec<(usize, SyncSender<Fragment>)> = (0..p)
                .filter(|&j| j != ue)
                .map(|j| (j, frag_tx[j][ue].clone()))
                .collect();
            let rx = frag_rx[ue].take().unwrap();
            let opts = opts.clone();
            let start = Arc::clone(&start);
            handles.push(scope.spawn(move || {
                start.wait();
                let mut x = problem.uniform_start();
                let mut out = vec![0.0f32; hi - lo];
                let mut term = WorkerTermination::new(opts.pc_max_worker);
                let mut iters = 0u64;
                let mut dropped = 0u64;
                let deadline = Instant::now() + opts.timeout;
                while !stop.load(Ordering::Relaxed) && Instant::now() < deadline {
                    let iter_start = Instant::now();
                    // import everything currently queued (non-blocking)
                    while let Ok(frag) = rx.try_recv() {
                        let (flo, fhi) = blocks[frag.src];
                        x[flo..fhi].copy_from_slice(&frag.data);
                    }
                    // one local update (eq. 6)
                    problem.apply_google_range(&x, lo, hi, &mut out);
                    let resid = crate::pagerank::l1_diff(&out, &x[lo..hi]);
                    x[lo..hi].copy_from_slice(&out);
                    iters += 1;
                    // non-blocking sends; full queue == cancelled thread
                    for (_, tx) in &peers {
                        match tx.try_send(Fragment { src: ue, data: out.clone() }) {
                            Ok(()) => {}
                            Err(TrySendError::Full(_)) => dropped += 1,
                            Err(TrySendError::Disconnected(_)) => {}
                        }
                    }
                    if let Some(msg) = term.on_iteration(resid < opts.tol) {
                        let _ = ctl_tx.try_send((ue, msg));
                    }
                    let spent = iter_start.elapsed();
                    if spent < opts.min_iteration_interval {
                        std::thread::sleep(opts.min_iteration_interval - spent);
                    }
                }
                (iters, dropped, x)
            }));
        }
        drop(ctl_tx);

        // Figure-1 monitor, inline
        let mut monitor = MonitorTermination::new(p, opts.pc_max_monitor);
        let deadline = Instant::now() + opts.timeout;
        while !stop.load(Ordering::Relaxed) && Instant::now() < deadline {
            match ctl_rx.recv_timeout(std::time::Duration::from_millis(5)) {
                Ok((ue, msg)) => {
                    if monitor.on_message(ue, msg) {
                        stop.store(true, Ordering::Relaxed);
                    }
                }
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        stop.store(true, Ordering::Relaxed);
        handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
    });

    // assemble the final vector from each worker's own block
    let mut x = vec![0.0f32; n];
    let mut iters = Vec::with_capacity(p);
    let mut dropped = Vec::with_capacity(p);
    for (ue, (it, dr, xw)) in results.into_iter().enumerate() {
        let (lo, hi) = blocks[ue];
        x[lo..hi].copy_from_slice(&xw[lo..hi]);
        iters.push(it);
        dropped.push(dr);
    }
    let mut scratch = vec![0.0f32; n];
    problem.apply_google(&x, &mut scratch);
    let resid = crate::pagerank::l1_diff(&scratch, &x);

    ThreadRunMetrics {
        iters,
        dropped,
        wall: t0.elapsed(),
        x,
        final_global_residual: resid,
    }
}

// ---------------------------------------------------------------------
// Residual-push backend: true distributed D-Iteration on threads.
// ---------------------------------------------------------------------

/// Options for a threaded residual-push run.
#[derive(Debug, Clone)]
pub struct PushThreadOptions {
    /// Global residual target `Σ_s (‖r_s‖₁ + |uni_s|·|B_s|/n) < tol`.
    pub tol: f64,
    /// Local pushes each shard spends between channel services.
    pub round_pushes: u64,
    /// Per-inbox fragment queue depth multiplier (actual depth is
    /// `channel_depth * shards`); a full queue defers the fragment —
    /// it is re-accumulated locally and retried, never dropped.
    pub channel_depth: usize,
    /// Hard wall-clock cap (the run stays correct when it fires: the
    /// gathered state is exact, just not converged).
    pub timeout: std::time::Duration,
    /// Total push budget across all shards (safety cap, split evenly
    /// per worker; the first worker to exhaust its slice stops the
    /// run). The state stays exact when it fires.
    pub max_pushes: u64,
    /// Consecutive quiet monitor samples required before stopping
    /// (guards against the publish/apply race around fragment hand-off).
    pub quiet_checks: u32,
    /// When set, re-balance the shard bounds before spawning workers if
    /// churn has skewed the per-shard out-nnz beyond this factor of the
    /// ideal share ([`ShardedPush::rebalance`]) — the epoch-resident
    /// path's answer to hubs arriving in one shard's row range.
    pub rebalance_factor: Option<f64>,
    /// Serving-path early stop: workers stream per-shard head-candidate
    /// frames to the monitor alongside their residual estimates, and
    /// the run winds down as soon as the merged frames *tentatively*
    /// certify this top-k goal (see [`crate::stream::TopKTracker`]).
    /// Tentative because worker frames are asynchronous snapshots — the
    /// caller must re-check on the gathered/settled state (an exact
    /// [`TopKTracker::check_sharded`] call) and resume if the exact
    /// check fails. Ignored on the single-shard fast path (drive that
    /// with [`crate::stream::solve_certified_sharded`] instead).
    ///
    /// [`TopKTracker::check_sharded`]: crate::stream::TopKTracker::check_sharded
    pub topk: Option<TopKGoal>,
}

impl Default for PushThreadOptions {
    fn default() -> Self {
        PushThreadOptions {
            tol: 1e-10,
            round_pushes: 4096,
            channel_depth: 4,
            timeout: std::time::Duration::from_secs(30),
            max_pushes: u64::MAX,
            quiet_checks: 3,
            rebalance_factor: None,
            topk: None,
        }
    }
}

/// Outcome of a threaded residual-push run.
#[derive(Debug, Clone)]
pub struct PushThreadMetrics {
    /// Pushes performed per shard.
    pub shard_pushes: Vec<u64>,
    /// Drain/exchange rounds per shard.
    pub rounds: Vec<u64>,
    /// Residual fragments delivered per shard.
    pub fragments_sent: Vec<u64>,
    /// Fragments deferred on a full channel (retried later) per shard.
    pub fragments_deferred: Vec<u64>,
    pub wall: std::time::Duration,
    /// Exact residual mass after the run (re-tallied, outboxes
    /// delivered).
    pub residual: f64,
    /// Whether `residual < tol` — when false (timeout or a premature
    /// quiet window), the caller finishes the solve sequentially; the
    /// state is exact either way.
    pub converged: bool,
    /// Whether the pre-run skew check migrated the shard bounds
    /// (only with [`PushThreadOptions::rebalance_factor`]).
    pub rebalanced: bool,
    /// Whether the monitor cut the run on a *tentative* top-k
    /// certification (only with [`PushThreadOptions::topk`]; the caller
    /// re-checks exactly on the settled state).
    pub topk_stopped: bool,
}

/// Run the sharded residual-push solver on real OS threads — the
/// distributed D-Iteration counterpart of [`run_threaded`].
///
/// Where [`run_threaded`] workers ship their *whole rank fragment*
/// every iteration (and a full queue drops it — newer supersedes
/// older), push workers ship only the **residual mass** their pushes
/// created for out-of-shard rows. Residuals are additive and
/// conservative, so a full channel just defers the fragment: the mass
/// re-accumulates in the sender's outbox and ships in the next round's
/// merged batch. Nothing is ever lost, which is what lets the final
/// gathered state stay *exact* (mass conserved to float accumulation)
/// no matter how the OS interleaves the workers — only the *schedule*
/// is nondeterministic, never the invariant.
///
/// Termination: each worker publishes a conservative residual estimate
/// (local + everything parked in its outboxes) after every round; an
/// inline monitor stops the run once the published sum stays below
/// `tol` with zero fragments in flight for
/// [`quiet_checks`](PushThreadOptions::quiet_checks) consecutive
/// samples. A publish/apply race can still stop the run a hair early —
/// the returned `converged` flag reports the *exact* post-gather
/// residual, and callers polish sequentially when it is false.
pub fn run_threaded_push(
    g: &DeltaGraph,
    state: &mut ShardedPush,
    opts: &PushThreadOptions,
) -> PushThreadMetrics {
    assert_eq!(state.n(), g.n(), "sharded state sized to a different graph");
    assert!(opts.tol > 0.0, "tol must be positive");
    let t0 = Instant::now();
    // epoch-resident callers leave the state in place across churn; the
    // entry skew check is where the bounds catch up with the degree
    // distribution (shard count may change — read it after)
    let rebalanced = match opts.rebalance_factor {
        Some(f) => state.rebalance(g, f),
        None => false,
    };
    let s = state.shard_count();
    let deadline = t0 + opts.timeout;
    if s == 1 {
        // no peers, no channels: the deterministic drain is the run —
        // sliced so the timeout and the push budget still apply
        let step = opts.round_pushes.max(1);
        let mut pushes = 0u64;
        let mut rounds = 0u64;
        let (residual, converged) = loop {
            let remaining = opts.max_pushes.saturating_sub(pushes);
            if remaining == 0 {
                break (state.residual_exact(), false);
            }
            let st = state.solve(g, opts.tol, step.min(remaining));
            pushes += st.pushes;
            rounds += st.rounds;
            if st.converged || st.pushes == 0 || Instant::now() >= deadline {
                break (st.residual, st.converged);
            }
        };
        return PushThreadMetrics {
            shard_pushes: vec![pushes],
            rounds: vec![rounds],
            fragments_sent: vec![0],
            fragments_deferred: vec![0],
            wall: t0.elapsed(),
            residual,
            converged,
            rebalanced,
            topk_stopped: false,
        };
    }

    let tol = opts.tol;
    let alpha = state.alpha();
    let goal = opts.topk;
    let local_target = 0.5 * tol / s as f64;
    let round_budget = opts.round_pushes.max(1);
    // per-worker slice of the global push budget; s * floor never
    // exceeds the requested total (a budget below the shard count
    // rounds down to zero work, it does not overshoot)
    let worker_budget = opts.max_pushes / s as u64;
    let stop = Arc::new(AtomicBool::new(false));
    // fragments handed to a channel but not yet applied by the
    // receiver — counted so the monitor never declares quiet while
    // mass is in flight
    let in_flight = Arc::new(AtomicI64::new(0));
    let published: Arc<Vec<AtomicU64>> =
        Arc::new((0..s).map(|_| AtomicU64::new(f64::MAX.to_bits())).collect());
    // per-shard head-candidate frames for the serving-path monitor
    // (None until the owning worker's first publish)
    let head_frames: Arc<Vec<Mutex<Option<ShardHeadFrame>>>> =
        Arc::new((0..s).map(|_| Mutex::new(None)).collect());
    let topk_stop = Arc::new(AtomicBool::new(false));
    // all senders stop before this barrier; inboxes are drained after
    // it, so no fragment can be stranded in a dead channel
    let drained = Arc::new(Barrier::new(s));

    // one inbox per shard, every peer holds a sender to it
    let mut txs: Vec<SyncSender<ResidualFragment>> = Vec::with_capacity(s);
    let mut rxs: Vec<Option<Receiver<ResidualFragment>>> = Vec::with_capacity(s);
    for _ in 0..s {
        let (tx, rx) = sync_channel::<ResidualFragment>(opts.channel_depth.max(1) * s);
        txs.push(tx);
        rxs.push(Some(rx));
    }

    let results: Vec<(u64, u64, u64, u64)> = std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(s);
        for (id, shard) in state.shards.iter_mut().enumerate() {
            let rx = rxs[id].take().unwrap();
            let txs = txs.clone();
            let stop = Arc::clone(&stop);
            let in_flight = Arc::clone(&in_flight);
            let published = Arc::clone(&published);
            let head_frames = Arc::clone(&head_frames);
            let drained = Arc::clone(&drained);
            handles.push(scope.spawn(move || {
                let p0 = shard.pushes();
                let mut rounds = 0u64;
                let mut sent = 0u64;
                let mut deferred = 0u64;
                // serving path: this worker's head-candidate pool, fed
                // by the shard's hit stream (first refresh scans the
                // shard, later ones are O(hits))
                let mut head_list = goal.map(|gl| HeadList::new(gl.pool_cap()));
                let mut frame_due = true;
                loop {
                    // import residual fragments queued by the peers
                    let mut received = false;
                    while let Ok(frag) = rx.try_recv() {
                        shard.apply_fragment(&frag);
                        in_flight.fetch_sub(1, Ordering::AcqRel);
                        received = true;
                    }
                    if stop.load(Ordering::Acquire) || Instant::now() >= deadline {
                        break;
                    }
                    // drain the local bucket queue, honoring this
                    // worker's slice of the global push budget
                    let spent = shard.pushes() - p0;
                    let pushed =
                        shard.drain(g, local_target, round_budget.min(worker_budget - spent));
                    if shard.pushes() - p0 >= worker_budget {
                        // budget exhausted: wind the whole run down
                        stop.store(true, Ordering::Release);
                    }
                    // ship the outboxes; a full channel defers, never drops
                    for (j, tx) in txs.iter().enumerate() {
                        if j == id {
                            shard.absorb_self_uniform();
                            continue;
                        }
                        if let Some(frag) = shard.take_fragment(j) {
                            in_flight.fetch_add(1, Ordering::AcqRel);
                            match tx.try_send(frag) {
                                Ok(()) => sent += 1,
                                Err(TrySendError::Full(frag)) => {
                                    in_flight.fetch_sub(1, Ordering::AcqRel);
                                    shard.restore_fragment(j, frag);
                                    deferred += 1;
                                }
                                Err(TrySendError::Disconnected(frag)) => {
                                    in_flight.fetch_sub(1, Ordering::AcqRel);
                                    shard.restore_fragment(j, frag);
                                }
                            }
                        }
                    }
                    if let Some(hl) = head_list.as_mut() {
                        if frame_due || pushed > 0 || received {
                            *head_frames[id].lock().unwrap() = Some(shard_frame(hl, shard));
                            frame_due = false;
                        }
                    }
                    published[id]
                        .store(shard.residual_estimate().to_bits(), Ordering::Release);
                    rounds += 1;
                    if pushed == 0 && !received {
                        // locally quiet: let the peers have the cores
                        std::thread::sleep(std::time::Duration::from_micros(50));
                    }
                }
                // every worker reaches this barrier before anyone's
                // final drain, and nobody sends after it — so the drain
                // below observes every fragment ever sent
                drained.wait();
                while let Ok(frag) = rx.try_recv() {
                    shard.apply_fragment(&frag);
                    in_flight.fetch_sub(1, Ordering::AcqRel);
                }
                (shard.pushes() - p0, rounds, sent, deferred)
            }));
        }

        // inline monitor: quiet = published residual under tol with no
        // fragments in flight, persisted across consecutive samples.
        // With a top-k goal it additionally merges the workers' head
        // frames and stops the moment they certify — tentatively, since
        // the frames are asynchronous snapshots; the caller re-checks
        // exactly on the settled state.
        let mut quiet = 0u32;
        while !stop.load(Ordering::Acquire) && Instant::now() < deadline {
            std::thread::sleep(std::time::Duration::from_micros(300));
            if let Some(gl) = goal {
                if in_flight.load(Ordering::Acquire) == 0 {
                    let frames: Vec<ShardHeadFrame> = head_frames
                        .iter()
                        .filter_map(|m| m.lock().unwrap().clone())
                        .collect();
                    if frames.len() == s
                        && certify_frames(&frames, gl.k, alpha).certified(gl.order)
                    {
                        topk_stop.store(true, Ordering::Release);
                        stop.store(true, Ordering::Release);
                        continue;
                    }
                }
            }
            let total: f64 = published
                .iter()
                .map(|a| f64::from_bits(a.load(Ordering::Acquire)))
                .sum();
            if total < tol && in_flight.load(Ordering::Acquire) == 0 {
                quiet += 1;
                if quiet >= opts.quiet_checks.max(1) {
                    stop.store(true, Ordering::Release);
                }
            } else {
                quiet = 0;
            }
        }
        stop.store(true, Ordering::Release);
        handles
            .into_iter()
            .map(|h| h.join().expect("push worker panicked"))
            .collect()
    });

    // anything still parked in outboxes (deferred at the cut-off) is
    // delivered deterministically before the exact re-tally (dense:
    // the converged flag must not ride on drifted increments)
    state.exchange();
    if goal.is_some() {
        // the workers' head lists consumed the shards' hit streams and
        // re-armed the entry floors — detach so any outer tracker
        // rebuilds on its next check and no floor stays armed under
        // later untracked solves
        state.detach_head_tracking();
    }
    let residual = state.residual_recompute();
    let mut shard_pushes = Vec::with_capacity(s);
    let mut rounds = Vec::with_capacity(s);
    let mut fragments_sent = Vec::with_capacity(s);
    let mut fragments_deferred = Vec::with_capacity(s);
    for (p, r, f, d) in results {
        shard_pushes.push(p);
        rounds.push(r);
        fragments_sent.push(f);
        fragments_deferred.push(d);
    }
    PushThreadMetrics {
        shard_pushes,
        rounds,
        fragments_sent,
        fragments_deferred,
        wall: t0.elapsed(),
        residual,
        converged: residual < opts.tol,
        rebalanced,
        topk_stopped: topk_stop.load(Ordering::Acquire),
    }
}

/// Outcome of [`run_threaded_push_certified`].
#[derive(Debug, Clone)]
pub struct CertifiedRunOutcome {
    /// The last *exact* certificate (head reflects the settled state).
    pub cert: TopKCertificate,
    /// Pushes this call spent when the goal's certificate first held
    /// exactly (`Some(0)` = already certified at entry; `None` = the
    /// run ended — converged, timed out, or exhausted its budget —
    /// without one).
    pub pushes_to_cert: Option<u64>,
    /// Whether `residual < opts.tol` was reached.
    pub converged: bool,
    /// Exact residual at exit.
    pub residual: f64,
}

/// The tentative-certify / exact-recheck / resume protocol around
/// [`run_threaded_push`], packaged so every caller gets it right: the
/// monitor's top-k stop is only a *hint* (worker frames are
/// asynchronous snapshots), so each stopped run is re-checked exactly
/// on the settled state via `tracker` and resumed when the proof does
/// not actually hold — bounded attempts, so racing churn near the
/// k-boundary falls through to the caller's finish instead of
/// spinning. `opts.topk` is ignored; the goal comes from `tracker`.
pub fn run_threaded_push_certified(
    g: &DeltaGraph,
    state: &mut ShardedPush,
    tracker: &mut TopKTracker,
    opts: &PushThreadOptions,
) -> CertifiedRunOutcome {
    let goal = tracker.goal();
    let p0 = state.total_pushes();
    let mut cert = tracker.check_sharded(state);
    let mut pushes_to_cert = if cert.certified(goal.order) { Some(0) } else { None };
    let mut converged = false;
    let mut residual = f64::NAN;
    for _attempt in 0..8 {
        if pushes_to_cert.is_some() {
            break;
        }
        let used = state.total_pushes() - p0;
        let topts = PushThreadOptions {
            topk: Some(goal),
            max_pushes: opts.max_pushes.saturating_sub(used),
            ..opts.clone()
        };
        let tm = run_threaded_push(g, state, &topts);
        cert = tracker.check_sharded(state);
        if cert.certified(goal.order) {
            pushes_to_cert = Some(state.total_pushes() - p0);
        }
        if tm.converged {
            converged = true;
            residual = tm.residual;
            break;
        }
        if !tm.topk_stopped {
            break; // timeout or budget, not a tentative stop: don't loop
        }
    }
    if residual.is_nan() {
        residual = state.residual_recompute();
    }
    CertifiedRunOutcome { cert, pushes_to_cert, converged, residual }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Partitioner;
    use crate::graph::{generators, Csr};
    use crate::pagerank::{kendall_tau, power_method, PowerOptions};

    fn problem(n: usize, seed: u64) -> Arc<PagerankProblem> {
        let el = generators::power_law_web(&generators::WebParams::scaled(n), seed);
        Arc::new(PagerankProblem::new(Csr::from_edgelist(&el).unwrap(), 0.85))
    }

    /// The nondeterministic-interleaving assertions depend on the host
    /// scheduler (a descheduled worker lets its peers go locally quiet
    /// on stale data). Two CI-stability valves: the tau floor is
    /// env-tunable (`ASYNCPR_TAU_MIN`, default generous), and the run
    /// gets a few attempts before the test gives up — one bad schedule
    /// must not fail the suite.
    fn tau_floor() -> f64 {
        std::env::var("ASYNCPR_TAU_MIN")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(0.95)
    }

    #[test]
    fn threaded_run_converges_and_stops() {
        let problem = problem(2_000, 61);
        let blocks = Partitioner::consecutive(problem.n(), 3).blocks();
        let pm = power_method(
            &problem,
            &PowerOptions { tol: 1e-9, max_iters: 5000, record_residuals: false },
        );
        // tighter local threshold: with only 2 host cores the OS can
        // deschedule a worker long enough for its peers to go locally
        // quiet on stale data — exactly the premature-stop the paper's
        // persistence counters mitigate; tol 1e-7 absorbs it
        let opts = ThreadRunOptions { tol: 1e-7, pc_max_worker: 5, ..Default::default() };
        let mut last = (0.0f64, 0.0f32);
        for attempt in 0..3 {
            let m = run_threaded(&problem, &blocks, &opts);
            assert!(m.wall < std::time::Duration::from_secs(55), "hit the timeout");
            assert!(m.iters.iter().all(|&i| i > 0), "{:?}", m.iters);
            let tau = kendall_tau(&m.x, &pm.x);
            last = (tau, m.final_global_residual);
            if m.final_global_residual < 1e-2 && tau > tau_floor() {
                return;
            }
            eprintln!(
                "attempt {attempt}: tau {tau}, resid {} — retrying (scheduler luck)",
                m.final_global_residual
            );
        }
        panic!("3 attempts failed: tau {}, resid {}", last.0, last.1);
    }

    #[test]
    fn threaded_run_single_ue() {
        let problem = problem(800, 62);
        let blocks = vec![(0, problem.n())];
        let m = run_threaded(&problem, &blocks, &ThreadRunOptions::default());
        assert_eq!(m.iters.len(), 1);
        assert!(m.final_global_residual < 1e-4);
    }

    #[test]
    fn bounded_queues_drop_under_pressure() {
        let problem = problem(3_000, 63);
        let blocks = Partitioner::consecutive(problem.n(), 2).blocks();
        let opts = ThreadRunOptions {
            channel_depth: 1,
            tol: 1e-9, // unreachable in the window: keeps senders free-running
            // long enough to generate queue pressure, short enough for CI
            timeout: std::time::Duration::from_millis(1200),
            ..Default::default()
        };
        let m = run_threaded(&problem, &blocks, &opts);
        // with depth-1 queues and free-running senders, drops are
        // overwhelmingly likely; we only assert the run survived them
        assert!(m.iters.iter().all(|&i| i > 10), "{:?}", m.iters);
        let _ = m.dropped;
    }

    // --- residual-push backend ---

    fn web(n: usize, seed: u64) -> DeltaGraph {
        let el = generators::power_law_web(&generators::WebParams::scaled(n), seed);
        DeltaGraph::from_edgelist(&el)
    }

    #[test]
    fn threaded_push_agrees_with_sequential_and_conserves_mass() {
        let g = web(2_000, 71);
        let tol = 1e-10;
        // sequential single-shard reference, solved tighter so the
        // combined error bound stays under 10x the push tolerance
        let mut seq = crate::stream::PushState::new(g.n(), 0.85);
        seq.begin_epoch();
        let seq_stats = seq.solve(&g, tol * 0.1, u64::MAX);
        assert!(seq_stats.converged);

        let mut sp = ShardedPush::new(&g, 0.85, 4);
        let opts = PushThreadOptions { tol, ..Default::default() };
        let tm = run_threaded_push(&g, &mut sp, &opts);
        assert!(tm.shard_pushes.iter().sum::<u64>() > 0, "no parallel work done");
        assert_eq!(tm.shard_pushes.len(), 4);
        // gather and, if the monitor cut early (timeout/quiet race),
        // finish sequentially — the gathered state is exact either way
        let mut out = crate::stream::PushState::new(g.n(), 0.85);
        out.begin_epoch();
        sp.gather_into(&mut out);
        if !tm.converged {
            let polish = out.solve(&g, tol, u64::MAX);
            assert!(polish.converged);
        }
        let d: f64 = out
            .ranks()
            .iter()
            .zip(seq.ranks())
            .map(|(a, b)| (a - b).abs())
            .sum();
        assert!(d < 10.0 * tol, "threaded vs sequential drift {d:.3e}");
        let mass: f64 = out.ranks().iter().sum();
        assert!((mass - 1.0).abs() < 1e-9, "mass {mass}");
    }

    #[test]
    fn threaded_push_single_shard_falls_back_to_sequential() {
        let g = web(600, 72);
        let mut sp = ShardedPush::new(&g, 0.85, 1);
        let tm = run_threaded_push(&g, &mut sp, &PushThreadOptions::default());
        assert!(tm.converged, "residual {}", tm.residual);
        assert_eq!(tm.shard_pushes.len(), 1);
        assert_eq!(tm.fragments_sent, vec![0]);
    }

    #[test]
    fn threaded_push_topk_stop_is_sound_after_exact_recheck() {
        let g = web(3_000, 74);
        let goal = TopKGoal { k: 16, order: false };
        let mut sp = ShardedPush::new(&g, 0.85, 4);
        let mut tracker = TopKTracker::new(goal);
        let opts = PushThreadOptions { tol: 1e-10, ..Default::default() };
        // the monitor's stop is tentative (asynchronous snapshots); the
        // helper owns the run -> exact check -> resume protocol
        let out = run_threaded_push_certified(&g, &mut sp, &mut tracker, &opts);
        assert!(
            out.cert.set_certified,
            "power-law web must certify k=16 (converged: {})",
            out.converged
        );
        assert!((sp.mass() - 1.0).abs() < 1e-9, "mass {}", sp.mass());
        // soundness: the certified set is the true top-16
        let (xref, _) = crate::stream::power_method_f64(&g, 0.85, 1e-12, 10_000);
        let mut want = crate::pagerank::top_k_ids(&xref, 16);
        let mut got = out.cert.head.clone();
        want.sort_unstable();
        got.sort_unstable();
        assert_eq!(got, want, "certified head != converged reference top-16");
        // and the state remains a working solver after the early cut
        let st = sp.solve(&g, 1e-10, u64::MAX);
        assert!(st.converged);
    }

    #[test]
    fn threaded_push_timeout_leaves_exact_state() {
        let g = web(4_000, 73);
        let mut sp = ShardedPush::new(&g, 0.85, 4);
        // a timeout too short to converge: the run must come back
        // unconverged with a consistent (mass-conserving) state
        let opts = PushThreadOptions {
            tol: 1e-14,
            timeout: std::time::Duration::from_millis(30),
            ..Default::default()
        };
        let tm = run_threaded_push(&g, &mut sp, &opts);
        assert!((sp.mass() - 1.0).abs() < 1e-9, "mass {}", sp.mass());
        // finishing deterministically still reaches the fixed point
        let st = sp.solve(&g, 1e-10, u64::MAX);
        assert!(st.converged);
        let _ = tm;
    }
}
