//! Generic asynchronous fixed-point engine — eq. (5) of the paper.
//!
//! `x_{i}(t+1) = f_i(x_{1}(τ¹ᵢ(t)), …, x_{p}(τᵖᵢ(t)))` for `t ∈ Tⁱ`:
//! each unit of execution (UE) owns a block of the iterate, repeatedly
//! applies its block operator to its *local, possibly stale view* of
//! the global vector, and exchanges fragments over the simulated
//! cluster network. The same engine runs both computational kernels of
//! §4 — the normalization-free power kernel (6) and the linear-system
//! kernel (7) are both [`BlockOperator`]s — and both execution
//! disciplines of §3–§4:
//!
//! * [`Mode::Synchronous`]: barrier per iteration (eq. 4 semantics);
//! * [`Mode::Asynchronous`]: free-running UEs, non-blocking sends with
//!   cancellation windows, Figure-1 termination.
//!
//! The discrete-event simulation is deterministic given a seed, so
//! every Table-1/Table-2 number regenerates exactly.

mod engine;
mod operator;
pub mod threads;

pub use engine::{Mode, RunMetrics, RunSpec, SimEngine, StopRule};
pub use operator::{ArtifactBlockOp, BlockOperator, NativeBlockOp};
pub use threads::{
    run_threaded, run_threaded_push, run_threaded_push_certified, CertifiedRunOutcome,
    PushThreadMetrics, PushThreadOptions, StallInjection, StopCause, TermMode, ThreadRunMetrics,
    ThreadRunOptions,
};
