//! Block operators: the `f_i` of eq. (5).
//!
//! Two interchangeable implementations of the PageRank block update
//! (eq. 6):
//!
//! * [`NativeBlockOp`] — rust CSR SpMV (the scalable host path);
//! * [`ArtifactBlockOp`] — the AOT-compiled Pallas kernel via PJRT
//!   (`runtime::PagerankStepExe`), exercising the full three-layer
//!   stack from the hot loop.
//!
//! Integration tests assert both produce the same iterates.

use std::sync::Arc;

use crate::graph::EllBlock;
use crate::pagerank::PagerankProblem;
use crate::runtime::{PagerankStepExe, StepBuffers};
use crate::Result;

/// The distributed operator component executing at one UE.
///
/// Not `Send`: the DES engine is single-threaded (determinism is a
/// design goal — DESIGN.md §3) and the PJRT executable handle is not
/// thread-safe to share anyway.
pub trait BlockOperator {
    /// Row range [lo, hi) this operator owns.
    fn rows(&self) -> (usize, usize);

    /// Apply one block update given the full (stale) snapshot `x`;
    /// write the new block into `out` (len hi-lo) and return the local
    /// L1 residual `‖out − x[lo..hi]‖₁`.
    fn update(&mut self, x: &[f32], out: &mut [f32]) -> f32;

    /// Nonzeros in this block (drives simulated compute time).
    fn block_nnz(&self) -> usize;
}

/// Native CSR implementation.
pub struct NativeBlockOp {
    problem: Arc<PagerankProblem>,
    lo: usize,
    hi: usize,
    nnz: usize,
}

impl NativeBlockOp {
    pub fn new(problem: Arc<PagerankProblem>, lo: usize, hi: usize) -> Self {
        let nnz = (lo..hi).map(|i| problem.csr.row_len(i)).sum();
        NativeBlockOp { problem, lo, hi, nnz }
    }
}

impl BlockOperator for NativeBlockOp {
    fn rows(&self) -> (usize, usize) {
        (self.lo, self.hi)
    }

    fn update(&mut self, x: &[f32], out: &mut [f32]) -> f32 {
        self.problem.apply_google_range(x, self.lo, self.hi, out);
        crate::pagerank::l1_diff(out, &x[self.lo..self.hi])
    }

    fn block_nnz(&self) -> usize {
        self.nnz
    }
}

/// PJRT-artifact implementation (L1 Pallas kernel via the L2 model).
///
/// The kernel computes `α·spmv + dang + bias` per *virtual* row (long
/// rows are split, DESIGN.md §Hardware-Adaptation); the host folds
/// virtual rows and subtracts the per-extra-virtual-row dang/bias
/// over-count, then computes the logical residual. When no row is
/// split the kernel output is used as-is.
pub struct ArtifactBlockOp {
    problem: Arc<PagerankProblem>,
    block: EllBlock,
    exe: PagerankStepExe,
    buf: StepBuffers,
    /// extra virtual rows per logical row (vrows_i - 1).
    extra_vrows: Vec<u32>,
    any_split: bool,
    /// scratch for virtual-row outputs folding
    folded: Vec<f32>,
    nnz: usize,
}

impl ArtifactBlockOp {
    /// Build over rows [lo, hi) with ELL width `width`, executing on
    /// `engine`'s artifacts.
    pub fn new(
        engine: &crate::runtime::Engine,
        problem: Arc<PagerankProblem>,
        lo: usize,
        hi: usize,
        width: usize,
    ) -> Result<Self> {
        let block = EllBlock::new(&problem.csr, lo, hi, width);
        let vrows = block.ell.virtual_rows();
        let mut exe = engine.pagerank_step(problem.n(), vrows, width)?;
        let mut buf = exe.buffers();
        // fixed matrix slots
        let cols: Vec<u32> = block.ell.cols().to_vec();
        exe.load_matrix(&mut buf, block.ell.vals(), &cols);
        buf.alpha = [problem.alpha];
        // per-virtual-row bias: only the first virtual row of each
        // logical row carries the teleport bias
        let mut extra_vrows = vec![0u32; hi - lo];
        let mut seen = vec![false; hi - lo];
        let bias_logical = problem.bias_range(lo, hi);
        for (v, &owner) in block.ell.owner().iter().enumerate() {
            if seen[owner as usize] {
                extra_vrows[owner as usize] += 1;
            } else {
                seen[owner as usize] = true;
                buf.bias[v] = bias_logical[owner as usize];
            }
        }
        let any_split = extra_vrows.iter().any(|&e| e > 0);
        let nnz = (lo..hi).map(|i| problem.csr.row_len(i)).sum();
        Ok(ArtifactBlockOp {
            problem,
            block,
            exe,
            buf,
            extra_vrows,
            any_split,
            folded: vec![0.0; hi - lo],
            nnz,
        })
    }

    pub fn bucket_name(&self) -> String {
        self.exe.bucket().name.clone()
    }
}

impl BlockOperator for ArtifactBlockOp {
    fn rows(&self) -> (usize, usize) {
        (self.block.row_lo, self.block.row_hi)
    }

    fn update(&mut self, x: &[f32], out: &mut [f32]) -> f32 {
        let (lo, hi) = (self.block.row_lo, self.block.row_hi);
        debug_assert_eq!(out.len(), hi - lo);
        // refresh dynamic inputs
        self.buf.x[..x.len()].copy_from_slice(x);
        self.buf.dang = [self.problem.dangling_term(x)];
        // xold is only used by the kernel's residual, which we discard
        // in split mode; keep it coherent anyway for the no-split path.
        let vrows = self.block.ell.virtual_rows();
        let (y, _kernel_resid) = self
            .exe
            .step(&mut self.buf)
            .expect("artifact execution failed mid-run");
        debug_assert_eq!(y.len(), vrows);
        if self.any_split {
            self.folded.iter_mut().for_each(|v| *v = 0.0);
            self.block.ell.fold_virtual(&y, &mut self.folded);
            let dang = self.buf.dang[0];
            for (o, &extra) in self.folded.iter_mut().zip(&self.extra_vrows) {
                *o -= dang * extra as f32;
            }
            out.copy_from_slice(&self.folded);
        } else {
            out.copy_from_slice(&y);
        }
        crate::pagerank::l1_diff(out, &x[lo..hi])
    }

    fn block_nnz(&self) -> usize {
        self.nnz
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generators, Csr};
    #[cfg(feature = "xla")]
    use crate::runtime::Engine;

    fn problem(n: usize, seed: u64) -> Arc<PagerankProblem> {
        let el = generators::power_law_web(&generators::WebParams::scaled(n), seed);
        Arc::new(PagerankProblem::new(Csr::from_edgelist(&el).unwrap(), 0.85))
    }

    #[test]
    fn native_update_matches_apply_google() {
        let p = problem(500, 1);
        let mut op = NativeBlockOp::new(p.clone(), 100, 300);
        assert_eq!(op.rows(), (100, 300));
        assert!(op.block_nnz() > 0);
        let x = p.uniform_start();
        let mut out = vec![0.0; 200];
        let r = op.update(&x, &mut out);
        let mut want = vec![0.0; p.n()];
        p.apply_google(&x, &mut want);
        assert_eq!(&out[..], &want[100..300]);
        assert!(r > 0.0);
    }

    // The artifact tests need the real PJRT engine (`--features xla`
    // plus `make artifacts`); the offline default build compiles the
    // stub engine, which cannot execute kernels.
    #[cfg(feature = "xla")]
    #[test]
    fn artifact_matches_native() {
        let eng = Engine::new(crate::runtime::default_artifacts_dir())
            .expect("run `make artifacts`");
        let p = problem(800, 2);
        let (lo, hi) = (200, 600);
        let mut native = NativeBlockOp::new(p.clone(), lo, hi);
        // width 4 forces virtual-row splitting on heavy rows
        let mut art = ArtifactBlockOp::new(&eng, p.clone(), lo, hi, 4).unwrap();
        let x = p.uniform_start();
        let mut a = vec![0.0; hi - lo];
        let mut b = vec![0.0; hi - lo];
        let ra = native.update(&x, &mut a);
        let rb = art.update(&x, &mut b);
        for (i, (u, v)) in a.iter().zip(&b).enumerate() {
            assert!((u - v).abs() < 1e-5, "row {i}: native {u} vs artifact {v}");
        }
        assert!((ra - rb).abs() < 1e-4, "resid {ra} vs {rb}");
    }

    #[cfg(feature = "xla")]
    #[test]
    fn artifact_matches_native_over_iterations() {
        let eng = Engine::new(crate::runtime::default_artifacts_dir())
            .expect("run `make artifacts`");
        let p = problem(600, 3);
        let n = p.n();
        let mut native = NativeBlockOp::new(p.clone(), 0, n);
        let mut art = ArtifactBlockOp::new(&eng, p.clone(), 0, n, 8).unwrap();
        let mut xa = p.uniform_start();
        let mut xb = p.uniform_start();
        let mut outa = vec![0.0; n];
        let mut outb = vec![0.0; n];
        for it in 0..10 {
            native.update(&xa, &mut outa);
            art.update(&xb, &mut outb);
            xa.copy_from_slice(&outa);
            xb.copy_from_slice(&outb);
            let d = crate::pagerank::l1_diff(&xa, &xb);
            assert!(d < 1e-4, "iter {it}: drift {d}");
        }
    }
}
