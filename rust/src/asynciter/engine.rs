//! The discrete-event simulation engine: UEs, fragment exchange over
//! the shared medium, the Figure-1 termination protocol, and metrics.
//!
//! One [`SimEngine::run`] executes one experiment: p computing UEs plus
//! one monitor UE on the simulated cluster ([`crate::simnet`]),
//! iterating a partitioned [`BlockOperator`] either synchronously
//! (barrier per round) or asynchronously (free-running, Figure-1
//! termination). Everything the paper measures falls out of the run:
//! Table 1 (iteration counts, completion-time ranges), Table 2 (the
//! completed-imports matrix), §5.2's achieved global residual, and
//! §6's cancellation/buffer statistics.

use crate::pagerank::PagerankProblem;
use crate::simnet::{ClusterProfile, EventQueue, SendOutcome, SharedMedium, Topology, VirtualTime};
use crate::termination::{MonitorTermination, TermMsg, WorkerTermination};
use crate::util::Rng;

use super::operator::BlockOperator;

/// Execution discipline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// Barrier per iteration: UE starts round t+1 only after importing
    /// every peer's round-t fragment (message-passing BSP, §3).
    Synchronous,
    /// Free-running UEs with stale views (§4).
    Asynchronous,
}

/// When to stop the run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum StopRule {
    /// The paper's protocol: local threshold + Figure-1 monitor with
    /// the given pcMax at both worker and monitor sides (Table 1 used
    /// pcMax = 1 on both).
    LocalProtocol { tol: f32, pc_max_worker: u32, pc_max_monitor: u32 },
    /// Omniscient global threshold on the TRUE assembled residual
    /// ‖Gx−x‖₁ (the §5.2 / G2 race). Checked after every UE update.
    GlobalThreshold { tol: f32 },
}

/// One experiment specification.
#[derive(Debug, Clone)]
pub struct RunSpec {
    pub mode: Mode,
    pub stop: StopRule,
    /// Adaptive per-peer rate control (§6 future work): double a
    /// peer's send period on cancellation, decay it back on success.
    pub adaptive: bool,
    /// Simulation seed (jitter streams).
    pub seed: u64,
    /// Safety cap on total UE iterations.
    pub max_total_iters: u64,
}

impl RunSpec {
    /// Table-1 configuration (tol 1e-6, pcMax 1/1).
    pub fn paper_table1(mode: Mode) -> RunSpec {
        RunSpec {
            mode,
            stop: StopRule::LocalProtocol { tol: 1e-6, pc_max_worker: 1, pc_max_monitor: 1 },
            adaptive: false,
            seed: 42,
            max_total_iters: 2_000_000,
        }
    }
}

/// Everything measured during a run.
#[derive(Debug, Clone)]
pub struct RunMetrics {
    pub mode: Mode,
    pub p: usize,
    /// Local iteration count per UE at stop.
    pub iters: Vec<u64>,
    /// Per-UE time of final local convergence — the paper's
    /// [t_min, t_max] (for sync: the stopping barrier time).
    pub finish_times: Vec<f64>,
    /// Virtual time at which the whole run ended.
    pub total_time: f64,
    /// `imports[receiver][sender]`: fragments actually imported;
    /// diagonal = locally computed fragments (Table 2).
    pub imports: Vec<Vec<u64>>,
    /// Fragment sends attempted / cancelled (per sender).
    pub sends_attempted: Vec<u64>,
    pub sends_cancelled: Vec<u64>,
    /// True global residual ‖Gx−x‖₁ of the assembled final vector.
    pub final_global_residual: f32,
    /// The assembled final iterate.
    pub x: Vec<f32>,
    /// Wire statistics (backlog pressure of §6).
    pub wire_sent: u64,
    pub wire_cancelled: u64,
    pub wire_queue_wait: f64,
    /// Completed-imports percentage per receiver (Table 2 last column).
    pub import_pct: Vec<f64>,
}

impl RunMetrics {
    pub fn iters_range(&self) -> (u64, u64) {
        (
            self.iters.iter().copied().min().unwrap_or(0),
            self.iters.iter().copied().max().unwrap_or(0),
        )
    }

    pub fn time_range(&self) -> (f64, f64) {
        let mut lo = f64::INFINITY;
        let mut hi = 0.0f64;
        for &t in &self.finish_times {
            lo = lo.min(t);
            hi = hi.max(t);
        }
        (lo, hi)
    }

    /// The paper's ⟨speedUp⟩: sync time over the mean of async extreme
    /// completion times.
    pub fn speedup_vs(&self, sync_time: f64) -> f64 {
        let (lo, hi) = self.time_range();
        sync_time / ((lo + hi) / 2.0)
    }
}

#[derive(Debug)]
enum Event {
    /// UE finished one local iteration.
    ComputeDone { ue: usize },
    /// A fragment bundle arrives: the sender's own block plus (on
    /// non-clique topologies) relayed copies of other UEs' blocks —
    /// the gossip scheme that makes tree/star topologies complete.
    /// Each entry is (origin UE, origin iteration, block values);
    /// payloads are Arc-shared — a p=6 async Stanford run would
    /// otherwise memcpy ~1.4 GB of fragment clones (§Perf).
    Fragment { src: usize, dst: usize, bundle: Vec<(usize, u64, std::sync::Arc<Vec<f32>>)> },
    /// Control message to the monitor (CONVERGE/DIVERGE) or back (STOP).
    Control { src: usize, dst: usize, msg: TermMsg },
}

struct UeState {
    lo: usize,
    hi: usize,
    /// Full-length local (stale) view of the iterate.
    x: Vec<f32>,
    /// Delivered, not-yet-imported fragments per ORIGIN: round -> data.
    frags: Vec<std::collections::BTreeMap<u64, std::sync::Arc<Vec<f32>>>>,
    /// Freshest known copy per origin (iteration tag + data), for
    /// relaying on non-clique topologies. Own slot updated on compute.
    known: Vec<Option<(u64, std::sync::Arc<Vec<f32>>)>>,
    local_iter: u64,
    term: WorkerTermination,
    stopped: bool,
    computing: bool,
    /// Highest round imported from each peer (sync barrier tracking).
    recv_round: Vec<u64>,
    /// Imports matrix row (Table 2).
    imports: Vec<u64>,
    sends_attempted: u64,
    sends_cancelled: u64,
    /// Virtual time when this UE last entered local convergence.
    converged_at: f64,
    rng: Rng,
    /// Scratch block output.
    out: Vec<f32>,
    /// Iterations since last send, per peer.
    since_send: Vec<u32>,
    /// Current per-peer send periods (adaptive).
    period: Vec<u32>,
    /// Residual of the most recent local iteration.
    last_resid: f32,
}

/// The simulation engine.
pub struct SimEngine<'a> {
    profile: &'a ClusterProfile,
    problem: &'a PagerankProblem,
}

impl<'a> SimEngine<'a> {
    pub fn new(profile: &'a ClusterProfile, problem: &'a PagerankProblem) -> Self {
        SimEngine { profile, problem }
    }

    /// Run one experiment over per-UE block operators (contiguously
    /// tiling [0, n) in order).
    pub fn run(&self, ops: &mut [Box<dyn BlockOperator>], spec: &RunSpec) -> RunMetrics {
        let p = ops.len();
        assert_eq!(p, self.profile.p(), "ops vs profile UE count");
        assert!(p >= 1);
        if spec.mode == Mode::Synchronous {
            assert_eq!(
                self.profile.topology,
                Topology::Clique,
                "synchronous mode requires the paper's all-to-all scheme"
            );
        }
        let n = self.problem.n();
        let monitor_id = p;
        let blocks: Vec<(usize, usize)> = ops.iter().map(|o| o.rows()).collect();
        assert_eq!(blocks[0].0, 0);
        assert_eq!(blocks[p - 1].1, n);
        for w in 0..p - 1 {
            assert_eq!(blocks[w].1, blocks[w + 1].0, "blocks must tile [0,n)");
        }

        let mut master_rng = Rng::new(spec.seed);
        let mut q: EventQueue<Event> = EventQueue::new();
        let mut medium = SharedMedium::new(
            self.profile.bandwidth,
            self.profile.latency,
            match spec.mode {
                Mode::Synchronous => None, // sync blocks, never cancels
                Mode::Asynchronous => self.profile.cancel_window,
            },
        );

        let x0 = self.problem.uniform_start();
        let mut ues: Vec<UeState> = (0..p)
            .map(|i| UeState {
                lo: blocks[i].0,
                hi: blocks[i].1,
                x: x0.clone(),
                frags: vec![std::collections::BTreeMap::new(); p],
                known: vec![None; p],
                local_iter: 0,
                term: WorkerTermination::new(match spec.stop {
                    StopRule::LocalProtocol { pc_max_worker, .. } => pc_max_worker,
                    _ => 1,
                }),
                stopped: false,
                computing: true, // first iteration scheduled below
                recv_round: vec![0; p],
                imports: vec![0; p],
                sends_attempted: 0,
                sends_cancelled: 0,
                converged_at: 0.0,
                rng: master_rng.fork(i as u64 + 1),
                out: vec![0.0; blocks[i].1 - blocks[i].0],
                since_send: vec![0; p],
                period: vec![1; p],
                last_resid: f32::INFINITY,
            })
            .collect();

        let mut monitor = MonitorTermination::new(
            p,
            match spec.stop {
                StopRule::LocalProtocol { pc_max_monitor, .. } => pc_max_monitor,
                _ => 1,
            },
        );

        // omniscient views
        let mut x_true = x0.clone();
        let mut scratch = vec![0.0f32; n];
        let mut global_stop_at: Option<f64> = None;

        // sync-mode round residual bookkeeping: resid sum + count per round
        let mut round_resid: Vec<(f32, usize)> = Vec::new();
        let mut sync_stop_round: Option<u64> = None;

        for (i, op) in ops.iter().enumerate() {
            let dt = self.compute_duration(i, op.block_nnz(), &mut ues[i].rng);
            q.push(VirtualTime(dt), Event::ComputeDone { ue: i });
        }

        let mut total_iters: u64 = 0;

        while let Some((now, ev)) = q.pop() {
            match ev {
                Event::ComputeDone { ue } => {
                    if ues[ue].stopped {
                        continue;
                    }
                    total_iters += 1;
                    assert!(
                        total_iters <= spec.max_total_iters,
                        "run did not terminate within {} iterations (mode {:?})",
                        spec.max_total_iters,
                        spec.mode
                    );

                    let mut imported_now = 0usize;
                    if spec.mode == Mode::Asynchronous {
                        imported_now = Self::import_newest(&mut ues[ue], &blocks);
                    }

                    // ---- one local update ----
                    let resid;
                    let out_snapshot: std::sync::Arc<Vec<f32>>;
                    {
                        let st = &mut ues[ue];
                        resid = ops[ue].update(&st.x, &mut st.out);
                        let (lo, hi) = (st.lo, st.hi);
                        st.x[lo..hi].copy_from_slice(&st.out);
                        st.local_iter += 1;
                        st.imports[ue] += 1; // Table-2 diagonal
                        st.computing = false;
                        st.last_resid = resid;
                        out_snapshot = std::sync::Arc::new(st.out.clone());
                        st.known[ue] = Some((st.local_iter, out_snapshot.clone()));
                        x_true[lo..hi].copy_from_slice(&st.out);
                    }

                    let tol = match spec.stop {
                        StopRule::LocalProtocol { tol, .. } => tol,
                        StopRule::GlobalThreshold { tol } => tol,
                    };
                    if resid < tol {
                        ues[ue].converged_at = now.secs();
                    }

                    // ---- Figure-1 worker side ----
                    if let StopRule::LocalProtocol { .. } = spec.stop {
                        if spec.mode == Mode::Asynchronous {
                            if let Some(msg) = ues[ue].term.on_iteration(resid < tol) {
                                self.send_control(
                                    &mut q, &mut medium, now, ue, monitor_id, msg,
                                );
                            }
                        }
                    }

                    // ---- global-threshold oracle ----
                    if let StopRule::GlobalThreshold { tol } = spec.stop {
                        self.problem.apply_google(&x_true, &mut scratch);
                        let g = crate::pagerank::l1_diff(&scratch, &x_true);
                        if g < tol {
                            global_stop_at = Some(now.secs());
                            for u in ues.iter_mut() {
                                u.stopped = true;
                                if u.converged_at == 0.0 {
                                    u.converged_at = now.secs();
                                }
                            }
                            break;
                        }
                    }

                    // ---- sync round residual bookkeeping ----
                    if spec.mode == Mode::Synchronous {
                        let round = ues[ue].local_iter as usize - 1;
                        if round_resid.len() <= round {
                            round_resid.resize(round + 1, (0.0, 0));
                        }
                        round_resid[round].0 += resid;
                        round_resid[round].1 += 1;
                        {
                            let (StopRule::LocalProtocol { tol, .. }
                            | StopRule::GlobalThreshold { tol }) = spec.stop;
                            if round_resid[round].1 == p
                                && round_resid[round].0 < tol
                                && sync_stop_round.is_none()
                            {
                                // the sync algorithm detects global
                                // convergence at this barrier
                                sync_stop_round = Some(round as u64 + 1);
                            }
                        }
                    }

                    // ---- fragment sends ----
                    // rotate send order each iteration: a fixed order
                    // would systematically starve high-id receivers on
                    // the shared wire (the paper's thread pool had no
                    // deterministic order either)
                    let mut nbrs = self.profile.topology.neighbors(ue, p);
                    if !nbrs.is_empty() {
                        let rot = (ues[ue].local_iter as usize + ue) % nbrs.len();
                        nbrs.rotate_left(rot);
                    }
                    match spec.mode {
                        Mode::Synchronous => {
                            for dst in nbrs {
                                ues[ue].sends_attempted += 1;
                                match medium.send(now, self.frag_bytes(ue, &blocks)) {
                                    SendOutcome::Delivered { deliver_at } => q.push(
                                        deliver_at,
                                        Event::Fragment {
                                            src: ue,
                                            dst,
                                            bundle: vec![(
                                                ue,
                                                ues[ue].local_iter,
                                                out_snapshot.clone(),
                                            )],
                                        },
                                    ),
                                    SendOutcome::Cancelled => unreachable!(),
                                }
                            }
                        }
                        Mode::Asynchronous => {
                            let mut delivered_sends = 0usize;
                            for dst in nbrs {
                                let st = &mut ues[ue];
                                st.since_send[dst] += 1;
                                if st.since_send[dst] < st.period[dst] {
                                    continue;
                                }
                                st.since_send[dst] = 0;
                                st.sends_attempted += 1;
                                // own block always; on non-clique
                                // topologies also relay the freshest
                                // known copy of every other block so
                                // information crosses the tree/star
                                let mut bundle =
                                    vec![(ue, st.local_iter, out_snapshot.clone())];
                                if self.profile.topology != Topology::Clique {
                                    for (o, slot) in st.known.iter().enumerate() {
                                        if o == ue || o == dst {
                                            continue;
                                        }
                                        if let Some((it, data)) = slot {
                                            bundle.push((o, *it, data.clone()));
                                        }
                                    }
                                }
                                let bytes: f64 = bundle
                                    .iter()
                                    .map(|(_, _, d)| {
                                        self.profile.fragment_bytes(d.len())
                                    })
                                    .sum();
                                match medium.send(now, bytes) {
                                    SendOutcome::Delivered { deliver_at } => {
                                        delivered_sends += 1;
                                        if spec.adaptive && st.period[dst] > 1 {
                                            st.period[dst] -= 1;
                                        }
                                        q.push(
                                            deliver_at,
                                            Event::Fragment { src: ue, dst, bundle },
                                        );
                                    }
                                    SendOutcome::Cancelled => {
                                        st.sends_cancelled += 1;
                                        if spec.adaptive {
                                            st.period[dst] = (st.period[dst] * 2).min(16);
                                        }
                                    }
                                }
                            }
                            // next iteration pays for the fragments just
                            // merged (deserialization) and the sends just
                            // submitted (serialization thread work)
                            let dt = self
                                .compute_duration(ue, ops[ue].block_nnz(), &mut ues[ue].rng)
                                + imported_now as f64
                                    * self.profile.nodes[ue].secs_per_import
                                + delivered_sends as f64
                                    * self.profile.nodes[ue].secs_per_send;
                            ues[ue].computing = true;
                            q.push(now.after(dt), Event::ComputeDone { ue });
                        }
                    }

                    if spec.mode == Mode::Synchronous {
                        self.advance_sync(&mut q, now, &mut ues, ops, p, sync_stop_round);
                    }
                }

                Event::Fragment { src, dst, bundle } => {
                    if ues[dst].stopped {
                        continue;
                    }
                    let _ = src;
                    for (origin, iter, data) in bundle {
                        if origin == dst {
                            continue;
                        }
                        let st = &mut ues[dst];
                        // Table 2 counts fragments of `origin`'s data
                        // actually received (relays included)
                        st.imports[origin] += 1;
                        st.recv_round[origin] = st.recv_round[origin].max(iter);
                        // refresh the relay store (Arc clone, no copy)
                        if st.known[origin]
                            .as_ref()
                            .map(|(it, _)| *it < iter)
                            .unwrap_or(true)
                        {
                            st.known[origin] = Some((iter, data.clone()));
                        }
                        st.frags[origin].insert(iter, data);
                    }
                    if spec.mode == Mode::Synchronous {
                        self.advance_sync(&mut q, now, &mut ues, ops, p, sync_stop_round);
                    }
                }

                Event::Control { src, dst, msg } => {
                    if dst == monitor_id {
                        if monitor.on_message(src, msg) {
                            for w in 0..p {
                                self.send_control(
                                    &mut q,
                                    &mut medium,
                                    now,
                                    monitor_id,
                                    w,
                                    TermMsg::Stop,
                                );
                            }
                        }
                    } else {
                        debug_assert_eq!(msg, TermMsg::Stop);
                        ues[dst].stopped = true;
                    }
                }
            }

            if ues.iter().all(|u| u.stopped) {
                break;
            }
        }

        let end_time = global_stop_at.unwrap_or_else(|| q.now().secs());

        self.problem.apply_google(&x_true, &mut scratch);
        let final_res = crate::pagerank::l1_diff(&scratch, &x_true);

        let import_pct: Vec<f64> = (0..p)
            .map(|i| {
                let own = ues[i].imports[i].max(1) as f64;
                let peers: Vec<f64> = (0..p)
                    .filter(|&j| j != i)
                    .map(|j| ues[i].imports[j] as f64 / own * 100.0)
                    .collect();
                if peers.is_empty() {
                    100.0
                } else {
                    peers.iter().sum::<f64>() / peers.len() as f64
                }
            })
            .collect();

        RunMetrics {
            mode: spec.mode,
            p,
            iters: ues.iter().map(|u| u.local_iter).collect(),
            finish_times: ues
                .iter()
                .map(|u| if u.converged_at > 0.0 { u.converged_at } else { end_time })
                .collect(),
            total_time: end_time,
            imports: ues.iter().map(|u| u.imports.clone()).collect(),
            sends_attempted: ues.iter().map(|u| u.sends_attempted).collect(),
            sends_cancelled: ues.iter().map(|u| u.sends_cancelled).collect(),
            final_global_residual: final_res,
            x: x_true,
            wire_sent: medium.sent,
            wire_cancelled: medium.cancelled,
            wire_queue_wait: medium.queue_wait,
            import_pct,
        }
    }

    fn frag_bytes(&self, ue: usize, blocks: &[(usize, usize)]) -> f64 {
        self.profile.fragment_bytes(blocks[ue].1 - blocks[ue].0)
    }

    fn send_control(
        &self,
        q: &mut EventQueue<Event>,
        medium: &mut SharedMedium,
        now: VirtualTime,
        src: usize,
        dst: usize,
        msg: TermMsg,
    ) {
        match medium.send(now, self.profile.control_bytes) {
            SendOutcome::Delivered { deliver_at } => {
                q.push(deliver_at, Event::Control { src, dst, msg })
            }
            SendOutcome::Cancelled => {
                // control messages tolerate delay, not loss: retry after
                // one cancellation window
                let w = self.profile.cancel_window.unwrap_or(0.0);
                q.push(now.after(w + self.profile.latency), Event::Control { src, dst, msg });
            }
        }
    }

    /// Sync barrier: start round t+1 on every UE that has finished
    /// round t and imported every peer's round-t fragment; stop UEs at
    /// the barrier where global convergence was detected.
    #[allow(clippy::too_many_arguments)]
    fn advance_sync(
        &self,
        q: &mut EventQueue<Event>,
        now: VirtualTime,
        ues: &mut [UeState],
        ops: &mut [Box<dyn BlockOperator>],
        p: usize,
        sync_stop_round: Option<u64>,
    ) {
        let blocks = ues_blocks(ues, p);
        for ue in 0..p {
            if ues[ue].stopped || ues[ue].computing {
                continue;
            }
            let t = ues[ue].local_iter;
            // convergence barrier reached?
            if let Some(stop_t) = sync_stop_round {
                if t >= stop_t {
                    ues[ue].stopped = true;
                    ues[ue].converged_at = now.secs();
                    continue;
                }
            }
            // BSP: round t+1 may start only with EVERY peer's round-t
            // fragment, and must use exactly those values (a faster
            // peer's round-t+1 fragment must NOT leak in).
            let ready = (0..p).all(|j| j == ue || ues[ue].frags[j].contains_key(&t));
            if ready {
                for j in 0..p {
                    if j == ue {
                        continue;
                    }
                    let data = ues[ue].frags[j].get(&t).cloned().unwrap();
                    let (lo, hi) = blocks[j];
                    ues[ue].x[lo..hi].copy_from_slice(&data);
                    // drop fragments at or below the consumed round
                    ues[ue].frags[j].retain(|&r, _| r > t);
                }
                let dt = self.compute_duration(ue, ops[ue].block_nnz(), &mut ues[ue].rng)
                    + (p - 1) as f64
                        * (self.profile.nodes[ue].secs_per_import
                            + self.profile.nodes[ue].secs_per_send);
                ues[ue].computing = true;
                q.push(now.after(dt), Event::ComputeDone { ue });
            }
        }
    }

    fn compute_duration(&self, ue: usize, nnz: usize, rng: &mut Rng) -> f64 {
        let base = self.profile.compute_time(ue, nnz);
        let j = self.profile.nodes[ue].jitter;
        base * (1.0 + (rng.f64() * 2.0 - 1.0) * j)
    }

    /// Asynchronous import: paste the newest delivered fragment from
    /// each sender into the local view (older ones are superseded) and
    /// clear the backlog. Import counting happened at delivery time —
    /// Table 2 counts fragments that actually arrived.
    fn import_newest(st: &mut UeState, blocks: &[(usize, usize)]) -> usize {
        let mut imported = 0;
        for src in 0..blocks.len() {
            if let Some((_, data)) = st.frags[src].iter().next_back() {
                let (lo, hi) = blocks[src];
                debug_assert_eq!(data.len(), hi - lo);
                st.x[lo..hi].copy_from_slice(data);
                imported += 1;
            }
            st.frags[src].clear();
        }
        imported
    }
}

/// Helper: rebuild the partition table from UE states (blocks are fixed
/// at construction; this avoids borrowing `blocks` through `self`).
fn ues_blocks(ues: &[UeState], p: usize) -> Vec<(usize, usize)> {
    (0..p).map(|i| (ues[i].lo, ues[i].hi)).collect()
}
