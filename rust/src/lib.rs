//! # asyncpr — Asynchronous Iterative PageRank
//!
//! A production-grade reproduction of *"Asynchronous iterative
//! computations with Web information retrieval structures: The PageRank
//! case"* (Kollias, Gallopoulos & Szyld, 2006).
//!
//! The crate is the **Layer-3 coordinator** of a three-layer stack:
//!
//! * **L1** — Pallas ELLPACK SpMV / fused PageRank-step kernels
//!   (`python/compile/kernels/`, build time only);
//! * **L2** — the JAX block-update model (`python/compile/model.py`),
//!   AOT-lowered to HLO-text artifacts (`artifacts/*.hlo.txt`);
//! * **L3** — this crate: units of execution (UEs), the simulated
//!   cluster network, the Figure-1 termination-detection protocol, the
//!   partitioner, metrics, and the CLI. The hot path executes either
//!   the PJRT artifacts ([`runtime`]) or the native SpMV
//!   ([`pagerank`]); Python never runs at request time.
//!
//! ## Module map
//!
//! The layered tour — data-flow diagram, the ownership/migration story
//! behind intra-epoch work stealing, and the invariants to know before
//! editing — lives in `ARCHITECTURE.md` at the repo root (see also
//! DESIGN.md §4); the short version:
//!
//! | module | role |
//! |---|---|
//! | [`graph`] | web-graph structures (CSR/ELL), generators, update streams, IO |
//! | [`pagerank`] | PageRank operators, sync baselines, residuals, ranking metrics |
//! | [`stream`] | evolving-graph workload: `DeltaGraph` epochs + push-based incremental PageRank (single-queue + sharded parallel, with intra-epoch work stealing) |
//! | [`simnet`] | virtual-time discrete-event cluster/network simulator |
//! | [`asynciter`] | generic asynchronous fixed-point engine (eq. 5) |
//! | [`termination`] | Figure-1 centralized protocol + global oracle + tree detector |
//! | [`net`] | process-boundary transport: wire codec, throttled loopback + socket tiers, fault injection |
//! | [`coordinator`] | partitioning, run orchestration, adaptive comms, reports |
//! | [`runtime`] | PJRT engine executing the AOT artifacts (stubbed without `--features xla`) |
//! | [`metrics`] | Table-1/Table-2 collectors, stream epoch reports, traces, emitters |
//! | [`obs`] | async progress telemetry: per-shard event rings, residual-decay sampling, Chrome-trace export |
//! | [`config`] | TOML experiment configs and presets |

pub mod asynciter;
pub mod config;
pub mod coordinator;
pub mod graph;
pub mod metrics;
pub mod net;
pub mod obs;
pub mod pagerank;
pub mod runtime;
pub mod simnet;
pub mod stream;
pub mod termination;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
