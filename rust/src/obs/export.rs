//! Exporters: Chrome trace-event JSON (loadable in `chrome://tracing`
//! and Perfetto) and a compact residual-decay series.
//!
//! The Chrome format is the object form `{"traceEvents": [...]}` —
//! viewers ignore unknown top-level keys, so the sample series and
//! collector metadata ride in the same file under `"series"` /
//! `"sampleIntervalUs"` without breaking loadability. Tracks map to
//! Chrome thread ids: shard `i` → `tid i`, monitor → `tid = shard
//! count`. Events are emitted as instants (`"ph": "i"`) on their
//! track; samples double as counter events (`"ph": "C"`) so the
//! residual decay renders as per-shard counter graphs.

use std::collections::BTreeMap;

use super::collect::{Sample, TraceCollector};
use super::event::Event;
use crate::util::Json;

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    let mut m = BTreeMap::new();
    for (k, v) in pairs {
        m.insert(k.to_string(), v);
    }
    Json::Obj(m)
}

fn thread_meta(tid: usize, name: &str) -> Json {
    obj(vec![
        ("name", Json::Str("thread_name".into())),
        ("ph", Json::Str("M".into())),
        ("pid", Json::Num(0.0)),
        ("tid", Json::Num(tid as f64)),
        ("args", obj(vec![("name", Json::Str(name.into()))])),
    ])
}

fn instant(tid: usize, ev: &Event) -> Json {
    obj(vec![
        ("name", Json::Str(ev.kind.name().into())),
        ("ph", Json::Str("i".into())),
        ("s", Json::Str("t".into())),
        ("pid", Json::Num(0.0)),
        ("tid", Json::Num(tid as f64)),
        ("ts", Json::Num(ev.t_us as f64)),
        ("args", obj(vec![("a", Json::Num(ev.a as f64)), ("v", Json::Num(ev.v))])),
    ])
}

fn counter(s: &Sample) -> Json {
    obj(vec![
        ("name", Json::Str(format!("shard{}", s.shard))),
        ("ph", Json::Str("C".into())),
        ("pid", Json::Num(0.0)),
        ("ts", Json::Num(s.t_us as f64)),
        (
            "args",
            obj(vec![
                ("residual", Json::Num(s.residual)),
                ("queued", Json::Num(s.queued)),
                ("pressure", Json::Num(s.pressure)),
            ]),
        ),
    ])
}

fn sample_row(s: &Sample) -> Json {
    obj(vec![
        ("t_us", Json::Num(s.t_us as f64)),
        ("shard", Json::Num(s.shard as f64)),
        ("residual", Json::Num(s.residual)),
        ("queued", Json::Num(s.queued)),
        ("in_flight", Json::Num(s.in_flight as f64)),
        ("pressure", Json::Num(s.pressure)),
    ])
}

impl TraceCollector {
    /// Render everything the collector holds as one Chrome-trace JSON
    /// document: per-track thread names, instant events, per-shard
    /// residual counters, and the raw sample series.
    pub fn to_chrome_json(&self) -> Json {
        let shards = self.shard_tracks();
        let mut events: Vec<Json> = Vec::new();
        for i in 0..shards {
            events.push(thread_meta(i, &format!("shard {i}")));
        }
        events.push(thread_meta(shards, "monitor"));
        for i in 0..shards {
            for ev in self.events_for(i) {
                events.push(instant(i, &ev));
            }
        }
        for ev in self.monitor_events() {
            events.push(instant(shards, &ev));
        }
        let samples = self.samples();
        for s in &samples {
            events.push(counter(s));
        }
        obj(vec![
            ("traceEvents", Json::Arr(events)),
            ("displayTimeUnit", Json::Str("ms".into())),
            ("sampleIntervalUs", Json::Num(self.sample_interval_us() as f64)),
            ("samplesDropped", Json::Num(self.samples_dropped() as f64)),
            ("series", Json::Arr(samples.iter().map(sample_row).collect())),
        ])
    }

    /// Just the residual-decay series (the `"series"` key above), for
    /// callers that want the time series without the event tracks.
    pub fn series_json(&self) -> Json {
        Json::Arr(self.samples().iter().map(sample_row).collect())
    }
}

/// Coarse Chrome trace for the simulator path (`repro run --trace`):
/// one complete event per UE spanning virtual time 0 → its finish
/// time, plus a run-level span. Virtual seconds map to trace
/// microseconds 1:1e6 so relative UE skew is visible.
pub fn run_trace_json(iters: &[u64], finish_times: &[f64], total_time: f64) -> Json {
    let mut events: Vec<Json> = Vec::new();
    for (i, (&it, &ft)) in iters.iter().zip(finish_times.iter()).enumerate() {
        events.push(thread_meta(i, &format!("UE {i}")));
        events.push(obj(vec![
            ("name", Json::Str(format!("UE {i} ({it} iters)"))),
            ("ph", Json::Str("X".into())),
            ("pid", Json::Num(0.0)),
            ("tid", Json::Num(i as f64)),
            ("ts", Json::Num(0.0)),
            ("dur", Json::Num(ft * 1e6)),
            ("args", obj(vec![("iters", Json::Num(it as f64))])),
        ]));
    }
    let mon = iters.len();
    events.push(thread_meta(mon, "run"));
    events.push(obj(vec![
        ("name", Json::Str("run".into())),
        ("ph", Json::Str("X".into())),
        ("pid", Json::Num(0.0)),
        ("tid", Json::Num(mon as f64)),
        ("ts", Json::Num(0.0)),
        ("dur", Json::Num(total_time * 1e6)),
        ("args", obj(vec![])),
    ]));
    obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::Str("ms".into())),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{EventKind, MONITOR_TRACK};

    #[test]
    fn chrome_export_roundtrips_and_carries_tracks() {
        let tr = TraceCollector::default();
        tr.record(0, EventKind::PushBatch, 128, 0.25);
        tr.record(1, EventKind::FragSend, 0, 3.0);
        tr.record(MONITOR_TRACK, EventKind::QuietWindow, 2, 1e-11);
        tr.push_sample(Sample {
            t_us: 42,
            shard: 0,
            residual: 0.5,
            queued: 0.5,
            in_flight: 1,
            pressure: 0.1,
        });
        let text = tr.to_chrome_json().to_string_compact();
        let parsed = Json::parse(&text).expect("exporter must emit valid JSON");
        let evs = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        // 3 thread metas (shard 0, shard 1, monitor) + 3 instants + 1 counter
        assert_eq!(evs.len(), 7);
        let metas: Vec<&str> = evs
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some("M"))
            .map(|e| e.get("args").unwrap().get("name").unwrap().as_str().unwrap())
            .collect();
        assert_eq!(metas, ["shard 0", "shard 1", "monitor"]);
        let series = parsed.get("series").unwrap().as_arr().unwrap();
        assert_eq!(series.len(), 1);
        assert_eq!(series[0].get("residual").unwrap().as_f64(), Some(0.5));
    }

    #[test]
    fn run_trace_emits_one_span_per_ue() {
        let j = run_trace_json(&[10, 20], &[0.5, 1.0], 1.0);
        let text = j.to_string_compact();
        let parsed = Json::parse(&text).unwrap();
        let evs = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        let spans: Vec<f64> = evs
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some("X"))
            .map(|e| e.get("dur").unwrap().as_f64().unwrap())
            .collect();
        assert_eq!(spans, [0.5e6, 1.0e6, 1.0e6]);
    }
}
