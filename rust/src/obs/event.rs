//! Typed progress events and the per-shard lock-free ring they land in.
//!
//! Design constraints, in order:
//!
//! 1. **Zero cost when disabled.** Nothing in this module is called
//!    from `push_row`/`drain` — instrumentation lives at round/batch
//!    granularity in the drivers, behind an `Option` check, so the
//!    per-push hot path carries no tracing code at all.
//! 2. **No locks on the recording path.** Each shard worker owns one
//!    [`EventRing`] and is its only writer (single-producer contract);
//!    the cursor is a relaxed-loaded / release-stored atomic, so a
//!    record is one slot write plus two uncontended atomic ops.
//! 3. **Overflow drops oldest, never blocks.** The ring keeps the most
//!    recent `cap` events; lifetime per-kind counters survive the
//!    overwrites, so drained totals stay exact even when the window
//!    does not.

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicU64, Ordering};

/// Number of event kinds (array sizes below key off this).
pub const KIND_COUNT: usize = 13;

/// The event taxonomy — one variant per observable step of the
/// asynchronous push protocol. Payload conventions (the `a`/`v` fields
/// of [`Event`]) are documented per variant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum EventKind {
    /// One drain round that performed work. `a` = pushes spent,
    /// `v` = the shard's materialized ‖r‖₁ after the batch.
    PushBatch = 0,
    /// A residual fragment was delivered (or handed to a channel).
    /// `a` = destination shard, `v` = entry count.
    FragSend = 1,
    /// A fragment met a full channel and was re-accumulated locally.
    /// `a` = destination shard, `v` = entry count.
    FragDefer = 2,
    /// A steal request left this (thief) shard. `a` = victim.
    StealRequest = 3,
    /// A steal grant left this (victim) shard. `a` = thief,
    /// `v` = rows granted.
    StealGrant = 4,
    /// Stolen rows returned home (epoch boundary). `a` = rows moved.
    Repatriate = 5,
    /// A worker round that neither pushed nor received.
    IdleRound = 6,
    /// A churn batch was injected into the live shards. `a` = epoch
    /// stamp, `v` = edges inserted + removed.
    EpochBegin = 7,
    /// A top-k certification check ran. `a` = 1 if it certified,
    /// `v` = the certificate margin (exact checks) or the merged
    /// frame count (tentative monitor checks).
    CertCheck = 8,
    /// The monitor observed a quiet sample (published residual under
    /// tol, nothing in flight). `a` = consecutive quiet count,
    /// `v` = the published residual total.
    QuietWindow = 9,
    /// A worker announced CONVERGE to the §4.2 termination monitor
    /// after `pc_max` persistent locally-converged rounds. `a` = the
    /// worker's persistence counter at the announce, `v` = its
    /// conservative local residual estimate.
    TermConverge = 10,
    /// A previously-announced worker left the converged state (fresh
    /// residual arrived or its own estimate rose) and retracted with
    /// DIVERGE. `a` = 1 when triggered by received mass, 0 when by the
    /// worker's own round; `v` = the local residual estimate for
    /// round-triggered retractions, 0 for mass-triggered ones (the
    /// estimate is not re-tallied until the round's drain).
    TermDiverge = 11,
    /// The monitor's persistence counter fired STOP: every worker's
    /// last protocol message was CONVERGE. `a` = protocol messages the
    /// monitor processed over the run, `v` = 0.
    TermStop = 12,
}

impl EventKind {
    /// All kinds, index-aligned with the counter arrays.
    pub const ALL: [EventKind; KIND_COUNT] = [
        EventKind::PushBatch,
        EventKind::FragSend,
        EventKind::FragDefer,
        EventKind::StealRequest,
        EventKind::StealGrant,
        EventKind::Repatriate,
        EventKind::IdleRound,
        EventKind::EpochBegin,
        EventKind::CertCheck,
        EventKind::QuietWindow,
        EventKind::TermConverge,
        EventKind::TermDiverge,
        EventKind::TermStop,
    ];

    /// Stable display name (Chrome-trace event name, summary column).
    pub fn name(self) -> &'static str {
        match self {
            EventKind::PushBatch => "PushBatch",
            EventKind::FragSend => "FragSend",
            EventKind::FragDefer => "FragDefer",
            EventKind::StealRequest => "StealRequest",
            EventKind::StealGrant => "StealGrant",
            EventKind::Repatriate => "Repatriate",
            EventKind::IdleRound => "IdleRound",
            EventKind::EpochBegin => "EpochBegin",
            EventKind::CertCheck => "CertCheck",
            EventKind::QuietWindow => "QuietWindow",
            EventKind::TermConverge => "TermConverge",
            EventKind::TermDiverge => "TermDiverge",
            EventKind::TermStop => "TermStop",
        }
    }
}

/// One timestamped typed event. `t_us` is microseconds since the
/// owning collector's epoch; `a` and `v` are kind-specific payloads
/// (see [`EventKind`]).
#[derive(Debug, Clone, Copy)]
pub struct Event {
    pub t_us: u64,
    pub kind: EventKind,
    pub a: u64,
    pub v: f64,
}

impl Default for Event {
    fn default() -> Event {
        Event { t_us: 0, kind: EventKind::PushBatch, a: 0, v: 0.0 }
    }
}

/// Lifetime per-kind event totals for one track — exact even after the
/// ring window overwrote old records.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct EventTotals {
    /// Events recorded per kind, indexed by `EventKind as usize`.
    pub counts: [u64; KIND_COUNT],
    /// Records overwritten by ring overflow (recorded − retained).
    pub dropped: u64,
}

impl EventTotals {
    pub fn get(&self, kind: EventKind) -> u64 {
        self.counts[kind as usize]
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }
}

/// Single-producer ring buffer of [`Event`]s with a relaxed-atomic
/// cursor and drop-oldest overflow.
///
/// # Safety contract
///
/// Exactly ONE thread records into a given ring at a time (the worker
/// that owns the shard, or the monitor for its track). Readers
/// ([`snapshot`](Self::snapshot)) must not race a recording thread —
/// in practice every drain happens after the threaded run joined (or
/// from the recording thread itself). The per-kind counters are plain
/// atomics and safe to read at any time.
pub struct EventRing {
    cap: usize,
    /// Total events ever recorded (the write cursor is `head % cap`).
    head: AtomicU64,
    slots: Box<[UnsafeCell<Event>]>,
    counts: [AtomicU64; KIND_COUNT],
}

// SAFETY: the UnsafeCell slots are only written by the single producer
// (contract above) and only read when no producer is active; the
// cursor and counters are atomics.
unsafe impl Sync for EventRing {}
unsafe impl Send for EventRing {}

impl EventRing {
    pub fn new(cap: usize) -> EventRing {
        let cap = cap.max(1);
        EventRing {
            cap,
            head: AtomicU64::new(0),
            slots: (0..cap).map(|_| UnsafeCell::new(Event::default())).collect(),
            counts: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Record one event (producer thread only — see the safety
    /// contract). Overflow overwrites the oldest slot.
    #[inline]
    pub fn record(&self, ev: Event) {
        let h = self.head.load(Ordering::Relaxed);
        // SAFETY: single producer; readers don't race (contract).
        unsafe {
            *self.slots[(h % self.cap as u64) as usize].get() = ev;
        }
        self.head.store(h + 1, Ordering::Release);
        self.counts[ev.kind as usize].fetch_add(1, Ordering::Relaxed);
    }

    /// Events recorded over the ring's lifetime (≥ retained).
    pub fn recorded(&self) -> u64 {
        self.head.load(Ordering::Acquire)
    }

    /// The retained window, oldest first (at most `cap` events). Must
    /// not race an active producer (see the safety contract).
    pub fn snapshot(&self) -> Vec<Event> {
        let h = self.head.load(Ordering::Acquire);
        let len = h.min(self.cap as u64);
        (h - len..h)
            .map(|i| {
                // SAFETY: no producer is active during a snapshot.
                unsafe { *self.slots[(i % self.cap as u64) as usize].get() }
            })
            .collect()
    }

    /// Exact lifetime totals (readable at any time).
    pub fn totals(&self) -> EventTotals {
        let mut counts = [0u64; KIND_COUNT];
        for (i, c) in self.counts.iter().enumerate() {
            counts[i] = c.load(Ordering::Acquire);
        }
        let h = self.head.load(Ordering::Acquire);
        EventTotals { counts, dropped: h.saturating_sub(self.cap as u64) }
    }
}

impl std::fmt::Debug for EventRing {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("EventRing")
            .field("cap", &self.cap)
            .field("recorded", &self.recorded())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_keeps_most_recent_window_in_order() {
        let ring = EventRing::new(8);
        for i in 0..20u64 {
            ring.record(Event {
                t_us: i,
                kind: EventKind::ALL[(i % KIND_COUNT as u64) as usize],
                a: i,
                v: i as f64,
            });
        }
        let evs = ring.snapshot();
        assert_eq!(evs.len(), 8);
        for (j, ev) in evs.iter().enumerate() {
            let i = 12 + j as u64; // events 12..20 survive
            assert_eq!(ev.t_us, i);
            assert_eq!(ev.a, i);
            assert_eq!(ev.kind, EventKind::ALL[(i % KIND_COUNT as u64) as usize]);
        }
        let t = ring.totals();
        assert_eq!(t.total(), 20);
        assert_eq!(t.dropped, 12);
    }

    #[test]
    fn ring_under_capacity_snapshots_everything() {
        let ring = EventRing::new(64);
        for i in 0..5u64 {
            ring.record(Event { t_us: i, kind: EventKind::IdleRound, a: 0, v: 0.0 });
        }
        assert_eq!(ring.snapshot().len(), 5);
        assert_eq!(ring.totals().get(EventKind::IdleRound), 5);
        assert_eq!(ring.totals().dropped, 0);
    }
}
