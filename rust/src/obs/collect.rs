//! The trace collector: owns the per-track event rings, the shared
//! microsecond clock, and the residual-decay sample series.
//!
//! One [`TraceCollector`] spans a whole CLI run (all epochs). Tracks
//! are addressed by shard index; the monitor/coordinator writes to the
//! dedicated [`MONITOR_TRACK`]. Rings are created lazily the first
//! time a track is requested, so the collector does not need to know
//! the shard count up front (it can even change across rebalances —
//! shard `i` always maps to track `i`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use super::event::{Event, EventKind, EventRing, EventTotals};

/// Track index reserved for the monitor/coordinator thread.
pub const MONITOR_TRACK: usize = usize::MAX;

/// Default per-track ring capacity (events retained per shard).
pub const DEFAULT_RING_CAP: usize = 8192;

/// Default sampling interval for the residual-decay series, in
/// microseconds.
pub const DEFAULT_SAMPLE_US: u64 = 500;

/// Hard cap on retained samples — the series is bounded even if a
/// caller leaves a collector attached across an enormous run. Excess
/// samples are counted, not stored.
const MAX_SAMPLES: usize = 1 << 20;

/// One residual-decay observation for one shard.
///
/// `queued` is the shard's materialized local ‖r‖₁ — the mass sitting
/// in its bucket queue, which is the meaningful "queue depth" for a
/// residual solver. `in_flight` is the global fragment count at sample
/// time (same value stamped on every shard's row of that sweep);
/// `pressure` is the shard's steal-pressure board reading.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Sample {
    pub t_us: u64,
    pub shard: u32,
    pub residual: f64,
    pub queued: f64,
    pub in_flight: i64,
    pub pressure: f64,
}

/// Shared observability sink for one run: per-shard event rings, a
/// monitor ring, and the sample series. Cheap to clone behind an
/// `Arc`; all methods take `&self`.
pub struct TraceCollector {
    t0: Instant,
    ring_cap: usize,
    sample_us: u64,
    rings: Mutex<Vec<Arc<EventRing>>>,
    monitor: Arc<EventRing>,
    samples: Mutex<Vec<Sample>>,
    samples_dropped: AtomicU64,
}

impl TraceCollector {
    pub fn new(ring_cap: usize, sample_us: u64) -> TraceCollector {
        TraceCollector {
            t0: Instant::now(),
            ring_cap,
            sample_us: sample_us.max(1),
            rings: Mutex::new(Vec::new()),
            monitor: Arc::new(EventRing::new(ring_cap)),
            samples: Mutex::new(Vec::new()),
            samples_dropped: AtomicU64::new(0),
        }
    }

    /// Microseconds since the collector was created (the trace epoch).
    #[inline]
    pub fn now_us(&self) -> u64 {
        self.t0.elapsed().as_micros() as u64
    }

    /// Sampling interval requested for the residual-decay series.
    pub fn sample_interval_us(&self) -> u64 {
        self.sample_us
    }

    /// The ring for a track, creating it (and any lower-indexed shard
    /// tracks) on first use. Hot loops should call this once and cache
    /// the `Arc` — the lookup takes a mutex.
    pub fn ring(&self, track: usize) -> Arc<EventRing> {
        if track == MONITOR_TRACK {
            return Arc::clone(&self.monitor);
        }
        let mut rings = self.rings.lock().unwrap();
        while rings.len() <= track {
            rings.push(Arc::new(EventRing::new(self.ring_cap)));
        }
        Arc::clone(&rings[track])
    }

    /// Convenience recorder for epoch/superstep-granularity call sites
    /// (takes the ring mutex; worker loops cache the ring instead).
    pub fn record(&self, track: usize, kind: EventKind, a: u64, v: f64) {
        let ev = Event { t_us: self.now_us(), kind, a, v };
        self.ring(track).record(ev);
    }

    /// Number of shard tracks created so far (monitor excluded).
    pub fn shard_tracks(&self) -> usize {
        self.rings.lock().unwrap().len()
    }

    /// Retained events for one shard track, oldest first.
    pub fn events_for(&self, track: usize) -> Vec<Event> {
        self.ring(track).snapshot()
    }

    /// Lifetime event totals for one shard track.
    pub fn totals_for(&self, track: usize) -> EventTotals {
        self.ring(track).totals()
    }

    /// Retained monitor-track events, oldest first.
    pub fn monitor_events(&self) -> Vec<Event> {
        self.monitor.snapshot()
    }

    /// Lifetime monitor-track event totals.
    pub fn monitor_totals(&self) -> EventTotals {
        self.monitor.totals()
    }

    /// Append one observation to the residual-decay series.
    pub fn push_sample(&self, s: Sample) {
        let mut samples = self.samples.lock().unwrap();
        if samples.len() < MAX_SAMPLES {
            samples.push(s);
        } else {
            self.samples_dropped.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// The full sample series in arrival order.
    pub fn samples(&self) -> Vec<Sample> {
        self.samples.lock().unwrap().clone()
    }

    /// Samples discarded after the `MAX_SAMPLES` cap was hit.
    pub fn samples_dropped(&self) -> u64 {
        self.samples_dropped.load(Ordering::Relaxed)
    }

    /// Last recorded sample per shard (by arrival order), indexed by
    /// shard. Shards that never sampled are absent (`None`).
    pub fn final_samples(&self) -> Vec<Option<Sample>> {
        let samples = self.samples.lock().unwrap();
        let tracks = samples.iter().map(|s| s.shard as usize + 1).max().unwrap_or(0);
        let mut last: Vec<Option<Sample>> = vec![None; tracks];
        for s in samples.iter() {
            last[s.shard as usize] = Some(*s);
        }
        last
    }
}

impl std::fmt::Debug for TraceCollector {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TraceCollector")
            .field("shard_tracks", &self.shard_tracks())
            .field("ring_cap", &self.ring_cap)
            .field("sample_us", &self.sample_us)
            .finish()
    }
}

impl Default for TraceCollector {
    fn default() -> TraceCollector {
        TraceCollector::new(DEFAULT_RING_CAP, DEFAULT_SAMPLE_US)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracks_grow_lazily_and_monitor_is_separate() {
        let tr = TraceCollector::default();
        tr.record(2, EventKind::PushBatch, 7, 0.5);
        assert_eq!(tr.shard_tracks(), 3);
        assert_eq!(tr.events_for(2).len(), 1);
        assert_eq!(tr.events_for(0).len(), 0);
        tr.record(MONITOR_TRACK, EventKind::QuietWindow, 1, 0.0);
        assert_eq!(tr.shard_tracks(), 3, "monitor track must not claim a shard slot");
        assert_eq!(tr.monitor_events().len(), 1);
    }

    #[test]
    fn final_samples_keep_last_per_shard() {
        let tr = TraceCollector::default();
        for (t, shard, r) in [(10u64, 0u32, 0.5), (20, 1, 0.4), (30, 0, 0.1), (40, 1, 0.05)] {
            tr.push_sample(Sample {
                t_us: t,
                shard,
                residual: r,
                queued: r,
                in_flight: 0,
                pressure: 0.0,
            });
        }
        let last = tr.final_samples();
        assert_eq!(last.len(), 2);
        assert_eq!(last[0].unwrap().residual, 0.1);
        assert_eq!(last[1].unwrap().residual, 0.05);
    }
}
