//! `obs` — async progress telemetry for the push solver.
//!
//! The paper's thesis is that asynchronous shards make *unequal*
//! progress without barriers; this module makes that visible. Three
//! layers, zero external dependencies (the build is offline — same
//! policy as the vendored `anyhow`):
//!
//! - **events** ([`event`]): per-shard lock-free ring buffers of
//!   timestamped typed events ([`EventKind`]) recorded from the
//!   threaded workers, the deterministic superstep driver, the epoch
//!   pipeline, and the top-k monitor. Nothing records from inside
//!   `push_row`/`drain`, so the disabled path adds literally zero
//!   per-push cost.
//! - **sampling** ([`collect`]): the monitor thread (and the
//!   deterministic driver, per superstep) snapshots per-shard
//!   residual / queued mass / in-flight count / steal-pressure
//!   readings into a residual-decay time series ([`Sample`]).
//! - **export** ([`export`]): Chrome trace-event JSON (one track per
//!   shard plus a monitor track, Perfetto-loadable) and a compact
//!   series JSON, surfaced as `repro stream --trace out.json` and
//!   `repro run --trace out.json`.
//!
//! Everything hangs off a shared [`TraceCollector`]; attach one to a
//! `ShardedPush` (`attach_trace`) or pass it in `PushThreadOptions` /
//! `StreamOptions` and the drivers record into it.

pub mod collect;
pub mod event;
pub mod export;

pub use collect::{Sample, TraceCollector, DEFAULT_RING_CAP, DEFAULT_SAMPLE_US, MONITOR_TRACK};
pub use event::{Event, EventKind, EventRing, EventTotals, KIND_COUNT};
pub use export::run_trace_json;

/// Diagnostic stderr, off by default: prints only when the
/// `ASYNCPR_DIAG` environment variable is set to a non-empty value
/// other than `0`. Routes occasional "scheduler luck" style notes
/// (e.g. threaded-test retries) so worker stderr stays silent in
/// normal runs.
pub fn diag(msg: &str) {
    if diag_enabled() {
        eprintln!("[asyncpr] {msg}");
    }
}

/// Whether [`diag`] output is enabled (`ASYNCPR_DIAG=1`).
pub fn diag_enabled() -> bool {
    static ENABLED: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *ENABLED.get_or_init(|| {
        std::env::var("ASYNCPR_DIAG").map(|v| !v.is_empty() && v != "0").unwrap_or(false)
    })
}
