//! `repro` — the asyncpr command-line launcher.
//!
//! Subcommands (hand-rolled parser; the offline build has no clap):
//!
//! ```text
//! repro generate --graph stanford --seed 42 --out web.bin [--check]
//! repro run [--config run.toml] [--graph G] [--procs P] [--mode sync|async]
//!           [--tol T] [--topology clique|star|tree] [--adaptive]
//!           [--artifact] [--push] [--balanced] [--global-threshold] [--seed S]
//!           [--trace FILE]
//! repro experiment table1|table2|global|ablations [--graph G] [--out reports/X]
//! repro stream [--graph G] [--epochs E] [--seed S] [--tol T] [--alpha A]
//!              [--threads N] [--resident] [--rebalance-factor F]
//!              [--steal] [--steal-batch B]
//!              [--topk K] [--topk-order] [--topk-stop]
//!              [--ppr SRC[,SRC...]]
//!              [--term protocol|quiet] [--pc-max N] [--inject-stall W:MS[:R]]
//!              [--net loopback|socket] [--net-profile test|beowulf]
//!              [--inject-link L:MS[:JITTER]]
//!              [--outbox auto|dense|sparse]
//!              [--arrivals K] [--links L] [--inserts I]
//!              [--removes R] [--out reports/X]
//!              [--trace FILE] [--trace-sample-us N]
//! repro serve [--graph G] [--epochs E] [--seed S] [--tol T] [--alpha A]
//!             [--queries Q] [--distinct D] [--sources S]
//!             [--cache-cap C] [--topk K] [--out reports/X]
//! repro net [--graph G] [--shards P] [--seed S] [--tol T] [--alpha A]
//!           [--pc-max N] [--max-pushes B] [--timeout-secs T]
//! repro artifacts-check
//! repro help
//! ```

use std::collections::HashMap;
use std::sync::Arc;

use asyncpr::asynciter::{Mode, StallInjection, TermMode};
use asyncpr::config::RunConfig;
use asyncpr::coordinator::{self, experiments, Report};
use asyncpr::graph::{io, Csr, GraphStats};
use asyncpr::metrics::{
    run_summary, stream_markdown, stream_topk_markdown, table1_markdown, table2_markdown,
    trace_summary_markdown,
};
use asyncpr::obs::{self, EventTotals, TraceCollector};
use asyncpr::simnet::Topology;
use asyncpr::stream::OutboxPolicy;
use asyncpr::util::Json;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match dispatch(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            2
        }
    };
    std::process::exit(code);
}

fn dispatch(args: &[String]) -> anyhow::Result<()> {
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "generate" => {
            let flags = parse_flags(&args[1..])?;
            cmd_generate(&flags)
        }
        "run" => {
            let flags = parse_flags(&args[1..])?;
            cmd_run(&flags)
        }
        "experiment" => {
            let which = args.get(1).map(String::as_str).unwrap_or("");
            let rest = if args.len() > 2 { &args[2..] } else { &[] };
            let flags = parse_flags(rest)?;
            cmd_experiment(which, &flags)
        }
        "stream" => {
            let flags = parse_flags(&args[1..])?;
            cmd_stream(&flags)
        }
        "serve" => {
            let flags = parse_flags(&args[1..])?;
            cmd_serve(&flags)
        }
        "net" => {
            let flags = parse_flags(&args[1..])?;
            cmd_net(&flags)
        }
        // hidden: the child half of `repro net` / `stream --net socket`
        // (one process per shard, spawned by the driver — not part of
        // the user-facing surface)
        "net-worker" => {
            let flags = parse_flags(&args[1..])?;
            cmd_net_worker(&flags)
        }
        "artifacts-check" => cmd_artifacts_check(),
        "help" | "--help" | "-h" => {
            print!("{HELP}");
            Ok(())
        }
        other => anyhow::bail!("unknown command {other:?}; try `repro help`"),
    }
}

const HELP: &str = r#"repro — asynchronous iterative PageRank (Kollias/Gallopoulos/Szyld 2006)

USAGE:
  repro generate --graph <SPEC> [--seed N] --out <FILE> [--check]
  repro run [--config FILE] [--graph SPEC] [--procs P] [--mode sync|async]
            [--tol T] [--topology clique|star|tree] [--adaptive]
            [--artifact] [--push] [--balanced] [--global-threshold] [--seed N]
            [--trace FILE]
  repro experiment <table1|table2|global|ablations> [--graph SPEC] [--out STEM]
  repro stream [--graph SPEC] [--epochs E] [--seed N] [--tol T] [--alpha A]
               [--threads N] [--resident] [--rebalance-factor F]
               [--steal] [--steal-batch B]
               [--topk K] [--topk-order] [--topk-stop]
               [--ppr SRC[,SRC...]]
               [--term protocol|quiet] [--pc-max N]
               [--inject-stall W:MS[:R]]
               [--net loopback|socket] [--net-profile test|beowulf]
               [--inject-link L:MS[:JITTER]]
               [--outbox auto|dense|sparse]
               [--arrivals K] [--links L] [--inserts I]
               [--removes R] [--out STEM]
               [--trace FILE] [--trace-sample-us N]
  repro serve [--graph SPEC] [--epochs E] [--seed N] [--tol T] [--alpha A]
              [--queries Q] [--distinct D] [--sources S]
              [--cache-cap C] [--topk K] [--out STEM]
  repro net [--graph SPEC] [--shards P] [--seed N] [--tol T] [--alpha A]
            [--pc-max N] [--max-pushes B] [--timeout-secs T]
  repro artifacts-check
  repro help

GRAPH SPECS: stanford | scaled:<n> | erdos:<n>:<m> | path(.txt|.bin)

`stream` runs the evolving-graph workload: E churn epochs over the
graph, re-ranking incrementally (warm-started residual push) vs. from
scratch, and checks final ranks against a fresh power-method run.
`--threads N` drains each epoch on N real worker threads (balanced-nnz
shards exchanging residual fragments over bounded channels).
`--resident` keeps ONE sharded state alive across all epochs: churn
injects directly into the live shards (no scatter/gather round-trip)
and the CSR snapshot is spliced incrementally; `--rebalance-factor F`
re-cuts the shard bounds between epochs once churn skews the per-shard
nnz beyond F times the ideal share.
`--steal` (needs --threads >= 2) turns on intra-epoch work stealing:
an idle worker adopts the hottest queued rows of the most-loaded peer
mid-drain, `--steal-batch B` rows per grant (default 64); the report
gains per-epoch `stolen (grants)` columns.
`--topk K` tracks the top-K head of the ranking with certified error
intervals (serving path): the report gains head-churn and
pushes-to-certification columns; `--topk-order` also certifies the
order within the head; `--topk-stop` ends each epoch's solve as soon
as the head certifies instead of running to tol.
`--ppr SRC[,SRC...]` switches every backend to personalized PageRank:
the teleport vector becomes uniform over the listed source nodes
(dangling mass follows it), and the from-scratch baseline plus the
power-method reference solve the same personalized fixed point, so
all cross-checks hold verbatim.
`serve` runs the PPR query tier: a recurring mix of multi-source
queries over a churning graph, answered through an LRU cache of warm
push states that graph deltas invalidate *incrementally* (the cached
state absorbs exactly the residual the delta created — no cold
re-solves). `--queries Q` per churn round, drawn from a pool of
`--distinct D` source sets of `--sources S` nodes each; `--cache-cap
C` warm entries; every answer carries a certified top-`--topk K`
head. Reports hit rate, warm-vs-cold push split, and p50/p99 latency.
`--term` picks how the threaded drains stop: `protocol` (default) is
the paper's §4.2 persistence-counter protocol — workers announce
CONVERGE after `--pc-max N` (default 3) consecutive locally-converged
rounds with nothing in flight, retract with DIVERGE when mass arrives,
and the monitor stops once every worker's last word was CONVERGE;
`quiet` keeps the legacy quiet-window heuristic (three consecutive
monitor samples with published residuals under tol), which can stop
early when a stalled worker holds unpublished residual. The report's
`stop` column shows each epoch's stop cause and protocol traffic.
`--inject-stall W:MS[:R]` makes worker W sleep MS milliseconds at
round R (default 0) of each threaded drain — fault injection for
racing the two termination modes.
`--net` routes the threaded exchange over a process-boundary wire
instead of mpsc channels (needs --threads >= 2): `loopback` serializes
every fragment/steal/top-k/termination message through the versioned
binary codec and an in-process fabric throttled by `--net-profile`
bandwidth/latency curves (`test` fast default, `beowulf` the paper's
heterogeneous cluster); `socket` runs one OS process per shard over
real TCP sockets (plain roundtrip drain only: no steal/topk/resident/
ppr/trace, --term protocol required). `--inject-link L:MS[:JITTER]`
(loopback only) delays every frame out of endpoint L by MS ms plus
uniform jitter in [0,JITTER) ms — the wire fault that makes the quiet
heuristic stop early while the protocol waits out in-flight mass.
`--outbox` picks the sharded solvers' per-peer outbox representation:
`dense` keeps O(span) accumulator arrays per peer, `sparse` swaps them
for ordered maps sized by touched targets, `auto` (default) goes
sparse above 8 shards so outbox memory stays O(touched) as the shard
count grows.
`net` is the standalone socket-tier driver: spawn `--shards P` worker
processes, solve cold over real sockets to a protocol STOP, gather and
verify (exact residual < tol, mass balance, L1 vs a fresh power run —
any violated bar is a hard error).
`--trace FILE` writes a Chrome trace-event JSON (open in Perfetto or
chrome://tracing). For `stream` it carries one instant-event track per
shard (push batches, fragment sends/defers, steal requests/grants,
idle rounds) plus a monitor track (epoch begins, cert checks, quiet
windows) and a per-shard residual-decay counter series;
`--trace-sample-us N` sets the monitor sampling period (default 500).
For `run` it carries one span per UE over virtual time. The CLI
re-parses the written file and fails on any invalid or empty trace.
`run --balanced` partitions rows by balanced nonzero count instead of
the paper's consecutive ⌈n/p⌉ blocks.
"#;

fn parse_flags(args: &[String]) -> anyhow::Result<HashMap<String, String>> {
    let mut map = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        let key = a
            .strip_prefix("--")
            .ok_or_else(|| anyhow::anyhow!("expected --flag, got {a:?}"))?;
        // boolean flags
        if matches!(
            key,
            "check" | "adaptive" | "artifact" | "push" | "balanced" | "global-threshold"
                | "quick" | "resident" | "seeded" | "steal" | "topk-order" | "topk-stop"
        ) {
            map.insert(key.to_string(), "true".to_string());
            i += 1;
            continue;
        }
        let val = args
            .get(i + 1)
            .ok_or_else(|| anyhow::anyhow!("--{key} needs a value"))?;
        map.insert(key.to_string(), val.clone());
        i += 2;
    }
    Ok(map)
}

/// Parse `--inject-stall WORKER:MS[:ROUND]` — worker index, sleep
/// milliseconds, and the round the sleep triggers on (default 0).
fn parse_stall(v: &str) -> anyhow::Result<StallInjection> {
    let parts: Vec<&str> = v.split(':').collect();
    anyhow::ensure!(
        parts.len() == 2 || parts.len() == 3,
        "--inject-stall wants WORKER:MS or WORKER:MS:ROUND, got {v:?}"
    );
    Ok(StallInjection {
        worker: parts[0].parse()?,
        ms: parts[1].parse()?,
        after_rounds: parts.get(2).map(|r| r.parse()).transpose()?.unwrap_or(0),
    })
}

/// Parse `--inject-link L:MS[:JITTER]` — sending endpoint, fixed extra
/// delay in milliseconds, and uniform jitter in `[0, JITTER)` ms
/// (default 0).
fn parse_inject_link(v: &str) -> anyhow::Result<(usize, f64, f64)> {
    let parts: Vec<&str> = v.split(':').collect();
    anyhow::ensure!(
        parts.len() == 2 || parts.len() == 3,
        "--inject-link wants L:MS or L:MS:JITTER, got {v:?}"
    );
    Ok((
        parts[0].parse()?,
        parts[1].parse()?,
        parts.get(2).map(|j| j.parse()).transpose()?.unwrap_or(0.0),
    ))
}

/// Parse `SRC[,SRC..]` — the comma-separated node-id list behind
/// `--ppr`.
fn parse_sources(v: &str) -> anyhow::Result<Vec<u32>> {
    v.split(',')
        .map(|s| {
            s.trim()
                .parse::<u32>()
                .map_err(|e| anyhow::anyhow!("source list wants node ids, got {s:?}: {e}"))
        })
        .collect()
}

/// Serialize a trace document, write it, and re-parse the written
/// bytes — a malformed exporter fails the run here, not later in the
/// viewer. Returns the re-parsed document for further validation.
fn write_trace_file(path: &str, doc: &Json) -> anyhow::Result<Json> {
    let text = doc.to_string_compact();
    std::fs::write(path, &text)?;
    let parsed = Json::parse(&text)
        .map_err(|e| anyhow::anyhow!("trace exporter produced invalid JSON: {e}"))?;
    anyhow::ensure!(
        parsed
            .get("traceEvents")
            .and_then(Json::as_arr)
            .map_or(false, |evs| !evs.is_empty()),
        "trace file {path} has no traceEvents"
    );
    Ok(parsed)
}

fn config_from_flags(flags: &HashMap<String, String>) -> anyhow::Result<RunConfig> {
    let mut cfg = if let Some(path) = flags.get("config") {
        RunConfig::from_toml(&std::fs::read_to_string(path)?)?
    } else {
        RunConfig::default()
    };
    if let Some(g) = flags.get("graph") {
        cfg.graph = g.clone();
    }
    if let Some(p) = flags.get("procs") {
        cfg.procs = p.parse()?;
    }
    if let Some(m) = flags.get("mode") {
        cfg.mode = match m.as_str() {
            "sync" => Mode::Synchronous,
            "async" => Mode::Asynchronous,
            _ => anyhow::bail!("--mode sync|async"),
        };
    }
    if let Some(t) = flags.get("tol") {
        cfg.tol = t.parse()?;
    }
    if let Some(t) = flags.get("topology") {
        cfg.topology =
            Topology::parse(t).ok_or_else(|| anyhow::anyhow!("unknown topology {t:?}"))?;
    }
    if let Some(s) = flags.get("seed") {
        cfg.seed = s.parse()?;
    }
    if flags.contains_key("adaptive") {
        cfg.adaptive = true;
    }
    if flags.contains_key("artifact") {
        cfg.use_artifact = true;
    }
    if flags.contains_key("push") {
        cfg.use_push = true;
    }
    if flags.contains_key("balanced") {
        cfg.balanced_partition = true;
    }
    if flags.contains_key("global-threshold") {
        cfg.global_threshold = true;
    }
    cfg.validate()?;
    Ok(cfg)
}

fn cmd_generate(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let spec = flags.get("graph").map(String::as_str).unwrap_or("stanford");
    let seed: u64 = flags.get("seed").map(|s| s.parse()).transpose()?.unwrap_or(42);
    let out = flags
        .get("out")
        .ok_or_else(|| anyhow::anyhow!("generate requires --out <file>"))?;
    eprintln!("generating {spec} (seed {seed}) ...");
    // one materialization serves both the stats/validation CSR and the
    // saved edge list (the old code generated the graph twice)
    let el = coordinator::load_edgelist(spec, seed)?;
    let csr = Csr::from_edgelist(&el)?;
    if flags.contains_key("check") {
        csr.validate()?;
        eprintln!("structural validation OK");
    }
    println!("{}", GraphStats::compute(&csr).report());
    if out.ends_with(".bin") {
        io::save_edgelist_bin(&el, out)?;
    } else {
        io::save_edgelist_text(&el, out)?;
    }
    eprintln!("wrote {out}");
    Ok(())
}

fn cmd_run(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let cfg = config_from_flags(flags)?;
    let engine = if cfg.use_artifact {
        Some(asyncpr::runtime::Engine::new(asyncpr::runtime::default_artifacts_dir())?)
    } else {
        None
    };
    eprintln!(
        "running {:?} p={} graph={} tol={:.0e} ...",
        cfg.mode, cfg.procs, cfg.graph, cfg.tol
    );
    let m = coordinator::run_experiment(&cfg, engine.as_ref())?;
    println!("{}", run_summary(&m));
    let (imin, imax) = m.iters_range();
    let (tmin, tmax) = m.time_range();
    println!("iters [{imin}, {imax}]  t [{tmin:.1}, {tmax:.1}] s");
    println!("\nimports matrix:\n{}", table2_markdown(&m));
    if let Some(path) = flags.get("trace") {
        write_trace_file(path, &obs::run_trace_json(&m.iters, &m.finish_times, m.total_time))?;
        eprintln!("wrote trace {path} ({} UE spans over virtual time)", m.iters.len());
    }
    Ok(())
}

fn cmd_experiment(which: &str, flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let graph = flags
        .get("graph")
        .cloned()
        .unwrap_or_else(|| "stanford".to_string());
    let out = flags.get("out").cloned();
    let base = RunConfig { graph, ..Default::default() };
    let ctx = experiments::ExperimentCtx::new(base)?;
    let mut rep = Report::new();
    match which {
        "table1" => {
            let rows = experiments::table1(&ctx, &[2, 4, 6])?;
            let t1: Vec<_> = rows.iter().map(|(r, _, _)| r.clone()).collect();
            let md = table1_markdown(&t1);
            println!("{md}");
            rep.add_section("Table 1", &md);
            for (row, s, a) in &rows {
                rep.add_run(&format!("sync_p{}", row.procs), s);
                rep.add_run(&format!("async_p{}", row.procs), a);
            }
        }
        "table2" => {
            let m = experiments::table2(&ctx, 4)?;
            let md = table2_markdown(&m);
            println!("{md}");
            rep.add_section("Table 2", &md);
            rep.add_run("async_p4", &m);
        }
        "global" => {
            let g = experiments::global_threshold(&ctx, 4, 1e-6)?;
            let md = format!(
                "local tol {:.0e} => achieved global residual {:.2e}\n\
                 kendall-tau {:.6}, top-100 overlap {:.2}\n\
                 race to global tol: sync {:.1}s vs async {:.1}s => speedup {:.2}",
                g.local_tol,
                g.achieved_global_residual,
                g.ranking_tau,
                g.top100_overlap,
                g.sync_time_global,
                g.async_time_global,
                g.speedup_global,
            );
            println!("{md}");
            rep.add_section("Global threshold (G1+G2)", &md);
        }
        "ablations" => {
            let mut md = String::new();
            let windows = [None, Some(1.0), Some(3.0), Some(10.0)];
            md.push_str("cancel-window sweep (p=4, async):\n");
            for (w, m) in experiments::ablation_cancel_window(&ctx, 4, &windows)? {
                md.push_str(&format!(
                    "  window {:?}: t={:.1}s cancelled={} queue_wait={:.1}s resid={:.1e}\n",
                    w, m.total_time, m.wire_cancelled, m.wire_queue_wait, m.final_global_residual
                ));
            }
            md.push_str("\nadaptive rates (p=4, one 3x-slow node):\n");
            let (fixed, adap) = experiments::ablation_adaptive(&ctx, 4, 3.0)?;
            md.push_str(&format!(
                "  fixed:    t={:.1}s cancelled={}\n  adaptive: t={:.1}s cancelled={}\n",
                fixed.total_time, fixed.wire_cancelled, adap.total_time, adap.wire_cancelled
            ));
            md.push_str("\ntopology sweep (p=6, async):\n");
            for (t, m) in experiments::ablation_topology(
                &ctx,
                6,
                &[Topology::Clique, Topology::Star, Topology::BinaryTree],
            )? {
                md.push_str(&format!(
                    "  {:?}: t={:.1}s cancelled={} resid={:.1e}\n",
                    t, m.total_time, m.wire_cancelled, m.final_global_residual
                ));
            }
            println!("{md}");
            rep.add_section("Ablations", &md);
        }
        other => anyhow::bail!("unknown experiment {other:?} (table1|table2|global|ablations)"),
    }
    if let Some(stem) = out {
        rep.write(&stem)?;
        eprintln!("wrote {stem}.md / {stem}.json");
    }
    Ok(())
}

fn cmd_stream(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let graph = flags
        .get("graph")
        .cloned()
        .unwrap_or_else(|| "scaled:50000".to_string());
    let mut opts = experiments::StreamOptions::default();
    if let Some(v) = flags.get("epochs") {
        opts.epochs = v.parse()?;
    }
    if let Some(v) = flags.get("seed") {
        opts.seed = v.parse()?;
    }
    if let Some(v) = flags.get("tol") {
        opts.tol = v.parse()?;
    }
    if let Some(v) = flags.get("alpha") {
        opts.alpha = v.parse()?;
    }
    if let Some(v) = flags.get("threads") {
        opts.threads = v.parse()?;
    }
    if flags.contains_key("resident") {
        opts.resident = true;
    }
    if let Some(v) = flags.get("rebalance-factor") {
        opts.rebalance_factor = Some(v.parse()?);
    }
    if flags.contains_key("steal") {
        opts.steal = true;
    }
    if let Some(v) = flags.get("steal-batch") {
        opts.steal_batch = v.parse()?;
    }
    if let Some(v) = flags.get("topk") {
        opts.topk = Some(v.parse()?);
    }
    if flags.contains_key("topk-order") {
        opts.topk_order = true;
    }
    if flags.contains_key("topk-stop") {
        opts.topk_stop = true;
    }
    if let Some(v) = flags.get("ppr") {
        opts.ppr = Some(parse_sources(v)?);
    }
    if let Some(v) = flags.get("term") {
        opts.term = match v.as_str() {
            "protocol" => TermMode::Protocol,
            "quiet" => TermMode::Quiet,
            other => anyhow::bail!("--term must be protocol|quiet, got {other:?}"),
        };
    }
    if let Some(v) = flags.get("pc-max") {
        opts.pc_max = v.parse()?;
    }
    if let Some(v) = flags.get("inject-stall") {
        opts.inject_stall = Some(parse_stall(v)?);
    }
    if let Some(v) = flags.get("net") {
        opts.net = Some(match v.as_str() {
            "loopback" => experiments::NetBackend::Loopback,
            "socket" => experiments::NetBackend::Socket,
            other => anyhow::bail!("--net must be loopback|socket, got {other:?}"),
        });
    }
    if let Some(v) = flags.get("net-profile") {
        anyhow::ensure!(opts.net.is_some(), "--net-profile needs --net loopback|socket");
        opts.net_profile = match v.as_str() {
            "test" => experiments::NetProfileKind::Test,
            "beowulf" => experiments::NetProfileKind::Beowulf,
            other => anyhow::bail!("--net-profile must be test|beowulf, got {other:?}"),
        };
    }
    if let Some(v) = flags.get("inject-link") {
        opts.inject_link = Some(parse_inject_link(v)?);
    }
    if let Some(v) = flags.get("outbox") {
        opts.outbox = match v.as_str() {
            "auto" => OutboxPolicy::Auto,
            "dense" => OutboxPolicy::Dense,
            "sparse" => OutboxPolicy::Sparse,
            other => anyhow::bail!("--outbox must be auto|dense|sparse, got {other:?}"),
        };
    }
    // churn overrides ride as options; the driver resolves them against
    // graph-scaled defaults once the graph is loaded (loading it here
    // just to size the defaults would build it twice)
    if let Some(v) = flags.get("arrivals") {
        opts.arrivals = Some(v.parse()?);
    }
    if let Some(v) = flags.get("links") {
        opts.links_per_arrival = Some(v.parse()?);
    }
    if let Some(v) = flags.get("inserts") {
        opts.churn_inserts = Some(v.parse()?);
    }
    if let Some(v) = flags.get("removes") {
        opts.churn_removes = Some(v.parse()?);
    }
    let trace_sample_us: u64 = flags
        .get("trace-sample-us")
        .map(|v| v.parse())
        .transpose()?
        .unwrap_or(obs::DEFAULT_SAMPLE_US);
    anyhow::ensure!(
        flags.get("trace").is_some() || !flags.contains_key("trace-sample-us"),
        "--trace-sample-us needs --trace FILE"
    );
    opts.trace = flags
        .get("trace")
        .map(|_| Arc::new(TraceCollector::new(obs::DEFAULT_RING_CAP, trace_sample_us)));

    eprintln!(
        "streaming {graph}: {} update epochs, tol {:.0e}, alpha {}, threads {}{}{}{}{} ...",
        opts.epochs,
        opts.tol,
        opts.alpha,
        opts.threads,
        if opts.resident { " (epoch-resident shards)" } else { "" },
        if opts.steal { " (work stealing)" } else { "" },
        match opts.net {
            Some(experiments::NetBackend::Loopback) => " (loopback wire)",
            Some(experiments::NetBackend::Socket) => " (socket processes)",
            None => "",
        },
        opts.ppr
            .as_ref()
            .map(|s| format!(" (PPR over {} sources)", s.len()))
            .unwrap_or_default()
    );
    let rep = experiments::stream_epochs(&graph, &opts)?;
    let md = stream_markdown(&rep.rows);
    println!("{md}");
    if let Some(k) = opts.topk {
        println!(
            "\nserving path (top-{k}{}{}):",
            if opts.topk_order { ", ordered" } else { "" },
            if opts.topk_stop { ", early-stop" } else { "" },
        );
        println!("{}", stream_topk_markdown(&rep.rows));
        let update = &rep.rows[1..];
        let certified = update
            .iter()
            .filter(|r| r.topk.as_ref().map_or(false, |t| t.certified))
            .count();
        let cert_pushes: u64 = update
            .iter()
            .filter_map(|r| r.topk.as_ref().and_then(|t| t.pushes_to_cert))
            .sum();
        let conv_pushes: u64 = update
            .iter()
            .filter(|r| r.topk.as_ref().map_or(false, |t| t.pushes_to_cert.is_some()))
            .map(|r| r.inc_pushes)
            .sum();
        if opts.topk_stop {
            println!(
                "update epochs: head certified in {certified}/{} epochs; \
                 epochs end at certification, so `inc pushes` above IS the serving cost",
                update.len()
            );
        } else {
            println!(
                "update epochs: head certified in {certified}/{} epochs; \
                 pushes-to-cert {cert_pushes} vs pushes-to-convergence {conv_pushes} \
                 ({:.1}x earlier)",
                update.len(),
                conv_pushes as f64 / cert_pushes.max(1) as f64
            );
        }
    }
    if opts.steal {
        let stolen: u64 = rep.rows.iter().map(|r| r.stolen_rows).sum();
        let grants: u64 = rep.rows.iter().map(|r| r.steal_grants).sum();
        println!(
            "work stealing: {stolen} rows changed owner across {grants} grants \
             (opportunistic — 0 just means no idle/loaded window opened)"
        );
    }
    if opts.resident {
        let dirty: usize = rep.rows.iter().map(|r| r.csr_dirty_rows).sum();
        let full: usize = rep.rows[1..].iter().map(|r| r.n).sum();
        println!(
            "CSR handoff: {dirty} rows spliced across update epochs \
             (full rebuilds would have paid {full})"
        );
    }
    let saving = rep.update_scratch_pushes as f64 / rep.update_inc_pushes.max(1) as f64;
    println!(
        "update epochs: incremental {} pushes vs from-scratch {} ({saving:.1}x saving)",
        rep.update_inc_pushes, rep.update_scratch_pushes
    );
    println!(
        "warm start strictly cheaper on every update epoch: {}",
        if rep.all_updates_cheaper { "yes" } else { "NO" }
    );
    // the L1 bar scales with the requested tolerance (floored at the
    // repo's 1e-8 acceptance threshold, which the default tol meets);
    // under --topk-stop epochs end at certification, so the certified
    // head — not the full vector — is the acceptance surface
    let l1_bar = opts.l1_check_threshold();
    if opts.topk_stop {
        println!(
            "final-epoch ranks vs fresh power method: L1 = {:.2e} \
             (informational under --topk-stop; heads are certified instead)",
            rep.final_l1_vs_power
        );
    } else {
        println!(
            "final-epoch ranks vs fresh power method: L1 = {:.2e} ({} {l1_bar:.0e})",
            rep.final_l1_vs_power,
            if rep.final_l1_vs_power < l1_bar { "within" } else { "OUTSIDE" }
        );
    }

    if let Some(stem) = flags.get("out") {
        let mut report = Report::new();
        report.add_section("Evolving-graph epochs (stream)", &md);
        report.add_json(
            "stream",
            Json::Arr(rep.rows.iter().map(|r| r.to_json()).collect()),
        );
        report.write(stem)?;
        eprintln!("wrote {stem}.md / {stem}.json");
    }
    if let (Some(path), Some(tr)) = (flags.get("trace"), opts.trace.as_ref()) {
        let parsed = write_trace_file(path, &tr.to_chrome_json())?;
        // every shard track that exists must have recorded something —
        // an all-silent track means an instrumentation hook fell off
        let shards = tr.shard_tracks();
        for i in 0..shards {
            anyhow::ensure!(
                tr.totals_for(i).total() > 0,
                "trace validation: shard track {i} recorded no events"
            );
        }
        let mut tracks: Vec<(String, EventTotals)> =
            (0..shards).map(|i| (format!("shard {i}"), tr.totals_for(i))).collect();
        tracks.push(("monitor".to_string(), tr.monitor_totals()));
        println!("\ntrace summary:\n{}", trace_summary_markdown(&tracks));
        let n_events = parsed.get("traceEvents").and_then(Json::as_arr).map_or(0, |a| a.len());
        eprintln!(
            "wrote trace {path}: {n_events} trace events, {} series samples ({} dropped)",
            tr.samples().len(),
            tr.samples_dropped()
        );
    }
    // certified heads must audit clean against the power reference
    // (the driver hard-fails margin-resolvable disagreements already;
    // this catches the printed column drifting from 1.00 too)
    let heads_exact = rep.rows.iter().all(|r| {
        r.topk.as_ref().map_or(true, |t| !t.certified || t.overlap_vs_power == 1.0)
    });
    let l1_ok = opts.topk_stop || rep.final_l1_vs_power < l1_bar;
    if !rep.all_updates_cheaper || !l1_ok || !heads_exact {
        anyhow::bail!("stream acceptance check failed (see report above)");
    }
    Ok(())
}

fn cmd_net(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let graph = flags
        .get("graph")
        .cloned()
        .unwrap_or_else(|| "scaled:20000".to_string());
    let mut opts = asyncpr::net::SocketRunOptions::default();
    if let Some(v) = flags.get("shards") {
        opts.shards = v.parse()?;
    }
    if let Some(v) = flags.get("alpha") {
        opts.alpha = v.parse()?;
    }
    if let Some(v) = flags.get("tol") {
        opts.tol = v.parse()?;
    }
    if let Some(v) = flags.get("seed") {
        opts.seed = v.parse()?;
    }
    if let Some(v) = flags.get("max-pushes") {
        opts.max_pushes = v.parse()?;
    }
    if let Some(v) = flags.get("pc-max") {
        opts.pc_max = v.parse()?;
    }
    if let Some(v) = flags.get("timeout-secs") {
        opts.timeout = std::time::Duration::from_secs(v.parse()?);
    }
    eprintln!(
        "net {graph}: {} worker processes over real sockets, tol {:.0e}, alpha {} ...",
        opts.shards, opts.tol, opts.alpha
    );
    let rep = asyncpr::net::run_net_driver(&graph, &opts)?;
    // run_net_driver already enforced every bar below; a STOP that
    // left residual >= tol would have been an error, so reaching here
    // means the §4.2 protocol ended the run
    println!("socket tier: {} processes over n = {}", rep.shards, rep.n);
    println!("  pushes        {}", rep.pushes);
    println!("  residual      {:.3e} (tol {:.0e})", rep.residual, opts.tol);
    println!("  mass error    {:.3e} (bar 1e-9)", rep.mass_err);
    println!("  L1 vs power   {:.3e}", rep.l1_vs_power);
    println!(
        "  term traffic  {} messages ({} CONVERGE downgraded)",
        rep.term_messages, rep.downgraded
    );
    println!("  stop_cause    Protocol");
    println!("  wall clock    {:.0} ms", rep.wall_ms);
    Ok(())
}

fn cmd_net_worker(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    fn req<'a>(flags: &'a HashMap<String, String>, key: &str) -> anyhow::Result<&'a String> {
        flags
            .get(key)
            .ok_or_else(|| anyhow::anyhow!("net-worker needs --{key} (driver-spawned only)"))
    }
    let args = asyncpr::net::NetWorkerArgs {
        graph: req(flags, "graph")?.clone(),
        seed: req(flags, "seed")?.parse()?,
        shard: req(flags, "shard")?.parse()?,
        shards: req(flags, "shards")?.parse()?,
        alpha: req(flags, "alpha")?.parse()?,
        tol: req(flags, "tol")?.parse()?,
        budget: req(flags, "budget")?.parse()?,
        pc_max: req(flags, "pc-max")?.parse()?,
        addr: req(flags, "addr")?.clone(),
        timeout_ms: req(flags, "timeout-ms")?.parse()?,
        seeded: flags.contains_key("seeded"),
    };
    asyncpr::net::run_net_worker(&args)
}

fn cmd_serve(flags: &HashMap<String, String>) -> anyhow::Result<()> {
    let graph = flags
        .get("graph")
        .cloned()
        .unwrap_or_else(|| "scaled:20000".to_string());
    let mut opts = experiments::ServeRunOptions::default();
    if let Some(v) = flags.get("epochs") {
        opts.epochs = v.parse()?;
    }
    if let Some(v) = flags.get("seed") {
        opts.seed = v.parse()?;
    }
    if let Some(v) = flags.get("tol") {
        opts.tol = v.parse()?;
    }
    if let Some(v) = flags.get("alpha") {
        opts.alpha = v.parse()?;
    }
    if let Some(v) = flags.get("queries") {
        opts.queries_per_epoch = v.parse()?;
    }
    if let Some(v) = flags.get("distinct") {
        opts.distinct_queries = v.parse()?;
    }
    if let Some(v) = flags.get("sources") {
        opts.sources_per_query = v.parse()?;
    }
    if let Some(v) = flags.get("cache-cap") {
        opts.cache_cap = v.parse()?;
    }
    if let Some(v) = flags.get("topk") {
        opts.topk = v.parse()?;
    }
    eprintln!(
        "serving {graph}: {} churn rounds x {} queries, pool {} x {} sources, \
         cache {} entries, top-{} ...",
        opts.epochs,
        opts.queries_per_epoch,
        opts.distinct_queries,
        opts.sources_per_query,
        opts.cache_cap,
        opts.topk
    );
    let rep = experiments::serve_queries(&graph, &opts)?;
    println!(
        "answered {} queries: hit rate {:.2}, {} evictions, {} certified heads",
        rep.queries,
        rep.hit_rate,
        rep.evictions,
        rep.certified
    );
    println!(
        "pushes: {} warm (cache hits staying current under churn) vs {} cold",
        rep.warm_pushes, rep.cold_pushes
    );
    println!("latency: p50 {:.0} us, p99 {:.0} us", rep.p50_us, rep.p99_us);
    if let Some(stem) = flags.get("out") {
        let mut report = Report::new();
        report.add_section(
            "PPR serving tier",
            &format!(
                "queries {} | hit rate {:.2} | warm pushes {} | cold pushes {} | \
                 p50 {:.0}us | p99 {:.0}us",
                rep.queries, rep.hit_rate, rep.warm_pushes, rep.cold_pushes, rep.p50_us,
                rep.p99_us
            ),
        );
        let mut obj = std::collections::BTreeMap::new();
        obj.insert("queries".to_string(), Json::Num(rep.queries as f64));
        obj.insert("hit_rate".to_string(), Json::Num(rep.hit_rate));
        obj.insert("evictions".to_string(), Json::Num(rep.evictions as f64));
        obj.insert("warm_pushes".to_string(), Json::Num(rep.warm_pushes as f64));
        obj.insert("cold_pushes".to_string(), Json::Num(rep.cold_pushes as f64));
        obj.insert("p50_us".to_string(), Json::Num(rep.p50_us));
        obj.insert("p99_us".to_string(), Json::Num(rep.p99_us));
        obj.insert("certified".to_string(), Json::Num(rep.certified as f64));
        report.add_json("serve", Json::Obj(obj));
        report.write(stem)?;
        eprintln!("wrote {stem}.md / {stem}.json");
    }
    // a warm answer re-certifies on residual the churn actually
    // injected; if the cache never pays off the tier is mis-wired
    if rep.hit_rate > 0.0 {
        let warm_per_hit = rep.warm_pushes as f64 / (rep.queries as f64 * rep.hit_rate).max(1.0);
        let cold_per_miss =
            rep.cold_pushes as f64 / (rep.queries as f64 * (1.0 - rep.hit_rate)).max(1.0);
        anyhow::ensure!(
            warm_per_hit < cold_per_miss,
            "serve acceptance check failed: warm queries averaged {warm_per_hit:.0} pushes \
             vs {cold_per_miss:.0} cold"
        );
    }
    Ok(())
}

fn cmd_artifacts_check() -> anyhow::Result<()> {
    let dir = asyncpr::runtime::default_artifacts_dir();
    let engine = asyncpr::runtime::Engine::new(&dir)?;
    println!(
        "platform: {}; artifacts dir: {}",
        engine.platform(),
        dir.display()
    );
    for a in &engine.manifest().artifacts.clone() {
        // compile + one smoke execution per bucket
        let mut exe = engine.pagerank_step(a.bucket.n, a.bucket.b, a.bucket.k)?;
        let mut buf = exe.buffers();
        buf.alpha = [0.85];
        let (y, resid) = exe.step(&mut buf)?;
        println!(
            "  {:<44} bucket={:<9} n={:<7} b={:<7} k={:<2} smoke: y0={} resid={}",
            a.path, a.bucket.name, a.bucket.n, a.bucket.b, a.bucket.k, y[0], resid
        );
    }
    println!("all artifacts compile and execute");
    Ok(())
}
