//! Socket tier: the asynchronous push protocol across real OS process
//! boundaries.
//!
//! One process per shard, spawned by the `repro net` driver (and, for
//! warm-started epochs, by `repro stream --net socket`). The driver is
//! the star hub: every child connects back over loopback TCP, and the
//! driver forwards shard-to-shard frames *without decoding the
//! payload* ([`super::codec::peek`] reads the destination out of the
//! header) while fully decoding anything addressed to the monitor
//! endpoint.
//!
//! # Why the star topology is load-bearing
//!
//! The §4.2 protocol's soundness rests on per-producer FIFO: a
//! worker's DIVERGE retraction must reach the monitor's central log
//! before the acknowledgement that releases the sender's in-flight
//! accounting. A TCP stream preserves order, and the driver's
//! single-threaded decode loop processes each child's frames in stream
//! order — so a child that writes `Term(DIVERGE)` then `Ack` on its
//! one socket is guaranteed the monitor logs the retraction before the
//! originating peer can observe the release. With direct peer-to-peer
//! sockets that guarantee would need a distributed ordering protocol;
//! routing everything through the hub gets it for free.
//!
//! # Shutdown sequence
//!
//! STOP is only the beginning of the end: the driver broadcasts it,
//! each child flushes its outboxes one last time and reports
//! `Flushed`, the driver waits until every forwarded fragment has been
//! acknowledged (`pending == 0`), then requests a dense
//! [`WireMsg::State`] dump from every child. Residual that landed
//! after a child's flush stays in its `r` vector and comes home inside
//! the dump, so the gathered mass balance is exact.
//!
//! Socket mode runs the plain protocol only: no stealing, no top-k
//! serving, §4.2 termination (the quiet-window heuristic needs the
//! shared in-flight register that a process boundary removes).

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use super::codec::{self, WireMsg};
use crate::stream::{power_method_f64, DeltaGraph, PushShard, PushState, ShardedPush};
use crate::termination::{TermMsg, WireMonitor, WorkerTermination};
use crate::Result;

/// Compact the lazily-consumed buffers once the dead prefix passes
/// this.
const COMPACT_AT: usize = 64 * 1024;

/// A nonblocking framed TCP connection: unbounded outbox (neither side
/// may ever block on a write, or hub and child could deadlock feeding
/// each other), lazily compacted inbox, frame reassembly via
/// [`codec::peek`].
struct FrameConn {
    stream: TcpStream,
    rbuf: Vec<u8>,
    rpos: usize,
    wbuf: Vec<u8>,
    wpos: usize,
    eof: bool,
}

impl FrameConn {
    fn new(stream: TcpStream) -> Result<FrameConn> {
        stream.set_nodelay(true)?;
        stream.set_nonblocking(true)?;
        Ok(FrameConn { stream, rbuf: Vec::new(), rpos: 0, wbuf: Vec::new(), wpos: 0, eof: false })
    }

    fn send(&mut self, msg: &WireMsg, dst: u16) -> Result<()> {
        let bytes = codec::encode(msg, dst);
        self.send_raw(&bytes)
    }

    /// Queue one already-encoded frame (the hub's forwarding path) and
    /// push as much as the kernel will take.
    fn send_raw(&mut self, bytes: &[u8]) -> Result<()> {
        self.wbuf.extend_from_slice(bytes);
        self.pump_writes()
    }

    fn pump_writes(&mut self) -> Result<()> {
        while self.wpos < self.wbuf.len() {
            match self.stream.write(&self.wbuf[self.wpos..]) {
                Ok(0) => anyhow::bail!("peer closed the socket mid-write"),
                Ok(k) => self.wpos += k,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e.into()),
            }
        }
        if self.wpos == self.wbuf.len() || self.wpos > COMPACT_AT {
            self.wbuf.drain(..self.wpos);
            self.wpos = 0;
        }
        Ok(())
    }

    fn fill(&mut self) -> Result<()> {
        let mut chunk = [0u8; 16 * 1024];
        loop {
            match self.stream.read(&mut chunk) {
                Ok(0) => {
                    self.eof = true;
                    break;
                }
                Ok(k) => self.rbuf.extend_from_slice(&chunk[..k]),
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) if e.kind() == std::io::ErrorKind::ConnectionReset => {
                    self.eof = true;
                    break;
                }
                Err(e) => return Err(e.into()),
            }
        }
        Ok(())
    }

    /// Next complete frame already in the inbox, as
    /// `(kind, dst, raw frame bytes)` — raw so the hub can forward
    /// without re-encoding.
    fn next_frame(&mut self) -> Result<Option<(u8, u16, Vec<u8>)>> {
        let avail = &self.rbuf[self.rpos..];
        let (kind, dst, total) = match codec::peek(avail) {
            Ok(t) => t,
            Err(codec::WireError::Truncated) => return Ok(None),
            Err(e) => anyhow::bail!("corrupt frame on socket: {e}"),
        };
        if avail.len() < total {
            return Ok(None);
        }
        let bytes = avail[..total].to_vec();
        self.rpos += total;
        if self.rpos == self.rbuf.len() {
            self.rbuf.clear();
            self.rpos = 0;
        } else if self.rpos > COMPACT_AT {
            self.rbuf.drain(..self.rpos);
            self.rpos = 0;
        }
        Ok(Some((kind, dst, bytes)))
    }

    /// One read-side service: pull from the kernel, return every
    /// complete frame (order preserved — this is the FIFO the
    /// termination protocol leans on).
    fn drain_frames(&mut self) -> Result<Vec<(u8, u16, Vec<u8>)>> {
        self.fill()?;
        let mut out = Vec::new();
        while let Some(f) = self.next_frame()? {
            out.push(f);
        }
        Ok(out)
    }

    /// Block (politely) until the outbox is fully on the wire — the
    /// child's final State dump must not be cut off by process exit.
    fn finish(&mut self, deadline: Instant) -> Result<()> {
        while !self.wbuf.is_empty() {
            self.pump_writes()?;
            if self.wbuf.is_empty() {
                break;
            }
            anyhow::ensure!(Instant::now() < deadline, "timed out flushing the socket");
            std::thread::sleep(Duration::from_micros(200));
        }
        Ok(())
    }
}

/// Knobs for a socket-tier run (the `repro net` subcommand).
#[derive(Debug, Clone)]
pub struct SocketRunOptions {
    /// Worker process count (each owns one shard); `[2, 64]`.
    pub shards: usize,
    /// Damping factor.
    pub alpha: f64,
    /// Global residual target.
    pub tol: f64,
    /// Graph/stream seed, forwarded verbatim to every child so all
    /// processes materialize the identical graph.
    pub seed: u64,
    /// Total push budget across all children (split evenly).
    pub max_pushes: u64,
    /// Worker-side §4.2 persistence counter.
    pub pc_max: u32,
    /// Hard wall-clock cap; children are killed when it fires.
    pub timeout: Duration,
}

impl Default for SocketRunOptions {
    fn default() -> Self {
        SocketRunOptions {
            shards: 2,
            alpha: 0.85,
            tol: 1e-10,
            seed: 42,
            max_pushes: u64::MAX,
            pc_max: 3,
            timeout: Duration::from_secs(120),
        }
    }
}

/// What a verified socket-tier run produced.
#[derive(Debug, Clone)]
pub struct SocketRunReport {
    /// Worker process count.
    pub shards: usize,
    /// Graph size.
    pub n: usize,
    /// Total pushes across all children.
    pub pushes: u64,
    /// Exact gathered residual (recomputed, not estimated).
    pub residual: f64,
    /// `|Σp + R/(1-α) - 1|` of the gathered state.
    pub mass_err: f64,
    /// L1 distance of the gathered ranks to a fresh power reference.
    pub l1_vs_power: f64,
    /// §4.2 control messages the driver's monitor processed.
    pub term_messages: u64,
    /// CONVERGE frames downgraded for nonzero in-flight counts.
    pub downgraded: u64,
    /// Wall-clock of the whole run, child spawn included.
    pub wall_ms: f64,
}

/// Cheap convergence telemetry for one warm socket drain
/// (`repro stream --net socket`).
#[derive(Debug, Clone)]
pub struct SocketPushMetrics {
    /// Exact residual of the gathered state.
    pub residual: f64,
    /// `residual < tol` — a protocol STOP should imply it.
    pub converged: bool,
    /// §4.2 control messages the driver's monitor processed.
    pub term_messages: u64,
    /// CONVERGE frames the monitor logged (post-downgrade).
    pub term_converge: u64,
    /// DIVERGE frames the monitor logged (downgrades included).
    pub term_diverge: u64,
    /// CONVERGE frames downgraded for nonzero in-flight counts.
    pub downgraded: u64,
    /// Wall-clock of the drain, child spawn included.
    pub wall_ms: f64,
}

/// Everything the hub needs to spawn and drive one generation of
/// children.
struct DriveSpec<'a> {
    graph_arg: &'a str,
    seed: u64,
    shards: usize,
    alpha: f64,
    tol: f64,
    budget: u64,
    pc_max: u32,
    deadline: Instant,
    timeout_ms: u64,
    /// Pre-built `State` seed frames, one per shard (warm start).
    seeds: Option<Vec<WireMsg>>,
}

/// One child's dense state as it came off the wire.
struct GatheredState {
    lo: u32,
    p: Vec<f64>,
    r: Vec<f64>,
    uni: f64,
    pv: f64,
    pushes: u64,
}

struct DriveOutcome {
    states: Vec<GatheredState>,
    term_messages: u64,
    term_converge: u64,
    term_diverge: u64,
    downgraded: u64,
}

/// Kills any still-running child on every exit path, error or not.
struct ChildGuard {
    children: Vec<Child>,
}

impl Drop for ChildGuard {
    fn drop(&mut self) {
        for c in &mut self.children {
            let _ = c.kill();
            let _ = c.wait();
        }
    }
}

/// Fail fast when a child died with a nonzero status (a clean exit is
/// fine: children exit 0 after dumping state).
fn check_children(guard: &mut ChildGuard) -> Result<()> {
    for (i, c) in guard.children.iter_mut().enumerate() {
        if let Some(status) = c.try_wait()? {
            anyhow::ensure!(status.success(), "net worker {i} exited early with {status}");
        }
    }
    Ok(())
}

fn spawn_children(spec: &DriveSpec<'_>, port: u16) -> Result<ChildGuard> {
    let exe = std::env::current_exe()?;
    let mut children = Vec::with_capacity(spec.shards);
    for i in 0..spec.shards {
        let mut cmd = Command::new(&exe);
        cmd.arg("net-worker")
            .arg("--graph")
            .arg(spec.graph_arg)
            .arg("--seed")
            .arg(spec.seed.to_string())
            .arg("--shard")
            .arg(i.to_string())
            .arg("--shards")
            .arg(spec.shards.to_string())
            .arg("--alpha")
            .arg(format!("{:.17e}", spec.alpha))
            .arg("--tol")
            .arg(format!("{:.17e}", spec.tol))
            .arg("--budget")
            .arg(spec.budget.to_string())
            .arg("--pc-max")
            .arg(spec.pc_max.to_string())
            .arg("--addr")
            .arg(format!("127.0.0.1:{port}"))
            .arg("--timeout-ms")
            .arg(spec.timeout_ms.to_string())
            .stdin(Stdio::null())
            .stdout(Stdio::null())
            .stderr(Stdio::inherit());
        if spec.seeds.is_some() {
            cmd.arg("--seeded");
        }
        children.push(cmd.spawn()?);
    }
    Ok(ChildGuard { children })
}

/// Accept and identify all `shards` children: each opens with a
/// `Hello` naming the shard it owns; placement is by that name, not
/// accept order.
fn handshake(
    listener: &TcpListener,
    guard: &mut ChildGuard,
    n: usize,
    deadline: Instant,
) -> Result<Vec<FrameConn>> {
    let mut placed: Vec<Option<FrameConn>> = (0..n).map(|_| None).collect();
    let mut lobby: Vec<FrameConn> = Vec::new();
    while placed.iter().any(|c| c.is_none()) {
        anyhow::ensure!(
            Instant::now() < deadline,
            "timed out waiting for {} of {n} workers to connect",
            placed.iter().filter(|c| c.is_none()).count()
        );
        check_children(guard)?;
        loop {
            match listener.accept() {
                Ok((s, _)) => lobby.push(FrameConn::new(s)?),
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e.into()),
            }
        }
        let mut i = 0;
        while i < lobby.len() {
            lobby[i].fill()?;
            match lobby[i].next_frame()? {
                Some((_, _, bytes)) => {
                    let (msg, _, _) = codec::decode(&bytes)
                        .map_err(|e| anyhow::anyhow!("handshake frame: {e}"))?;
                    match msg {
                        WireMsg::Hello { shard } => {
                            let sh = shard as usize;
                            anyhow::ensure!(sh < n, "Hello for out-of-range shard {sh}");
                            anyhow::ensure!(placed[sh].is_none(), "duplicate Hello for shard {sh}");
                            // any bytes already behind the Hello stay
                            // queued in the moved connection
                            placed[sh] = Some(lobby.swap_remove(i));
                        }
                        other => anyhow::bail!("handshake: expected Hello, got {other:?}"),
                    }
                }
                None => i += 1,
            }
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    Ok(placed.into_iter().map(|c| c.expect("all placed")).collect())
}

/// Spawn one generation of children and drive the star until every
/// shard's state is home: route data frames by header, feed the
/// monitor-bound control stream through a [`WireMonitor`], run the
/// STOP → flush → ack-drain → dump shutdown sequence.
fn drive(spec: &DriveSpec<'_>) -> Result<DriveOutcome> {
    let n = spec.shards;
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let port = listener.local_addr()?.port();
    listener.set_nonblocking(true)?;
    let mut guard = spawn_children(spec, port)?;
    let mut conns = handshake(&listener, &mut guard, n, spec.deadline)?;
    if let Some(seeds) = &spec.seeds {
        anyhow::ensure!(seeds.len() == n, "seed frame count != shard count");
        for (i, msg) in seeds.iter().enumerate() {
            conns[i].send(msg, i as u16)?;
        }
    }

    let mut wm = WireMonitor::new(n);
    // per-kind tallies of what the monitor actually logged (a downgraded
    // CONVERGE counts as the DIVERGE it became)
    let (mut converge, mut diverge) = (0u64, 0u64);
    // fragments forwarded to a child but not yet acknowledged — the
    // gate between "all flushed" and "safe to dump"
    let mut pending: i64 = 0;
    let mut stop_sent = false;
    let mut dump_sent = false;
    let mut flushed = vec![false; n];
    let mut states: Vec<Option<GatheredState>> = (0..n).map(|_| None).collect();
    loop {
        anyhow::ensure!(
            Instant::now() < spec.deadline,
            "socket run timed out ({} of {n} states gathered, stop_sent={stop_sent})",
            states.iter().filter(|s| s.is_some()).count()
        );
        check_children(&mut guard)?;
        let mut activity = false;
        for i in 0..n {
            conns[i].pump_writes()?;
            let frames = conns[i].drain_frames()?;
            activity |= !frames.is_empty();
            for (kind, dst, bytes) in frames {
                let d = dst as usize;
                if d < n {
                    // shard-to-shard: forward the raw bytes, count
                    // fragments toward the outstanding-ack gate
                    if kind == codec::KIND_FRAG {
                        pending += 1;
                    }
                    conns[d].send_raw(&bytes)?;
                    continue;
                }
                let (msg, _, _) = codec::decode(&bytes)
                    .map_err(|e| anyhow::anyhow!("monitor frame from worker {i}: {e}"))?;
                match msg {
                    WireMsg::Term { src, msg, inflight } => {
                        let nz = inflight.iter().any(|&(_, c)| c > 0);
                        match msg {
                            TermMsg::Converge if nz => diverge += 1,
                            TermMsg::Converge => converge += 1,
                            TermMsg::Diverge => diverge += 1,
                            TermMsg::Stop => {}
                        }
                        if wm.on_message(src as usize, msg, nz) && !stop_sent {
                            stop_sent = true;
                            for j in 0..n {
                                conns[j].send(
                                    &WireMsg::Term {
                                        src: n as u32,
                                        msg: TermMsg::Stop,
                                        inflight: Vec::new(),
                                    },
                                    j as u16,
                                )?;
                            }
                        }
                    }
                    WireMsg::Ack { peer } => {
                        // the receiver's same-stream DIVERGE (if any)
                        // was decoded just above this frame, so the
                        // release below can never outrun the
                        // retraction
                        let p = peer as usize;
                        anyhow::ensure!(p < n, "Ack for out-of-range peer {p}");
                        pending -= 1;
                        conns[p].send(&WireMsg::Ack { peer }, p as u16)?;
                    }
                    WireMsg::Flushed { src } => {
                        let sidx = src as usize;
                        anyhow::ensure!(sidx < n, "Flushed from out-of-range shard {sidx}");
                        flushed[sidx] = true;
                    }
                    WireMsg::State { src, lo, p, r, uni, pv, pushes } => {
                        let sidx = src as usize;
                        anyhow::ensure!(sidx < n, "State from out-of-range shard {sidx}");
                        states[sidx] = Some(GatheredState { lo, p, r, uni, pv, pushes });
                    }
                    other => anyhow::bail!("unexpected monitor-bound frame: {other:?}"),
                }
            }
        }
        if stop_sent && !dump_sent && pending == 0 && flushed.iter().all(|&f| f) {
            dump_sent = true;
            for j in 0..n {
                conns[j].send(&WireMsg::DumpReq, j as u16)?;
            }
        }
        if states.iter().all(|s| s.is_some()) {
            break;
        }
        for (i, c) in conns.iter().enumerate() {
            anyhow::ensure!(
                !c.eof || states[i].is_some(),
                "net worker {i} closed its socket before dumping state"
            );
        }
        if !activity {
            std::thread::sleep(Duration::from_micros(300));
        }
    }
    // children exit on their own after flushing the dump; reap them so
    // a nonzero status (assertion in the child tail) still fails the
    // run
    drop(conns);
    for (i, c) in guard.children.iter_mut().enumerate() {
        loop {
            if let Some(status) = c.try_wait()? {
                anyhow::ensure!(status.success(), "net worker {i} exited with {status}");
                break;
            }
            if Instant::now() >= spec.deadline {
                break; // guard will kill the straggler
            }
            std::thread::sleep(Duration::from_millis(2));
        }
    }
    Ok(DriveOutcome {
        states: states.into_iter().map(|s| s.expect("loop exits all-Some")).collect(),
        term_messages: wm.messages_seen(),
        term_converge: converge,
        term_diverge: diverge,
        downgraded: wm.downgraded(),
    })
}

/// Land the gathered dense states in the driver-side shards — the
/// per-shard `lo` is the tripwire that catches the two sides having
/// partitioned the graph differently.
fn import_states(sp: &mut ShardedPush, states: Vec<GatheredState>) -> Result<()> {
    anyhow::ensure!(states.len() == sp.shard_count(), "gathered state count != shard count");
    for (i, st) in states.into_iter().enumerate() {
        let sh = &mut sp.shards[i];
        let (lo, hi) = sh.rows();
        anyhow::ensure!(
            st.lo as usize == lo && st.p.len() == hi - lo && st.r.len() == hi - lo,
            "child {i} partition bounds diverged (child lo {} len {}, driver [{lo}, {hi}))",
            st.lo,
            st.p.len()
        );
        sh.import_dense(st.p, st.r, st.uni, st.pv, st.pushes);
    }
    Ok(())
}

/// `repro net`: cold multi-process solve plus full verification —
/// exact residual under `tol`, mass balance to 1e-9, L1 agreement with
/// a fresh power reference. Any violated bar is an error (this is the
/// CI smoke's teeth).
pub fn run_net_driver(graph_spec: &str, opts: &SocketRunOptions) -> Result<SocketRunReport> {
    anyhow::ensure!(
        (2..=64).contains(&opts.shards),
        "socket shards {} out of [2, 64] (one process per shard)",
        opts.shards
    );
    anyhow::ensure!((0.0..1.0).contains(&opts.alpha), "alpha {} out of [0,1)", opts.alpha);
    anyhow::ensure!(opts.tol > 0.0, "tol must be positive, got {}", opts.tol);
    let t0 = Instant::now();
    let el = crate::coordinator::load_edgelist(graph_spec, opts.seed)?;
    let g = DeltaGraph::from_edgelist(&el);
    let mut sp = ShardedPush::new(&g, opts.alpha, opts.shards);
    let spec = DriveSpec {
        graph_arg: graph_spec,
        seed: opts.seed,
        shards: opts.shards,
        alpha: opts.alpha,
        tol: opts.tol,
        budget: opts.max_pushes / opts.shards as u64,
        pc_max: opts.pc_max.max(1),
        deadline: t0 + opts.timeout,
        timeout_ms: opts.timeout.as_millis() as u64,
        seeds: None,
    };
    let out = drive(&spec)?;
    import_states(&mut sp, out.states)?;
    let pushes = sp.total_pushes();
    let residual = sp.residual_recompute();
    let mass_err = (sp.mass() - 1.0).abs();
    anyhow::ensure!(
        residual < opts.tol,
        "protocol STOP with gathered residual {residual:.3e} >= tol {:.3e}",
        opts.tol
    );
    anyhow::ensure!(mass_err < 1e-9, "gathered mass off balance by {mass_err:.3e}");
    let (xref, _) = power_method_f64(&g, opts.alpha, opts.tol, 100_000);
    let mut state = PushState::new(g.n(), opts.alpha);
    sp.gather_into(&mut state);
    let l1: f64 = state.ranks().iter().zip(&xref).map(|(a, b)| (a - b).abs()).sum();
    let bar = (2.0 * opts.tol / (1.0 - opts.alpha)).max(1e-8);
    anyhow::ensure!(l1 <= bar, "gathered ranks {l1:.3e} from the power reference (bar {bar:.3e})");
    Ok(SocketRunReport {
        shards: opts.shards,
        n: g.n(),
        pushes,
        residual,
        mass_err,
        l1_vs_power: l1,
        term_messages: out.term_messages,
        downgraded: out.downgraded,
        wall_ms: t0.elapsed().as_secs_f64() * 1e3,
    })
}

/// Warm multi-process drain for `repro stream --net socket`: seed each
/// child with its shard's dense state, run the star to a protocol
/// STOP, land the results back in `state`. `graph_arg` must
/// deterministically materialize the *current* snapshot in the
/// children (the stream driver writes a temp `.bin` per epoch).
pub fn run_socket_push(
    state: &mut ShardedPush,
    graph_arg: &str,
    opts: &SocketRunOptions,
) -> Result<SocketPushMetrics> {
    let n = state.shard_count();
    anyhow::ensure!(opts.shards == n, "socket shards {} != live shard count {n}", opts.shards);
    anyhow::ensure!(
        (state.alpha() - opts.alpha).abs() < 1e-12,
        "socket alpha {} != live state alpha {}",
        opts.alpha,
        state.alpha()
    );
    anyhow::ensure!(n >= 2, "socket mode needs >= 2 shards (one process per shard)");
    let t0 = Instant::now();
    let seeds: Vec<WireMsg> = state
        .shards
        .iter()
        .enumerate()
        .map(|(i, sh)| {
            let (lo, _) = sh.rows();
            let (p, r, uni, pv, pushes) = sh.export_dense();
            WireMsg::State { src: i as u32, lo: lo as u32, p, r, uni, pv, pushes }
        })
        .collect();
    let spec = DriveSpec {
        graph_arg,
        seed: opts.seed,
        shards: n,
        alpha: opts.alpha,
        tol: opts.tol,
        budget: opts.max_pushes / n as u64,
        pc_max: opts.pc_max.max(1),
        deadline: t0 + opts.timeout,
        timeout_ms: opts.timeout.as_millis() as u64,
        seeds: Some(seeds),
    };
    let out = drive(&spec)?;
    import_states(state, out.states)?;
    let residual = state.residual_recompute();
    Ok(SocketPushMetrics {
        residual,
        converged: residual < opts.tol,
        term_messages: out.term_messages,
        term_converge: out.term_converge,
        term_diverge: out.term_diverge,
        downgraded: out.downgraded,
        wall_ms: t0.elapsed().as_secs_f64() * 1e3,
    })
}

/// Parsed `net-worker` arguments (the hidden child subcommand spawned
/// by the driver; not part of the user-facing CLI surface).
#[derive(Debug, Clone)]
pub struct NetWorkerArgs {
    /// Graph spec or file; must materialize the same graph as the
    /// driver's.
    pub graph: String,
    /// Graph seed (determinism tripwire together with `graph`).
    pub seed: u64,
    /// Which shard this process owns.
    pub shard: usize,
    /// Total shard count.
    pub shards: usize,
    /// Damping factor.
    pub alpha: f64,
    /// Global residual target (the local target is derived).
    pub tol: f64,
    /// This child's push budget.
    pub budget: u64,
    /// §4.2 persistence counter.
    pub pc_max: u32,
    /// Driver address, `host:port`.
    pub addr: String,
    /// Wall-clock cap in milliseconds.
    pub timeout_ms: u64,
    /// Wait for a seed `State` frame before solving (warm start).
    pub seeded: bool,
}

fn connect_with_retry(addr: &str, deadline: Instant) -> Result<FrameConn> {
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return FrameConn::new(s),
            Err(e) => {
                anyhow::ensure!(
                    Instant::now() < deadline,
                    "could not reach the driver at {addr}: {e}"
                );
                std::thread::sleep(Duration::from_millis(10));
            }
        }
    }
}

/// Flush every peer-bound outbox: self-directed uniform mass folds
/// back in place, everything else leaves as one fragment per peer
/// (counted against `unacked` until the monitor-routed Ack returns).
fn ship(
    shard: &mut PushShard,
    conn: &mut FrameConn,
    me: u32,
    n: usize,
    unacked: &mut i64,
) -> Result<()> {
    for j in 0..n {
        if j == me as usize {
            shard.absorb_self_uniform();
            continue;
        }
        if let Some(frag) = shard.take_fragment(j) {
            *unacked += 1;
            conn.send(&WireMsg::Frag { src: me, frag }, j as u16)?;
        }
    }
    Ok(())
}

/// Child process body: own one shard of an independently-built
/// [`ShardedPush`], drain/ship/apply against the driver's star, speak
/// the §4.2 protocol over the wire, dump dense state on request.
///
/// The DIVERGE-before-acknowledge discipline lives in the fragment
/// arm: the retraction (if the apply caused one) is written to this
/// child's single TCP stream *before* the `Ack`, and the driver's
/// in-order decode does the rest.
pub fn run_net_worker(a: &NetWorkerArgs) -> Result<()> {
    let n = a.shards;
    anyhow::ensure!(n >= 2 && a.shard < n, "worker shard {}/{n} out of range", a.shard);
    let me = a.shard as u32;
    let mon = n as u16;
    let deadline = Instant::now() + Duration::from_millis(a.timeout_ms.max(1));
    let el = crate::coordinator::load_edgelist(&a.graph, a.seed)?;
    let g = DeltaGraph::from_edgelist(&el);
    let mut sp = ShardedPush::new(&g, a.alpha, n);
    let round_pushes = sp.round_pushes.max(1);
    let mut shard = sp.shards.remove(a.shard);
    drop(sp);

    let mut conn = connect_with_retry(&a.addr, deadline)?;
    conn.send(&WireMsg::Hello { shard: me }, mon)?;
    if a.seeded {
        'seed: loop {
            anyhow::ensure!(
                Instant::now() < deadline,
                "worker {me}: timed out waiting for the seed state"
            );
            conn.pump_writes()?;
            conn.fill()?;
            // single-step, not drain: frames already queued behind the
            // seed must stay in the inbox for the main loop
            if let Some((_, _, bytes)) = conn.next_frame()? {
                let (msg, _, _) =
                    codec::decode(&bytes).map_err(|e| anyhow::anyhow!("worker {me}: {e}"))?;
                match msg {
                    WireMsg::State { lo, p, r, uni, pv, pushes, .. } => {
                        let (slo, shi) = shard.rows();
                        anyhow::ensure!(
                            lo as usize == slo && p.len() == shi - slo && r.len() == shi - slo,
                            "worker {me}: seed state sized to different bounds \
                             (seed lo {lo} len {}, local [{slo}, {shi}))",
                            p.len()
                        );
                        shard.import_dense(p, r, uni, pv, pushes);
                        break 'seed;
                    }
                    other => anyhow::bail!("worker {me}: expected the seed state, got {other:?}"),
                }
            }
            anyhow::ensure!(!conn.eof, "worker {me}: driver closed the socket during seeding");
            std::thread::sleep(Duration::from_micros(200));
        }
    }

    let local_target = 0.5 * a.tol / n as f64;
    let mut term = WorkerTermination::new(a.pc_max.max(1));
    let mut unacked: i64 = 0;
    let mut stopping = false;
    let mut flushed_sent = false;
    let p0 = shard.pushes();
    loop {
        anyhow::ensure!(Instant::now() < deadline, "worker {me}: run deadline exceeded");
        conn.pump_writes()?;
        let frames = conn.drain_frames()?;
        let received = !frames.is_empty();
        let mut dump = false;
        for (_, _, bytes) in frames {
            let (msg, _, _) =
                codec::decode(&bytes).map_err(|e| anyhow::anyhow!("worker {me}: {e}"))?;
            match msg {
                WireMsg::Frag { src, frag } => {
                    shard.apply_fragment(&frag);
                    // retract BEFORE acknowledging, on the same stream
                    if let Some(m) = term.on_iteration(false) {
                        conn.send(&WireMsg::Term { src: me, msg: m, inflight: Vec::new() }, mon)?;
                    }
                    conn.send(&WireMsg::Ack { peer: src }, mon)?;
                }
                WireMsg::Ack { .. } => unacked -= 1,
                WireMsg::Term { msg: TermMsg::Stop, .. } => stopping = true,
                WireMsg::Term { .. } => {}
                WireMsg::DumpReq => dump = true,
                other => anyhow::bail!("worker {me}: unexpected frame {other:?}"),
            }
        }
        if dump {
            break;
        }
        if stopping {
            // one last flush (normally empty: every drain below ships
            // in the same iteration); then keep applying and acking
            // peers' flushes until the driver asks for the dump
            ship(&mut shard, &mut conn, me, n, &mut unacked)?;
            if !flushed_sent {
                conn.send(&WireMsg::Flushed { src: me }, mon)?;
                flushed_sent = true;
            }
            if !received {
                std::thread::sleep(Duration::from_micros(200));
            }
            continue;
        }
        let spent = shard.pushes() - p0;
        let pushed =
            shard.drain(&g, local_target, round_pushes.min(a.budget.saturating_sub(spent)));
        ship(&mut shard, &mut conn, me, n, &mut unacked)?;
        let estimate = shard.residual_estimate();
        if let Some(m) = term.on_iteration(estimate < a.tol / n as f64 && unacked == 0) {
            // the same `unacked` the predicate read: an honest
            // CONVERGE always ships an empty in-flight vector, so the
            // monitor's downgrade can only hit contradictory frames
            let inflight = if unacked > 0 { vec![(me, unacked as u64)] } else { Vec::new() };
            conn.send(&WireMsg::Term { src: me, msg: m, inflight }, mon)?;
        }
        if pushed == 0 && !received {
            std::thread::sleep(Duration::from_micros(200));
        }
    }

    let (lo, _) = shard.rows();
    let (p, r, uni, pv, pushes) = shard.export_dense();
    conn.send(&WireMsg::State { src: me, lo: lo as u32, p, r, uni, pv, pushes }, mon)?;
    conn.finish(deadline)?;
    Ok(())
}
