//! Message transports: the trait the threaded push backend drives, and
//! the in-process loopback implementation throttled by a
//! [`ClusterProfile`] with a deterministic fault injector.
//!
//! # Per-producer FIFO is load-bearing
//!
//! The §4.2 termination argument needs exactly one ordering guarantee
//! from the network: messages from one producer to one consumer arrive
//! in send order (a DIVERGE enqueued before an acknowledgement is
//! processed before any CONVERGE the acknowledgement enables). The
//! loopback enforces it structurally — each `(src, dst)` link keeps a
//! single queue whose delivery horizon only moves forward, so delay,
//! jitter, and stalls reorder traffic *across* links but never within
//! one. Everything else (arbitrary cross-link delay, loss-free
//! deferral) matches the asynchronous model of the paper's §3.
//!
//! # Fault injector semantics
//!
//! * **Link delay/jitter** ([`LinkFault`]) — every frame on a matching
//!   link pays a fixed extra delay plus a uniform draw in
//!   `[0, jitter)`; draws come from a per-link [`Rng`] seeded from the
//!   run seed, so a rerun injects the identical schedule.
//! * **Peer stall** ([`PeerStall`]) — deliveries *into* the stalled
//!   peer that would land inside the window are pushed to its end (the
//!   peer's NIC went quiet; nothing is lost).
//! * **Disconnect** ([`LinkDown`]) — *data* sends on the link inside
//!   the window fail with [`SendFail::Down`]; the sender defers exactly
//!   as it would for a full channel and retries after reconnect. Frames
//!   already in flight still deliver (they left before the cut).
//!   Control frames (termination verbs, acknowledgements) pass through
//!   disconnects: the control wire is reliable-but-slow, mirroring the
//!   unbounded in-process channel whose sends never fail — a dropped
//!   DIVERGE or Ack would silently corrupt the in-flight accounting
//!   the STOP guarantee rests on.
//!
//! Faults shift *when* a frame arrives, never *whether* — combined
//! with the sender-side restore discipline, no unit of residual mass
//! is ever dropped, which is what keeps Σp + R/(1−α) = Σv exact under
//! any injected schedule.

use std::collections::VecDeque;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::codec::{self, WireMsg};
use crate::simnet::ClusterProfile;
use crate::util::Rng;

/// Why a non-blocking send did not go through. The message comes back
/// to the caller, who restores its mass into the local shard — the
/// same deferral discipline the bounded mpsc channels use.
#[derive(Debug)]
pub enum SendFail {
    /// The link's data queue is at capacity; retry after draining.
    Full(WireMsg),
    /// The link is inside an injected disconnect window.
    Down(WireMsg),
}

/// A non-blocking, per-producer-FIFO message fabric between a fixed
/// set of endpoints. Implemented by the throttled in-process loopback
/// ([`LoopbackEndpoint`]) and by the socket tier's TCP endpoint
/// (`net::proc`).
pub trait Transport {
    /// Try to send toward endpoint `dst`; on failure the message is
    /// handed back for deferral.
    fn try_send(&mut self, dst: usize, msg: WireMsg) -> Result<(), SendFail>;
    /// Next deliverable message addressed to this endpoint, if any.
    fn try_recv(&mut self) -> Option<WireMsg>;
    /// Drop all throttling: everything queued anywhere becomes
    /// deliverable immediately (the end-of-run gather must not wait
    /// out injected delays).
    fn flush(&mut self);
}

/// Extra delay on matching links. `None` matches every endpoint.
#[derive(Debug, Clone, Copy)]
pub struct LinkFault {
    /// Sending endpoint filter.
    pub src: Option<usize>,
    /// Receiving endpoint filter.
    pub dst: Option<usize>,
    /// Fixed extra seconds per frame.
    pub delay: f64,
    /// Uniform extra seconds in `[0, jitter)` per frame.
    pub jitter: f64,
}

/// A window during which one peer stops taking delivery.
#[derive(Debug, Clone, Copy)]
pub struct PeerStall {
    /// The stalled endpoint.
    pub peer: usize,
    /// Window start, seconds after the net is created.
    pub start: f64,
    /// Window length, seconds.
    pub duration: f64,
}

/// A window during which one directed link refuses sends.
#[derive(Debug, Clone, Copy)]
pub struct LinkDown {
    /// Sending endpoint.
    pub src: usize,
    /// Receiving endpoint.
    pub dst: usize,
    /// Window start, seconds after the net is created.
    pub start: f64,
    /// Window length, seconds.
    pub duration: f64,
}

/// The deterministic fault schedule for one run.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    /// Per-link delay/jitter.
    pub link_faults: Vec<LinkFault>,
    /// Peer stall windows.
    pub stalls: Vec<PeerStall>,
    /// Disconnect/reconnect windows.
    pub disconnects: Vec<LinkDown>,
}

impl FaultPlan {
    /// A plan that delays every link out of `peer` by `delay_ms` with
    /// uniform jitter in `[0, jitter_ms)` — the `--inject-link`
    /// L:MS:JITTER CLI shape.
    pub fn delay_from(peer: usize, delay_ms: f64, jitter_ms: f64) -> FaultPlan {
        FaultPlan {
            link_faults: vec![LinkFault {
                src: Some(peer),
                dst: None,
                delay: delay_ms * 1e-3,
                jitter: jitter_ms * 1e-3,
            }],
            ..FaultPlan::default()
        }
    }

    fn penalty(&self, src: usize, dst: usize) -> (f64, f64) {
        let mut delay = 0.0;
        let mut jitter = 0.0;
        for f in &self.link_faults {
            if f.src.map_or(true, |s| s == src) && f.dst.map_or(true, |d| d == dst) {
                delay += f.delay;
                jitter += f.jitter;
            }
        }
        (delay, jitter)
    }

    fn down(&self, src: usize, dst: usize, elapsed: f64) -> bool {
        self.disconnects.iter().any(|d| {
            d.src == src && d.dst == dst && elapsed >= d.start && elapsed < d.start + d.duration
        })
    }

    /// Push a delivery time (seconds since net start) into `dst` past
    /// any stall window it lands in.
    fn stall_adjust(&self, dst: usize, mut at: f64) -> f64 {
        for s in &self.stalls {
            if s.peer == dst && at >= s.start && at < s.start + s.duration {
                at = s.start + s.duration;
            }
        }
        at
    }
}

/// Everything `run_threaded_push` needs to route its exchange over a
/// transport instead of mpsc channels.
#[derive(Debug, Clone)]
pub struct NetConfig {
    /// Bandwidth/latency curves throttling the loopback.
    pub profile: ClusterProfile,
    /// Deterministic fault schedule.
    pub faults: FaultPlan,
    /// Seed for the per-link jitter streams.
    pub seed: u64,
}

impl NetConfig {
    /// A fast-wire, fault-free config for tests.
    pub fn test(endpoints: usize) -> NetConfig {
        NetConfig {
            profile: ClusterProfile::test_profile(endpoints),
            faults: FaultPlan::default(),
            seed: 42,
        }
    }
}

struct LinkQueue {
    /// `(deliver_at, counts toward data cap, encoded frame)`.
    q: VecDeque<(Instant, bool, Vec<u8>)>,
    /// Delivery horizon: the last enqueued frame's deliver_at. New
    /// frames never deliver before it — this is the per-producer FIFO.
    horizon: Option<Instant>,
    /// Jitter stream for this link.
    rng: Rng,
    /// Frames currently queued that count toward the data cap.
    data_queued: usize,
}

struct NetState {
    links: Vec<LinkQueue>,
    flushed: bool,
}

struct Shared {
    eps: usize,
    data_cap: usize,
    profile: ClusterProfile,
    faults: FaultPlan,
    start: Instant,
    state: Mutex<NetState>,
}

/// The throttled in-process fabric. One instance backs all endpoints
/// of a run; hand each worker (and the monitor) its
/// [`endpoint`](LoopbackNet::endpoint).
pub struct LoopbackNet {
    shared: Arc<Shared>,
}

/// Data frames occupy bounded queue slots (they carry mass and are
/// deferred when full); control frames ride unbounded, mirroring the
/// unbounded in-process termination channel — see
/// `termination::channel` for why boundedness would break the STOP
/// guarantee.
fn counts_toward_cap(msg: &WireMsg) -> bool {
    matches!(
        msg,
        WireMsg::Frag { .. }
            | WireMsg::Grant { .. }
            | WireMsg::StealRequest { .. }
            | WireMsg::HeadFrame { .. }
    )
}

impl LoopbackNet {
    /// A fabric of `endpoints` endpoints (workers plus monitor) with
    /// room for `data_cap` queued data frames per link.
    pub fn new(endpoints: usize, cfg: &NetConfig, data_cap: usize) -> LoopbackNet {
        let links = (0..endpoints * endpoints)
            .map(|i| {
                let (src, dst) = (i / endpoints, i % endpoints);
                let tag = (((src as u64) << 20) | dst as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
                LinkQueue {
                    q: VecDeque::new(),
                    horizon: None,
                    rng: Rng::new(cfg.seed ^ tag),
                    data_queued: 0,
                }
            })
            .collect();
        LoopbackNet {
            shared: Arc::new(Shared {
                eps: endpoints,
                data_cap: data_cap.max(1),
                profile: cfg.profile.clone(),
                faults: cfg.faults.clone(),
                start: Instant::now(),
                state: Mutex::new(NetState { links, flushed: false }),
            }),
        }
    }

    /// The sending/receiving handle for endpoint `id`.
    pub fn endpoint(&self, id: usize) -> LoopbackEndpoint {
        assert!(id < self.shared.eps, "endpoint {id} out of range");
        LoopbackEndpoint { shared: Arc::clone(&self.shared), id }
    }
}

/// One endpoint's handle on a [`LoopbackNet`].
pub struct LoopbackEndpoint {
    shared: Arc<Shared>,
    id: usize,
}

impl Transport for LoopbackEndpoint {
    fn try_send(&mut self, dst: usize, msg: WireMsg) -> Result<(), SendFail> {
        let sh = &self.shared;
        assert!(dst < sh.eps, "destination {dst} out of range");
        let now = Instant::now();
        let elapsed = now.duration_since(sh.start).as_secs_f64();
        let data = counts_toward_cap(&msg);
        if data && sh.faults.down(self.id, dst, elapsed) {
            return Err(SendFail::Down(msg));
        }
        let mut st = sh.state.lock().unwrap();
        let link = &mut st.links[self.id * sh.eps + dst];
        if data && link.data_queued >= sh.data_cap {
            // head frames are tentative snapshots — a fresher one is
            // always coming, so a full link just drops this one
            if matches!(msg, WireMsg::HeadFrame { .. }) {
                return Ok(());
            }
            return Err(SendFail::Full(msg));
        }
        let bytes = codec::encode(&msg, dst as u16);
        let (delay, jitter) = sh.faults.penalty(self.id, dst);
        let mut secs = sh.profile.wire_time(bytes.len() as f64) + delay;
        if jitter > 0.0 {
            secs += jitter * link.rng.f64();
        }
        let base = match link.horizon {
            Some(h) if h > now => h,
            _ => now,
        };
        let mut at = base + Duration::from_secs_f64(secs.max(0.0));
        let at_el = at.duration_since(sh.start).as_secs_f64();
        let adj = sh.faults.stall_adjust(dst, at_el);
        if adj > at_el {
            at = sh.start + Duration::from_secs_f64(adj);
        }
        link.horizon = Some(at);
        if data {
            link.data_queued += 1;
        }
        link.q.push_back((at, data, bytes));
        Ok(())
    }

    fn try_recv(&mut self) -> Option<WireMsg> {
        let sh = &self.shared;
        let now = Instant::now();
        let mut st = sh.state.lock().unwrap();
        let flushed = st.flushed;
        // earliest deliverable frame across all inbound links; ties
        // break on source index so replays are stable
        let mut best: Option<(Instant, usize)> = None;
        for src in 0..sh.eps {
            let link = &st.links[src * sh.eps + self.id];
            if let Some(&(at, _, _)) = link.q.front() {
                if (flushed || at <= now) && best.map_or(true, |(b, _)| at < b) {
                    best = Some((at, src));
                }
            }
        }
        let (_, src) = best?;
        let link = &mut st.links[src * sh.eps + self.id];
        let (_, data, bytes) = link.q.pop_front().unwrap();
        if data {
            link.data_queued -= 1;
        }
        drop(st);
        let (msg, dst, _) = codec::decode(&bytes).expect("loopback frame must decode");
        debug_assert_eq!(dst as usize, self.id);
        Some(msg)
    }

    fn flush(&mut self) {
        self.shared.state.lock().unwrap().flushed = true;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stream::ResidualFragment;
    use crate::termination::TermMsg;

    fn frag_msg(src: u32, tag: u32) -> WireMsg {
        WireMsg::Frag {
            src,
            frag: ResidualFragment { entries: vec![(tag, 1e-6)], uni: 0.0, pv: 0.0 },
        }
    }

    fn tag_of(msg: &WireMsg) -> u32 {
        match msg {
            WireMsg::Frag { frag, .. } => frag.entries[0].0,
            other => panic!("expected frag, got {other:?}"),
        }
    }

    #[test]
    fn per_link_fifo_survives_heavy_jitter() {
        let mut cfg = NetConfig::test(2);
        cfg.faults.link_faults.push(LinkFault {
            src: Some(0),
            dst: Some(1),
            delay: 0.0,
            jitter: 0.050,
        });
        let net = LoopbackNet::new(2, &cfg, 256);
        let mut tx = net.endpoint(0);
        let mut rx = net.endpoint(1);
        for i in 0..100u32 {
            tx.try_send(1, frag_msg(0, i)).unwrap();
        }
        rx.flush();
        for want in 0..100u32 {
            let got = rx.try_recv().expect("flushed frame must deliver");
            assert_eq!(tag_of(&got), want, "per-producer FIFO violated");
        }
        assert!(rx.try_recv().is_none());
    }

    #[test]
    fn injected_delay_holds_frames_until_flush() {
        let mut cfg = NetConfig::test(2);
        cfg.faults = FaultPlan::delay_from(0, 10_000.0, 0.0);
        let net = LoopbackNet::new(2, &cfg, 16);
        let mut tx = net.endpoint(0);
        let mut rx = net.endpoint(1);
        tx.try_send(1, frag_msg(0, 7)).unwrap();
        assert!(rx.try_recv().is_none(), "10s injected delay must hold the frame");
        rx.flush();
        assert_eq!(tag_of(&rx.try_recv().unwrap()), 7);
    }

    #[test]
    fn full_link_defers_data_but_not_control() {
        let cfg = NetConfig::test(2);
        let net = LoopbackNet::new(2, &cfg, 2);
        let mut tx = net.endpoint(0);
        tx.try_send(1, frag_msg(0, 0)).unwrap();
        tx.try_send(1, frag_msg(0, 1)).unwrap();
        match tx.try_send(1, frag_msg(0, 2)) {
            Err(SendFail::Full(WireMsg::Frag { frag, .. })) => {
                assert_eq!(frag.entries[0].0, 2, "the deferred frag comes back intact");
            }
            other => panic!("expected Full, got {other:?}"),
        }
        // control rides unbounded past a full data queue
        tx.try_send(1, WireMsg::Term { src: 0, msg: TermMsg::Diverge, inflight: vec![] })
            .unwrap();
        // tentative head frames are droppable, not deferrable
        let hf = WireMsg::HeadFrame {
            src: 0,
            gen: 0,
            frame: super::super::codec::WireHeadFrame {
                entries: vec![],
                rest_bound: f64::NEG_INFINITY,
                r_plus: 0.0,
                r_minus: 0.0,
                unk_plus: 0.0,
                unk_minus: 0.0,
            },
        };
        assert!(tx.try_send(1, hf).is_ok());
    }

    #[test]
    fn disconnect_window_bounces_sends_then_recovers() {
        let mut cfg = NetConfig::test(2);
        cfg.faults.disconnects.push(LinkDown { src: 0, dst: 1, start: 0.0, duration: 0.05 });
        let net = LoopbackNet::new(2, &cfg, 16);
        let mut tx = net.endpoint(0);
        let mut rx = net.endpoint(1);
        match tx.try_send(1, frag_msg(0, 3)) {
            Err(SendFail::Down(msg)) => assert_eq!(tag_of(&msg), 3),
            other => panic!("expected Down, got {other:?}"),
        }
        std::thread::sleep(Duration::from_millis(60));
        tx.try_send(1, frag_msg(0, 3)).expect("reconnected");
        rx.flush();
        assert_eq!(tag_of(&rx.try_recv().unwrap()), 3);
    }

    #[test]
    fn stall_window_pushes_delivery_past_its_end() {
        let mut cfg = NetConfig::test(2);
        cfg.faults.stalls.push(PeerStall { peer: 1, start: 0.0, duration: 0.08 });
        let net = LoopbackNet::new(2, &cfg, 16);
        let mut tx = net.endpoint(0);
        let mut rx = net.endpoint(1);
        tx.try_send(1, frag_msg(0, 9)).unwrap();
        assert!(rx.try_recv().is_none(), "delivery inside the stall window");
        std::thread::sleep(Duration::from_millis(100));
        assert_eq!(tag_of(&rx.try_recv().unwrap()), 9, "stall over, frame lands");
    }

    #[test]
    fn deterministic_jitter_schedule_for_seed() {
        let mut cfg = NetConfig::test(2);
        cfg.faults.link_faults.push(LinkFault {
            src: None,
            dst: None,
            delay: 0.0,
            jitter: 0.5,
        });
        // same seed => identical per-link draw sequence; we can't
        // observe Instants directly, so compare the rng streams the
        // links were seeded with
        let tag = 0u64.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut a = Rng::new(cfg.seed ^ tag);
        let mut b = Rng::new(cfg.seed ^ tag);
        for _ in 0..32 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }
}
