//! Process-boundary transport for the asynchronous push exchange.
//!
//! Everything the threaded backend moves between shards — residual
//! fragments, steal traffic, top-k head frames, §4.2 termination
//! control — crosses this module as versioned binary frames
//! ([`codec`]), carried by a [`Transport`]:
//!
//! * [`LoopbackNet`] — in-process, throttled by a
//!   [`crate::simnet::ClusterProfile`]'s bandwidth/latency curves with
//!   a deterministic fault injector ([`FaultPlan`]): per-link
//!   delay/jitter, peer stalls, disconnect/reconnect. Surfaced as
//!   `repro stream --net loopback`.
//! * the socket tier ([`proc`]) — one OS process per shard, spawned
//!   and star-routed by a parent driver (`repro net`, and
//!   `repro stream --net socket`).
//!
//! Per-producer FIFO is the one property both transports guarantee,
//! because the termination protocol's STOP soundness depends on it —
//! see the [`transport`] module docs and ARCHITECTURE.md's
//! "process boundary" section.

pub mod codec;
pub mod proc;
pub mod transport;

pub use codec::{WireError, WireHeadFrame, WireMsg, WireRow};
pub use proc::{
    run_net_driver, run_net_worker, run_socket_push, NetWorkerArgs, SocketPushMetrics,
    SocketRunOptions, SocketRunReport,
};
pub use transport::{
    FaultPlan, LinkDown, LinkFault, LoopbackEndpoint, LoopbackNet, NetConfig, PeerStall, SendFail,
    Transport,
};
