//! Versioned, zero-dependency binary wire codec for the inter-shard
//! message set.
//!
//! Everything the threaded push backend moves between shards — residual
//! fragments, steal requests/grants, top-k head frames, and the §4.2
//! termination control messages with their per-origin in-flight counts
//! — has a frame here, so the same worker loop can run over an in-
//! process channel or a byte stream without changing the protocol.
//!
//! # Frame layout
//!
//! ```text
//! offset  size  field
//! 0       2     magic 0xA5 0x50
//! 2       1     version (currently 1)
//! 3       1     message kind
//! 4       2     destination endpoint, u16 LE (routers forward on this
//!               without decoding the payload)
//! 6       4     payload length, u32 LE
//! 10      len   payload (kind-specific, little-endian scalars)
//! 10+len  4     FNV-1a-32 checksum over bytes [0, 10+len), u32 LE
//! ```
//!
//! The decoder is total: any byte string either yields a message or a
//! [`WireError`] — truncation, bad magic/version/kind, checksum
//! mismatch, and NaN-carrying mass fields are all rejected without
//! panicking (a corrupted fragment must not poison a shard's residual
//! accounting with NaN, which would otherwise propagate through every
//! later mass tally). `±inf` is legal only where the protocol
//! legitimately produces it (a head frame's `rest_bound` is `-inf`
//! when the pool covers the whole shard).

use crate::stream::ResidualFragment;
use crate::termination::TermMsg;

/// Wire protocol version this build speaks.
pub const WIRE_VERSION: u8 = 1;
/// Two magic bytes opening every frame.
pub const WIRE_MAGIC: [u8; 2] = [0xA5, 0x50];
/// Fixed header length (magic + version + kind + dst + payload len).
pub const HEADER_LEN: usize = 10;
/// Trailing checksum length.
pub const TRAILER_LEN: usize = 4;

/// One row of a steal grant on the wire — the mirror of the crate-
/// private `StolenRow` (full per-row solver state plus the
/// touched-row accounting bit that migrates with the row).
#[derive(Debug, Clone, PartialEq)]
pub struct WireRow {
    /// Global node id.
    pub node: u32,
    /// Settled probability mass.
    pub p: f64,
    /// Queued residual mass.
    pub r: f64,
    /// Whether the row already counted toward this epoch's touched set.
    pub touched: bool,
}

/// A top-k head frame on the wire — the mirror of the crate-private
/// `ShardHeadFrame` snapshot the monitor certifies against.
#[derive(Debug, Clone, PartialEq)]
pub struct WireHeadFrame {
    /// (global node id, score center) for every pool member.
    pub entries: Vec<(u32, f64)>,
    /// Center upper bound for rows outside `entries` (`-inf` when the
    /// pool covers the whole shard).
    pub rest_bound: f64,
    /// Located-residual split, positive side.
    pub r_plus: f64,
    /// Located-residual split, negative side.
    pub r_minus: f64,
    /// Unlocated-residual split, positive side.
    pub unk_plus: f64,
    /// Unlocated-residual split, negative side.
    pub unk_minus: f64,
}

/// The full inter-shard message set.
#[derive(Debug, Clone)]
pub enum WireMsg {
    /// A residual fragment from shard `src` (additive state in flight;
    /// an undeliverable frame is restored, never dropped).
    Frag {
        /// Originating shard.
        src: u32,
        /// The fragment payload.
        frag: ResidualFragment,
    },
    /// An idle shard asking a loaded peer for rows.
    StealRequest {
        /// The requesting shard.
        thief: u32,
    },
    /// A batch of rows granted to a thief by victim `src`.
    Grant {
        /// The victim shard.
        src: u32,
        /// The migrating rows.
        rows: Vec<WireRow>,
    },
    /// A tentative top-k head snapshot from shard `src`, stamped with
    /// the steal generation it was built under. Once frames cross a
    /// delayed wire, the shared mutex trick the in-process monitor uses
    /// (clear-on-migration) no longer works; the generation stamp is
    /// what lets the monitor reject a frame built before a row
    /// migration that is only delivered after it.
    HeadFrame {
        /// Originating shard.
        src: u32,
        /// Steal generation at snapshot time.
        gen: u64,
        /// The snapshot.
        frame: WireHeadFrame,
    },
    /// A §4.2 termination control message from worker `src`, carrying
    /// the per-origin in-flight counts that must survive serialization
    /// (a CONVERGE is only credible while every listed count is zero;
    /// the monitor downgrades anything else).
    Term {
        /// Originating worker.
        src: u32,
        /// CONVERGE / DIVERGE / STOP.
        msg: TermMsg,
        /// `(origin, outstanding sends)` pairs; omitted entries are 0.
        inflight: Vec<(u32, u64)>,
    },
    /// Socket handshake: a child announcing which shard it serves.
    Hello {
        /// The shard index this process owns.
        shard: u32,
    },
    /// Socket acknowledgement: the receiver applied one fragment that
    /// `peer` originated (releases one unit of `peer`'s in-flight
    /// accounting; always enqueued *after* any DIVERGE the apply
    /// provoked, on the same stream).
    Ack {
        /// The fragment's originator.
        peer: u32,
    },
    /// Socket shutdown: worker `src` has emptied its outboxes after
    /// STOP.
    Flushed {
        /// Originating worker.
        src: u32,
    },
    /// Socket shutdown: the driver requesting a full state dump.
    DumpReq,
    /// Socket state transfer: the dense per-shard solver state, used to
    /// seed a warm child and to gather results at shutdown.
    State {
        /// The shard this state belongs to.
        src: u32,
        /// First global row of the shard's home range — both sides
        /// partition the graph independently, so this is the tripwire
        /// that catches a bounds mismatch before mass lands in the
        /// wrong rows.
        lo: u32,
        /// Settled mass per local row.
        p: Vec<f64>,
        /// Queued residual per local row.
        r: Vec<f64>,
        /// Pending uniform broadcast mass.
        uni: f64,
        /// Pending personalization mass.
        pv: f64,
        /// Pushes performed by this shard.
        pushes: u64,
    },
}

/// Why a frame failed to decode.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WireError {
    /// The buffer ends before the frame does (on a stream: wait for
    /// more bytes).
    Truncated,
    /// The first two bytes are not [`WIRE_MAGIC`].
    BadMagic,
    /// Version byte from a build we do not speak.
    BadVersion(u8),
    /// Unknown message kind byte.
    BadKind(u8),
    /// Checksum mismatch (corrupt frame).
    BadChecksum,
    /// A mass-carrying f64 field decoded to NaN.
    NanMass,
    /// Structurally invalid payload for the declared kind.
    Malformed(&'static str),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Truncated => write!(f, "truncated frame"),
            WireError::BadMagic => write!(f, "bad frame magic"),
            WireError::BadVersion(v) => write!(f, "unsupported wire version {v}"),
            WireError::BadKind(k) => write!(f, "unknown message kind {k}"),
            WireError::BadChecksum => write!(f, "frame checksum mismatch"),
            WireError::NanMass => write!(f, "NaN in a mass field"),
            WireError::Malformed(what) => write!(f, "malformed payload: {what}"),
        }
    }
}

impl std::error::Error for WireError {}

pub(crate) const KIND_FRAG: u8 = 0;
const KIND_STEAL_REQUEST: u8 = 1;
const KIND_GRANT: u8 = 2;
const KIND_HEAD_FRAME: u8 = 3;
const KIND_TERM: u8 = 4;
const KIND_HELLO: u8 = 5;
const KIND_ACK: u8 = 6;
const KIND_FLUSHED: u8 = 7;
const KIND_DUMP_REQ: u8 = 8;
const KIND_STATE: u8 = 9;

#[inline]
fn fnv1a32(bytes: &[u8]) -> u32 {
    let mut h: u32 = 0x811C_9DC5;
    for &b in bytes {
        h ^= b as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

#[inline]
fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

#[inline]
fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

#[inline]
fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_bits().to_le_bytes());
}

/// Cursor over a payload slice; every read is bounds-checked so a
/// truncated or lying length field surfaces as an error, not a panic.
struct Cur<'a> {
    buf: &'a [u8],
    at: usize,
}

impl<'a> Cur<'a> {
    fn new(buf: &'a [u8]) -> Cur<'a> {
        Cur { buf, at: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        let end = self.at.checked_add(n).ok_or(WireError::Malformed("length overflow"))?;
        if end > self.buf.len() {
            return Err(WireError::Malformed("payload shorter than declared contents"));
        }
        let s = &self.buf[self.at..end];
        self.at = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// An f64 that must be finite-or-infinite, never NaN.
    fn mass(&mut self) -> Result<f64, WireError> {
        let v = f64::from_bits(u64::from_le_bytes(self.take(8)?.try_into().unwrap()));
        if v.is_nan() {
            return Err(WireError::NanMass);
        }
        Ok(v)
    }

    /// Element count for a repeated section of `elem_bytes` each —
    /// rejected up front when the remaining payload cannot hold it, so
    /// a hostile count cannot trigger a huge allocation.
    fn count(&mut self, elem_bytes: usize) -> Result<usize, WireError> {
        let n = self.u32()? as usize;
        let need = n.checked_mul(elem_bytes).ok_or(WireError::Malformed("count overflow"))?;
        if self.at.checked_add(need).map_or(true, |end| end > self.buf.len()) {
            return Err(WireError::Malformed("count exceeds payload"));
        }
        Ok(n)
    }

    fn done(&self) -> Result<(), WireError> {
        if self.at != self.buf.len() {
            return Err(WireError::Malformed("trailing bytes in payload"));
        }
        Ok(())
    }
}

fn term_byte(msg: TermMsg) -> u8 {
    match msg {
        TermMsg::Converge => 0,
        TermMsg::Diverge => 1,
        TermMsg::Stop => 2,
    }
}

fn term_from(b: u8) -> Result<TermMsg, WireError> {
    match b {
        0 => Ok(TermMsg::Converge),
        1 => Ok(TermMsg::Diverge),
        2 => Ok(TermMsg::Stop),
        _ => Err(WireError::Malformed("unknown termination verb")),
    }
}

fn payload(msg: &WireMsg) -> (u8, Vec<u8>) {
    let mut out = Vec::new();
    let kind = match msg {
        WireMsg::Frag { src, frag } => {
            put_u32(&mut out, *src);
            put_f64(&mut out, frag.uni);
            put_f64(&mut out, frag.pv);
            put_u32(&mut out, frag.entries.len() as u32);
            for &(node, mass) in &frag.entries {
                put_u32(&mut out, node);
                put_f64(&mut out, mass);
            }
            KIND_FRAG
        }
        WireMsg::StealRequest { thief } => {
            put_u32(&mut out, *thief);
            KIND_STEAL_REQUEST
        }
        WireMsg::Grant { src, rows } => {
            put_u32(&mut out, *src);
            put_u32(&mut out, rows.len() as u32);
            for row in rows {
                put_u32(&mut out, row.node);
                put_f64(&mut out, row.p);
                put_f64(&mut out, row.r);
                out.push(row.touched as u8);
            }
            KIND_GRANT
        }
        WireMsg::HeadFrame { src, gen, frame } => {
            put_u32(&mut out, *src);
            put_u64(&mut out, *gen);
            put_f64(&mut out, frame.rest_bound);
            put_f64(&mut out, frame.r_plus);
            put_f64(&mut out, frame.r_minus);
            put_f64(&mut out, frame.unk_plus);
            put_f64(&mut out, frame.unk_minus);
            put_u32(&mut out, frame.entries.len() as u32);
            for &(node, center) in &frame.entries {
                put_u32(&mut out, node);
                put_f64(&mut out, center);
            }
            KIND_HEAD_FRAME
        }
        WireMsg::Term { src, msg, inflight } => {
            put_u32(&mut out, *src);
            out.push(term_byte(*msg));
            put_u32(&mut out, inflight.len() as u32);
            for &(origin, count) in inflight {
                put_u32(&mut out, origin);
                put_u64(&mut out, count);
            }
            KIND_TERM
        }
        WireMsg::Hello { shard } => {
            put_u32(&mut out, *shard);
            KIND_HELLO
        }
        WireMsg::Ack { peer } => {
            put_u32(&mut out, *peer);
            KIND_ACK
        }
        WireMsg::Flushed { src } => {
            put_u32(&mut out, *src);
            KIND_FLUSHED
        }
        WireMsg::DumpReq => KIND_DUMP_REQ,
        WireMsg::State { src, lo, p, r, uni, pv, pushes } => {
            put_u32(&mut out, *src);
            put_u32(&mut out, *lo);
            put_f64(&mut out, *uni);
            put_f64(&mut out, *pv);
            put_u64(&mut out, *pushes);
            put_u32(&mut out, p.len() as u32);
            for &v in p {
                put_f64(&mut out, v);
            }
            put_u32(&mut out, r.len() as u32);
            for &v in r {
                put_f64(&mut out, v);
            }
            KIND_STATE
        }
    };
    (kind, out)
}

/// Encode one message into a self-delimiting frame addressed to
/// endpoint `dst`.
pub fn encode(msg: &WireMsg, dst: u16) -> Vec<u8> {
    let (kind, body) = payload(msg);
    let mut out = Vec::with_capacity(HEADER_LEN + body.len() + TRAILER_LEN);
    out.extend_from_slice(&WIRE_MAGIC);
    out.push(WIRE_VERSION);
    out.push(kind);
    out.extend_from_slice(&dst.to_le_bytes());
    put_u32(&mut out, body.len() as u32);
    out.extend_from_slice(&body);
    let sum = fnv1a32(&out);
    put_u32(&mut out, sum);
    out
}

/// Header peek for routers: validates magic/version and returns
/// `(kind, dst, total frame length)` without touching the payload, so
/// a relay can forward the raw bytes. [`WireError::Truncated`] means
/// "read more first".
pub fn peek(buf: &[u8]) -> Result<(u8, u16, usize), WireError> {
    if buf.len() < HEADER_LEN {
        return Err(WireError::Truncated);
    }
    if buf[0..2] != WIRE_MAGIC {
        return Err(WireError::BadMagic);
    }
    if buf[2] != WIRE_VERSION {
        return Err(WireError::BadVersion(buf[2]));
    }
    let kind = buf[3];
    if kind > KIND_STATE {
        return Err(WireError::BadKind(kind));
    }
    let dst = u16::from_le_bytes([buf[4], buf[5]]);
    let len = u32::from_le_bytes([buf[6], buf[7], buf[8], buf[9]]) as usize;
    let total = HEADER_LEN
        .checked_add(len)
        .and_then(|t| t.checked_add(TRAILER_LEN))
        .ok_or(WireError::Malformed("length overflow"))?;
    Ok((kind, dst, total))
}

/// Decode the frame at the head of `buf`. Returns the message, its
/// destination endpoint, and the number of bytes consumed (stream
/// framing: advance by that much and call again).
pub fn decode(buf: &[u8]) -> Result<(WireMsg, u16, usize), WireError> {
    let (kind, dst, total) = peek(buf)?;
    if buf.len() < total {
        return Err(WireError::Truncated);
    }
    let body_end = total - TRAILER_LEN;
    let want = u32::from_le_bytes(buf[body_end..total].try_into().unwrap());
    if fnv1a32(&buf[..body_end]) != want {
        return Err(WireError::BadChecksum);
    }
    let mut c = Cur::new(&buf[HEADER_LEN..body_end]);
    let msg = match kind {
        KIND_FRAG => {
            let src = c.u32()?;
            let uni = c.mass()?;
            let pv = c.mass()?;
            let n = c.count(12)?;
            let mut entries = Vec::with_capacity(n);
            for _ in 0..n {
                let node = c.u32()?;
                entries.push((node, c.mass()?));
            }
            WireMsg::Frag { src, frag: ResidualFragment { entries, uni, pv } }
        }
        KIND_STEAL_REQUEST => WireMsg::StealRequest { thief: c.u32()? },
        KIND_GRANT => {
            let src = c.u32()?;
            let n = c.count(21)?;
            let mut rows = Vec::with_capacity(n);
            for _ in 0..n {
                let node = c.u32()?;
                let p = c.mass()?;
                let r = c.mass()?;
                let touched = match c.u8()? {
                    0 => false,
                    1 => true,
                    _ => return Err(WireError::Malformed("touched flag out of range")),
                };
                rows.push(WireRow { node, p, r, touched });
            }
            WireMsg::Grant { src, rows }
        }
        KIND_HEAD_FRAME => {
            let src = c.u32()?;
            let gen = c.u64()?;
            let rest_bound = c.mass()?;
            let r_plus = c.mass()?;
            let r_minus = c.mass()?;
            let unk_plus = c.mass()?;
            let unk_minus = c.mass()?;
            let n = c.count(12)?;
            let mut entries = Vec::with_capacity(n);
            for _ in 0..n {
                let node = c.u32()?;
                entries.push((node, c.mass()?));
            }
            WireMsg::HeadFrame {
                src,
                gen,
                frame: WireHeadFrame { entries, rest_bound, r_plus, r_minus, unk_plus, unk_minus },
            }
        }
        KIND_TERM => {
            let src = c.u32()?;
            let msg = term_from(c.u8()?)?;
            let n = c.count(12)?;
            let mut inflight = Vec::with_capacity(n);
            for _ in 0..n {
                let origin = c.u32()?;
                inflight.push((origin, c.u64()?));
            }
            WireMsg::Term { src, msg, inflight }
        }
        KIND_HELLO => WireMsg::Hello { shard: c.u32()? },
        KIND_ACK => WireMsg::Ack { peer: c.u32()? },
        KIND_FLUSHED => WireMsg::Flushed { src: c.u32()? },
        KIND_DUMP_REQ => WireMsg::DumpReq,
        KIND_STATE => {
            let src = c.u32()?;
            let lo = c.u32()?;
            let uni = c.mass()?;
            let pv = c.mass()?;
            let pushes = c.u64()?;
            let np = c.count(8)?;
            let mut p = Vec::with_capacity(np);
            for _ in 0..np {
                p.push(c.mass()?);
            }
            let nr = c.count(8)?;
            let mut r = Vec::with_capacity(nr);
            for _ in 0..nr {
                r.push(c.mass()?);
            }
            WireMsg::State { src, lo, p, r, uni, pv, pushes }
        }
        _ => unreachable!("peek validated the kind"),
    };
    c.done()?;
    Ok((msg, dst, total))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round_trip(msg: &WireMsg, dst: u16) -> WireMsg {
        let bytes = encode(msg, dst);
        let (got, got_dst, used) = decode(&bytes).expect("round trip");
        assert_eq!(got_dst, dst);
        assert_eq!(used, bytes.len());
        got
    }

    #[test]
    fn frag_round_trip_bit_exact() {
        let frag = ResidualFragment {
            entries: vec![(0, 1.5e-300), (u32::MAX, f64::MIN_POSITIVE / 2.0), (7, -0.0)],
            uni: 3.25e-12,
            pv: 0.0,
        };
        let got = round_trip(&WireMsg::Frag { src: 3, frag: frag.clone() }, 1);
        match got {
            WireMsg::Frag { src, frag: f } => {
                assert_eq!(src, 3);
                assert_eq!(f.uni.to_bits(), frag.uni.to_bits());
                assert_eq!(f.pv.to_bits(), frag.pv.to_bits());
                assert_eq!(f.entries.len(), frag.entries.len());
                for (a, b) in f.entries.iter().zip(&frag.entries) {
                    assert_eq!(a.0, b.0);
                    assert_eq!(a.1.to_bits(), b.1.to_bits());
                }
            }
            other => panic!("wrong kind: {other:?}"),
        }
    }

    #[test]
    fn empty_frag_round_trip() {
        let got = round_trip(
            &WireMsg::Frag {
                src: 0,
                frag: ResidualFragment { entries: vec![], uni: 0.0, pv: 0.0 },
            },
            0,
        );
        assert!(matches!(got, WireMsg::Frag { frag, .. } if frag.entries.is_empty()));
    }

    #[test]
    fn term_round_trip_all_verbs() {
        for msg in [TermMsg::Converge, TermMsg::Diverge, TermMsg::Stop] {
            let got = round_trip(
                &WireMsg::Term { src: 5, msg, inflight: vec![(0, 3), (5, u64::MAX)] },
                9,
            );
            match got {
                WireMsg::Term { src, msg: m, inflight } => {
                    assert_eq!(src, 5);
                    assert_eq!(m, msg);
                    assert_eq!(inflight, vec![(0, 3), (5, u64::MAX)]);
                }
                other => panic!("wrong kind: {other:?}"),
            }
        }
    }

    #[test]
    fn head_frame_neg_inf_rest_bound_is_legal() {
        let frame = WireHeadFrame {
            entries: vec![(2, 0.125)],
            rest_bound: f64::NEG_INFINITY,
            r_plus: 1e-9,
            r_minus: 0.0,
            unk_plus: 0.0,
            unk_minus: 0.0,
        };
        let got =
            round_trip(&WireMsg::HeadFrame { src: 1, gen: u64::MAX, frame: frame.clone() }, 4);
        assert!(
            matches!(got, WireMsg::HeadFrame { gen: u64::MAX, frame: f, .. } if f == frame)
        );
    }

    #[test]
    fn truncated_frames_error_at_every_cut() {
        let bytes = encode(
            &WireMsg::Grant {
                src: 2,
                rows: vec![WireRow { node: 9, p: 0.5, r: 0.25, touched: true }],
            },
            3,
        );
        for cut in 0..bytes.len() {
            assert!(matches!(decode(&bytes[..cut]), Err(WireError::Truncated)), "cut at {cut}");
        }
    }

    #[test]
    fn bad_magic_version_kind_checksum() {
        let good = encode(&WireMsg::Hello { shard: 1 }, 0);
        let mut b = good.clone();
        b[0] = 0x00;
        assert!(matches!(decode(&b), Err(WireError::BadMagic)));
        let mut b = good.clone();
        b[2] = 99;
        assert!(matches!(decode(&b), Err(WireError::BadVersion(99))));
        let mut b = good.clone();
        b[3] = 200;
        assert!(matches!(decode(&b), Err(WireError::BadKind(200))));
        let mut b = good.clone();
        let last = b.len() - 1;
        b[last] ^= 0xFF;
        assert!(matches!(decode(&b), Err(WireError::BadChecksum)));
    }

    #[test]
    fn nan_mass_rejected() {
        // corrupt the uni field in place and re-stamp the checksum so
        // only the NaN check can fire
        let mut b = encode(
            &WireMsg::Frag {
                src: 0,
                frag: ResidualFragment { entries: vec![], uni: 1.0, pv: 0.0 },
            },
            0,
        );
        let nan = f64::NAN.to_bits().to_le_bytes();
        b[HEADER_LEN + 4..HEADER_LEN + 12].copy_from_slice(&nan);
        let body_end = b.len() - TRAILER_LEN;
        let sum = super::fnv1a32(&b[..body_end]).to_le_bytes();
        b[body_end..].copy_from_slice(&sum);
        assert!(matches!(decode(&b), Err(WireError::NanMass)));
    }

    #[test]
    fn lying_count_rejected_without_allocation() {
        // claim u32::MAX fragment entries in a tiny payload
        let mut b = encode(
            &WireMsg::Frag {
                src: 0,
                frag: ResidualFragment { entries: vec![], uni: 0.0, pv: 0.0 },
            },
            0,
        );
        b[HEADER_LEN + 20..HEADER_LEN + 24].copy_from_slice(&u32::MAX.to_le_bytes());
        let body_end = b.len() - TRAILER_LEN;
        let sum = super::fnv1a32(&b[..body_end]).to_le_bytes();
        b[body_end..].copy_from_slice(&sum);
        assert!(matches!(decode(&b), Err(WireError::Malformed(_))));
    }

    #[test]
    fn stream_framing_consumes_exact_lengths() {
        let a = encode(&WireMsg::Ack { peer: 7 }, 2);
        let b = encode(&WireMsg::DumpReq, 1);
        let mut stream = a.clone();
        stream.extend_from_slice(&b);
        let (m1, _, used1) = decode(&stream).unwrap();
        assert!(matches!(m1, WireMsg::Ack { peer: 7 }));
        assert_eq!(used1, a.len());
        let (m2, dst2, used2) = decode(&stream[used1..]).unwrap();
        assert!(matches!(m2, WireMsg::DumpReq));
        assert_eq!(dst2, 1);
        assert_eq!(used2, b.len());
    }

    #[test]
    fn peek_matches_decode() {
        let bytes = encode(&WireMsg::StealRequest { thief: 4 }, 6);
        let (kind, dst, total) = peek(&bytes).unwrap();
        assert_eq!(kind, KIND_STEAL_REQUEST);
        assert_eq!(dst, 6);
        assert_eq!(total, bytes.len());
    }
}
