//! Experiment metrics: the exact rows/cells the paper's tables report,
//! plus emitters (markdown / JSON) for `repro report`.

use crate::asynciter::{RunMetrics, StopCause};
use crate::obs::{EventKind, EventTotals};
use crate::util::{Json, Table};

/// One row of Table 1.
#[derive(Debug, Clone)]
pub struct Table1Row {
    pub procs: usize,
    pub sync_iters: u64,
    pub sync_time: f64,
    pub async_iters_min: u64,
    pub async_iters_max: u64,
    pub async_t_min: f64,
    pub async_t_max: f64,
    pub speedup: f64,
}

impl Table1Row {
    pub fn from_runs(sync: &RunMetrics, asynchronous: &RunMetrics) -> Table1Row {
        let (imin, imax) = asynchronous.iters_range();
        let (tmin, tmax) = asynchronous.time_range();
        Table1Row {
            procs: sync.p,
            sync_iters: sync.iters.iter().copied().max().unwrap_or(0),
            sync_time: sync.total_time,
            async_iters_min: imin,
            async_iters_max: imax,
            async_t_min: tmin,
            async_t_max: tmax,
            speedup: asynchronous.speedup_vs(sync.total_time),
        }
    }

    pub fn cells(&self) -> Vec<String> {
        vec![
            self.procs.to_string(),
            self.sync_iters.to_string(),
            format!("{:.1}", self.sync_time),
            format!("[{}, {}]", self.async_iters_min, self.async_iters_max),
            format!("[{:.1}, {:.1}]", self.async_t_min, self.async_t_max),
            format!("{:.2}", self.speedup),
        ]
    }

    pub fn to_json(&self) -> Json {
        let mut o = std::collections::BTreeMap::new();
        o.insert("procs".into(), Json::Num(self.procs as f64));
        o.insert("sync_iters".into(), Json::Num(self.sync_iters as f64));
        o.insert("sync_time".into(), Json::Num(self.sync_time));
        o.insert("async_iters_min".into(), Json::Num(self.async_iters_min as f64));
        o.insert("async_iters_max".into(), Json::Num(self.async_iters_max as f64));
        o.insert("async_t_min".into(), Json::Num(self.async_t_min));
        o.insert("async_t_max".into(), Json::Num(self.async_t_max));
        o.insert("speedup".into(), Json::Num(self.speedup));
        Json::Obj(o)
    }
}

/// Render Table 1 rows in the paper's layout.
pub fn table1_markdown(rows: &[Table1Row]) -> String {
    let mut t = Table::new(&[
        "procs",
        "sync iters",
        "sync t (s)",
        "async [it_min, it_max]",
        "async [t_min, t_max] (s)",
        "<speedUp>",
    ]);
    for r in rows {
        t.row(&r.cells());
    }
    t.to_markdown()
}

/// Render Table 2 (completed-imports matrix) in the paper's layout.
pub fn table2_markdown(m: &RunMetrics) -> String {
    let p = m.p;
    let mut header: Vec<String> = vec!["Receiver".into()];
    header.extend((0..p).map(|j| format!("id = {j}")));
    header.push("Completed Imports (%)".into());
    let hdr_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hdr_refs);
    for i in 0..p {
        let mut cells: Vec<String> = vec![format!("id = {i}")];
        cells.extend((0..p).map(|j| m.imports[i][j].to_string()));
        cells.push(format!("{:.0}", m.import_pct[i]));
        t.row(&cells);
    }
    t.to_markdown()
}

/// One epoch of the evolving-graph experiment (`repro stream`): the
/// incremental warm-start solve vs. the from-scratch baseline on the
/// same snapshot.
#[derive(Debug, Clone)]
pub struct StreamEpochRow {
    pub epoch: usize,
    pub n: usize,
    pub m: usize,
    /// Effective batch contents (0/0/0 for the initial build epoch).
    pub new_nodes: usize,
    pub inserted: usize,
    pub removed: usize,
    /// Warm-start (incremental) solve cost.
    pub inc_pushes: u64,
    pub inc_touched: usize,
    pub inc_residual: f64,
    /// From-scratch push solve on the identical snapshot, same tol.
    pub scratch_pushes: u64,
    /// L1 distance of the incremental ranks to a fresh f64 power-method
    /// run on the snapshot.
    pub l1_vs_power: f64,
    /// Resident path only: transposed rows the incremental CSR splice
    /// (`DeltaGraph::merge_csr`) rebuilt this epoch — a full rebuild
    /// would have paid for all `n`. 0 on the roundtrip path (no
    /// per-epoch CSR is maintained there).
    pub csr_dirty_rows: usize,
    /// Rows that changed owner through intra-epoch work stealing this
    /// epoch (`repro stream --steal`); 0 when stealing is off or no
    /// idle/loaded window opened.
    pub stolen_rows: u64,
    /// Steal grants delivered between shards this epoch.
    pub steal_grants: u64,
    /// What stopped the epoch's *threaded* drain (`--threads N`,
    /// N ≥ 2); `None` on sequential epochs, which stop inline on the
    /// exact residual and need no monitor verdict.
    pub stop_cause: Option<StopCause>,
    /// §4.2 CONVERGE announcements the epoch's threaded drains shipped
    /// (0 under `--term quiet` or sequential solves).
    pub term_converge: u64,
    /// §4.2 DIVERGE retractions — each one is a premature stop the
    /// protocol prevented.
    pub term_diverge: u64,
    /// Serving-path columns (`repro stream --topk K`); `None` when no
    /// top-k goal was tracked.
    pub topk: Option<TopKEpochStats>,
}

/// Certified top-k head columns for one stream epoch: how much the
/// head churned, when the certificate fired relative to full
/// convergence, and whether the certified set matches the power
/// reference (it must — certification is a proof, the column is the
/// audit).
#[derive(Debug, Clone)]
pub struct TopKEpochStats {
    pub k: usize,
    /// Set certificate held at epoch exit.
    pub certified: bool,
    /// Order-within-the-head certificate held at epoch exit.
    pub order_certified: bool,
    /// Incremental pushes spent when the goal first certified
    /// (`Some(0)` = the warm-started head was already certified;
    /// `None` = never certified, e.g. a tie at the k boundary).
    pub pushes_to_cert: Option<u64>,
    /// Head-set churn vs. the previous epoch's head.
    pub entries: usize,
    pub exits: usize,
    /// Set overlap of the tracked head vs. the power reference's
    /// top-k on the same snapshot.
    pub overlap_vs_power: f64,
}

impl TopKEpochStats {
    pub fn to_json(&self) -> Json {
        let mut o = std::collections::BTreeMap::new();
        o.insert("k".into(), Json::Num(self.k as f64));
        o.insert("certified".into(), Json::Bool(self.certified));
        o.insert("order_certified".into(), Json::Bool(self.order_certified));
        match self.pushes_to_cert {
            Some(p) => o.insert("pushes_to_cert".into(), Json::Num(p as f64)),
            None => o.insert("pushes_to_cert".into(), Json::Null),
        };
        o.insert("entries".into(), Json::Num(self.entries as f64));
        o.insert("exits".into(), Json::Num(self.exits as f64));
        o.insert("overlap_vs_power".into(), Json::Num(self.overlap_vs_power));
        Json::Obj(o)
    }
}

impl StreamEpochRow {
    pub fn cells(&self) -> Vec<String> {
        vec![
            self.epoch.to_string(),
            self.n.to_string(),
            self.m.to_string(),
            format!("+{}n +{}e -{}e", self.new_nodes, self.inserted, self.removed),
            self.inc_pushes.to_string(),
            self.inc_touched.to_string(),
            self.scratch_pushes.to_string(),
            if self.scratch_pushes > 0 {
                format!("{:.1}x", self.scratch_pushes as f64 / self.inc_pushes.max(1) as f64)
            } else {
                "-".into()
            },
            if self.steal_grants > 0 {
                format!("{} ({})", self.stolen_rows, self.steal_grants)
            } else {
                "-".into()
            },
            match self.stop_cause {
                Some(c) if self.term_converge + self.term_diverge > 0 => {
                    format!("{} {}c/{}d", c.name(), self.term_converge, self.term_diverge)
                }
                Some(c) => c.name().to_string(),
                None => "-".into(),
            },
            format!("{:.1e}", self.l1_vs_power),
        ]
    }

    pub fn to_json(&self) -> Json {
        let mut o = std::collections::BTreeMap::new();
        o.insert("epoch".into(), Json::Num(self.epoch as f64));
        o.insert("n".into(), Json::Num(self.n as f64));
        o.insert("m".into(), Json::Num(self.m as f64));
        o.insert("new_nodes".into(), Json::Num(self.new_nodes as f64));
        o.insert("inserted".into(), Json::Num(self.inserted as f64));
        o.insert("removed".into(), Json::Num(self.removed as f64));
        o.insert("inc_pushes".into(), Json::Num(self.inc_pushes as f64));
        o.insert("inc_touched".into(), Json::Num(self.inc_touched as f64));
        o.insert("inc_residual".into(), Json::Num(self.inc_residual));
        o.insert("scratch_pushes".into(), Json::Num(self.scratch_pushes as f64));
        o.insert("l1_vs_power".into(), Json::Num(self.l1_vs_power));
        o.insert("csr_dirty_rows".into(), Json::Num(self.csr_dirty_rows as f64));
        o.insert("stolen_rows".into(), Json::Num(self.stolen_rows as f64));
        o.insert("steal_grants".into(), Json::Num(self.steal_grants as f64));
        match self.stop_cause {
            Some(c) => o.insert("stop_cause".into(), Json::Str(c.name().into())),
            None => o.insert("stop_cause".into(), Json::Null),
        };
        o.insert("term_converge".into(), Json::Num(self.term_converge as f64));
        o.insert("term_diverge".into(), Json::Num(self.term_diverge as f64));
        if let Some(t) = &self.topk {
            o.insert("topk".into(), t.to_json());
        }
        Json::Obj(o)
    }
}

/// Render the per-epoch serving-path table (`repro stream --topk K`):
/// head churn, pushes-to-certification vs. pushes-to-convergence, and
/// the audit overlap against the power reference.
pub fn stream_topk_markdown(rows: &[StreamEpochRow]) -> String {
    let mut t = Table::new(&[
        "epoch",
        "head +in/-out",
        "cert pushes",
        "conv pushes",
        "early",
        "certified",
        "overlap",
    ]);
    for r in rows {
        let Some(tk) = &r.topk else { continue };
        let cert_cell = match tk.pushes_to_cert {
            Some(p) => p.to_string(),
            None => "-".into(),
        };
        let early = match tk.pushes_to_cert {
            Some(p) if r.inc_pushes > 0 => {
                format!("{:.1}x", r.inc_pushes as f64 / (p.max(1)) as f64)
            }
            _ => "-".into(),
        };
        let certified = match (tk.certified, tk.order_certified) {
            (true, true) => "set+order",
            (true, false) => "set",
            _ => "no",
        };
        t.row(&[
            r.epoch.to_string(),
            format!("+{} -{}", tk.entries, tk.exits),
            cert_cell,
            r.inc_pushes.to_string(),
            early,
            certified.to_string(),
            format!("{:.2}", tk.overlap_vs_power),
        ]);
    }
    t.to_markdown()
}

/// One shard-count cell of the parallel-push scaling experiment
/// (`benches/push_parallel.rs`): a cold sharded solve on real threads
/// at a given shard count, against the single-shard wall time.
#[derive(Debug, Clone)]
pub struct ShardScaleRow {
    pub shards: usize,
    /// Mean wall time of the threaded solve.
    pub wall_ms: f64,
    /// Total pushes across shards (staleness inflates this vs. 1 shard).
    pub pushes: u64,
    /// Residual fragments delivered between shards.
    pub fragments: u64,
    /// Single-shard wall / this wall.
    pub speedup: f64,
    /// Exact residual after the run (per-run convergence evidence).
    pub residual: f64,
}

impl ShardScaleRow {
    pub fn cells(&self) -> Vec<String> {
        vec![
            self.shards.to_string(),
            format!("{:.1}", self.wall_ms),
            self.pushes.to_string(),
            self.fragments.to_string(),
            format!("{:.2}x", self.speedup),
            format!("{:.1e}", self.residual),
        ]
    }

    pub fn to_json(&self) -> Json {
        let mut o = std::collections::BTreeMap::new();
        o.insert("shards".into(), Json::Num(self.shards as f64));
        o.insert("wall_ms".into(), Json::Num(self.wall_ms));
        o.insert("pushes".into(), Json::Num(self.pushes as f64));
        o.insert("fragments".into(), Json::Num(self.fragments as f64));
        o.insert("speedup".into(), Json::Num(self.speedup));
        o.insert("residual".into(), Json::Num(self.residual));
        Json::Obj(o)
    }
}

/// Render the shard-count scaling table.
pub fn parallel_push_markdown(rows: &[ShardScaleRow]) -> String {
    let mut t = Table::new(&[
        "shards",
        "wall (ms)",
        "pushes",
        "fragments",
        "speedup",
        "residual",
    ]);
    for r in rows {
        t.row(&r.cells());
    }
    t.to_markdown()
}

/// Render the per-epoch stream table. The `stolen (grants)` column
/// reads `-` on epochs without a steal — stealing is opportunistic
/// (an idle/loaded window has to open), so sparse entries are normal.
pub fn stream_markdown(rows: &[StreamEpochRow]) -> String {
    let mut t = Table::new(&[
        "epoch",
        "n",
        "m",
        "batch",
        "inc pushes",
        "touched",
        "scratch pushes",
        "saving",
        "stolen (grants)",
        "stop",
        "L1 vs power",
    ]);
    for r in rows {
        t.row(&r.cells());
    }
    t.to_markdown()
}

/// Render per-track event totals from a trace run (`--trace`): one row
/// per track, one column per [`EventKind`], plus ring-overflow drops.
pub fn trace_summary_markdown(tracks: &[(String, EventTotals)]) -> String {
    let mut header: Vec<&str> = vec!["track"];
    header.extend(EventKind::ALL.iter().map(|k| k.name()));
    header.push("dropped");
    let mut t = Table::new(&header);
    for (name, totals) in tracks {
        let mut cells = vec![name.clone()];
        cells.extend(EventKind::ALL.iter().map(|&k| totals.get(k).to_string()));
        cells.push(totals.dropped.to_string());
        t.row(&cells);
    }
    t.to_markdown()
}

/// Run-level summary (global residual, wire stats) for EXPERIMENTS.md.
pub fn run_summary(m: &RunMetrics) -> String {
    format!(
        "mode={:?} p={} iters={:?} total_t={:.1}s global_resid={:.2e} wire: sent={} cancelled={} queue_wait={:.1}s",
        m.mode,
        m.p,
        m.iters,
        m.total_time,
        m.final_global_residual,
        m.wire_sent,
        m.wire_cancelled,
        m.wire_queue_wait,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::asynciter::Mode;

    fn fake_metrics(p: usize) -> RunMetrics {
        RunMetrics {
            mode: Mode::Asynchronous,
            p,
            iters: (0..p).map(|i| 60 + i as u64).collect(),
            finish_times: (0..p).map(|i| 80.0 + i as f64).collect(),
            total_time: 95.0,
            imports: vec![vec![10; p]; p],
            sends_attempted: vec![100; p],
            sends_cancelled: vec![50; p],
            final_global_residual: 4.2e-5,
            x: vec![0.0; 8],
            wire_sent: 123,
            wire_cancelled: 45,
            wire_queue_wait: 6.0,
            import_pct: vec![30.0; p],
        }
    }

    fn fake_sync(p: usize) -> RunMetrics {
        RunMetrics {
            mode: Mode::Synchronous,
            iters: vec![44; p],
            finish_times: vec![179.0; p],
            total_time: 179.2,
            ..fake_metrics(p)
        }
    }

    #[test]
    fn table1_row_shape() {
        let r = Table1Row::from_runs(&fake_sync(2), &fake_metrics(2));
        assert_eq!(r.procs, 2);
        assert_eq!(r.sync_iters, 44);
        assert_eq!(r.async_iters_min, 60);
        assert_eq!(r.async_iters_max, 61);
        // speedup = 179.2 / mean(80, 81)
        assert!((r.speedup - 179.2 / 80.5).abs() < 1e-9);
        let md = table1_markdown(&[r]);
        assert!(md.contains("<speedUp>"));
        assert!(md.contains("[60, 61]"));
    }

    #[test]
    fn table1_json_roundtrip() {
        let r = Table1Row::from_runs(&fake_sync(4), &fake_metrics(4));
        let j = r.to_json();
        assert_eq!(j.get("procs").unwrap().as_usize(), Some(4));
        let txt = j.to_string_compact();
        assert!(Json::parse(&txt).is_ok());
    }

    #[test]
    fn table2_layout() {
        let md = table2_markdown(&fake_metrics(4));
        assert!(md.contains("id = 3"));
        assert!(md.contains("Completed Imports"));
        // 4 data rows + header + separator
        assert_eq!(md.trim().lines().count(), 6);
    }

    fn fake_stream_row(epoch: usize) -> StreamEpochRow {
        StreamEpochRow {
            epoch,
            n: 1000 + epoch,
            m: 8000,
            new_nodes: 1,
            inserted: 20,
            removed: 10,
            inc_pushes: 500,
            inc_touched: 300,
            inc_residual: 9.0e-11,
            scratch_pushes: 50_000,
            l1_vs_power: 3.0e-10,
            csr_dirty_rows: 25,
            stolen_rows: 0,
            steal_grants: 0,
            stop_cause: None,
            term_converge: 0,
            term_diverge: 0,
            topk: None,
        }
    }

    #[test]
    fn stream_table_layout_and_saving_ratio() {
        let mut with_steal = fake_stream_row(1);
        with_steal.stolen_rows = 96;
        with_steal.steal_grants = 3;
        with_steal.stop_cause = Some(StopCause::Protocol);
        with_steal.term_converge = 5;
        with_steal.term_diverge = 1;
        let md = stream_markdown(&[fake_stream_row(0), with_steal]);
        assert!(md.contains("inc pushes"));
        assert!(md.contains("100.0x"), "{md}");
        assert!(md.contains("+1n +20e -10e"));
        assert!(md.contains("stolen (grants)"));
        assert!(md.contains("96 (3)"), "{md}");
        assert!(md.contains("| stop"), "{md}");
        assert!(md.contains("protocol 5c/1d"), "{md}");
        assert!(md.contains("| -"), "no-steal epochs render a dash: {md}");
        assert_eq!(md.trim().lines().count(), 4);
    }

    #[test]
    fn stream_row_json() {
        let mut row = fake_stream_row(3);
        row.stolen_rows = 12;
        row.steal_grants = 1;
        row.stop_cause = Some(StopCause::QuietWindow);
        row.term_converge = 2;
        let j = row.to_json();
        assert_eq!(j.get("epoch").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("scratch_pushes").unwrap().as_usize(), Some(50_000));
        assert_eq!(j.get("csr_dirty_rows").unwrap().as_usize(), Some(25));
        assert_eq!(j.get("stolen_rows").unwrap().as_usize(), Some(12));
        assert_eq!(j.get("steal_grants").unwrap().as_usize(), Some(1));
        assert_eq!(j.get("stop_cause").unwrap().as_str(), Some("quiet"));
        assert_eq!(j.get("term_converge").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("term_diverge").unwrap().as_usize(), Some(0));
        assert_eq!(fake_stream_row(0).to_json().get("stop_cause"), Some(&Json::Null));
        assert!(Json::parse(&j.to_string_compact()).is_ok());
    }

    #[test]
    // NOTE: deliberately NOT named `topk_*` — CI's debug pass filters
    // `--skip topk_` (for the release-only proptest campaigns) and a
    // matching name here would drop this test from every CI pass
    fn serving_columns_table_and_json() {
        let mut certified = fake_stream_row(1);
        certified.topk = Some(TopKEpochStats {
            k: 32,
            certified: true,
            order_certified: false,
            pushes_to_cert: Some(50),
            entries: 2,
            exits: 2,
            overlap_vs_power: 1.0,
        });
        let mut tied = fake_stream_row(2);
        tied.topk = Some(TopKEpochStats {
            k: 32,
            certified: false,
            order_certified: false,
            pushes_to_cert: None,
            entries: 0,
            exits: 0,
            overlap_vs_power: 0.97,
        });
        // rows without topk columns are skipped, not rendered empty
        let md = stream_topk_markdown(&[fake_stream_row(0), certified.clone(), tied.clone()]);
        assert_eq!(md.trim().lines().count(), 4, "{md}");
        assert!(md.contains("+2 -2"));
        assert!(md.contains("10.0x"), "500 conv / 50 cert: {md}");
        assert!(md.contains("set"));
        assert!(md.contains("| no"), "{md}");

        let j = certified.to_json();
        let t = j.get("topk").unwrap();
        assert_eq!(t.get("pushes_to_cert").unwrap().as_usize(), Some(50));
        assert_eq!(t.get("certified"), Some(&Json::Bool(true)));
        assert_eq!(tied.to_json().get("topk").unwrap().get("pushes_to_cert"), Some(&Json::Null));
        assert!(Json::parse(&j.to_string_compact()).is_ok());
    }

    #[test]
    fn parallel_push_table_layout() {
        let rows = vec![
            ShardScaleRow {
                shards: 1,
                wall_ms: 120.0,
                pushes: 50_000,
                fragments: 0,
                speedup: 1.0,
                residual: 9.0e-11,
            },
            ShardScaleRow {
                shards: 4,
                wall_ms: 48.0,
                pushes: 61_000,
                fragments: 320,
                speedup: 2.5,
                residual: 8.0e-11,
            },
        ];
        let md = parallel_push_markdown(&rows);
        assert!(md.contains("shards"));
        assert!(md.contains("2.50x"), "{md}");
        assert_eq!(md.trim().lines().count(), 4);
        let j = rows[1].to_json();
        assert_eq!(j.get("shards").unwrap().as_usize(), Some(4));
        assert!(Json::parse(&j.to_string_compact()).is_ok());
    }

    #[test]
    fn summary_contains_key_fields() {
        let s = run_summary(&fake_metrics(2));
        assert!(s.contains("4.2e-5") || s.contains("4.20e-5"));
        assert!(s.contains("cancelled=45"));
    }

    #[test]
    fn trace_summary_has_one_column_per_kind() {
        let mut totals = EventTotals::default();
        totals.counts[EventKind::PushBatch as usize] = 17;
        totals.counts[EventKind::StealGrant as usize] = 3;
        totals.dropped = 2;
        let md = trace_summary_markdown(&[
            ("shard 0".to_string(), totals),
            ("monitor".to_string(), EventTotals::default()),
        ]);
        // header + separator + two track rows
        assert_eq!(md.trim().lines().count(), 4, "{md}");
        for kind in EventKind::ALL {
            assert!(md.contains(kind.name()), "missing column {}", kind.name());
        }
        assert!(md.contains("17"), "{md}");
        assert!(md.contains("dropped"), "{md}");
    }
}
