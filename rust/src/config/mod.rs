//! Experiment configuration: a TOML-subset parser (offline build — no
//! toml crate) and typed run configs with the paper's presets.
//!
//! Supported syntax: `[section]` headers, `key = value` with string
//! ("x"), integer, float, boolean values, `#` comments. That covers
//! every config this repo ships; anything fancier fails loudly.

mod toml_lite;

pub use toml_lite::TomlLite;

use crate::asynciter::{Mode, StopRule};
use crate::simnet::Topology;
use crate::Result;

/// Fully resolved run configuration (one experiment invocation).
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Graph: "stanford" | "scaled:<n>" | "erdos:<n>:<m>" | path to an
    /// edge list (.txt/.bin).
    pub graph: String,
    pub seed: u64,
    pub alpha: f32,
    /// Computing UEs.
    pub procs: usize,
    pub mode: Mode,
    pub tol: f32,
    pub pc_max_worker: u32,
    pub pc_max_monitor: u32,
    /// Stop on the omniscient global threshold instead of Figure-1.
    pub global_threshold: bool,
    pub topology: Topology,
    pub cancel_window: Option<f64>,
    pub adaptive: bool,
    /// Use the PJRT artifact operator instead of native CSR.
    pub use_artifact: bool,
    /// Use the push-diffusion block operator
    /// ([`crate::stream::PushBlockOp`]) instead of native CSR.
    pub use_push: bool,
    /// Partition rows by balanced nonzero count
    /// ([`crate::coordinator::Partitioner::balanced_nnz`]) instead of
    /// the paper's consecutive ⌈n/p⌉ blocks — equalizes per-UE compute
    /// under the web's degree skew.
    pub balanced_partition: bool,
    /// ELL width for the artifact path.
    pub ell_width: usize,
    /// Multiplier on the testbed bandwidth (1.0 = the paper's wire).
    /// Scaled-down graphs shrink fragments but not the paper's
    /// compute/communication ratio; setting this to n_scaled/n_full
    /// restores the ratio so saturation phenomena reproduce at small
    /// scale.
    pub bandwidth_scale: f64,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            graph: "scaled:20000".into(),
            seed: 42,
            alpha: 0.85,
            procs: 4,
            mode: Mode::Asynchronous,
            tol: 1e-6,
            pc_max_worker: 1,
            pc_max_monitor: 1,
            global_threshold: false,
            topology: Topology::Clique,
            cancel_window: Some(3.0),
            adaptive: false,
            use_artifact: false,
            use_push: false,
            balanced_partition: false,
            ell_width: 16,
            bandwidth_scale: 1.0,
        }
    }
}

impl RunConfig {
    /// The paper's Table-1/2 configuration at a given machine count.
    pub fn paper(procs: usize, mode: Mode) -> RunConfig {
        RunConfig { graph: "stanford".into(), procs, mode, ..Default::default() }
    }

    pub fn stop_rule(&self) -> StopRule {
        if self.global_threshold {
            StopRule::GlobalThreshold { tol: self.tol }
        } else {
            StopRule::LocalProtocol {
                tol: self.tol,
                pc_max_worker: self.pc_max_worker,
                pc_max_monitor: self.pc_max_monitor,
            }
        }
    }

    /// Parse from TOML-subset text.
    pub fn from_toml(text: &str) -> Result<RunConfig> {
        let t = TomlLite::parse(text)?;
        let mut c = RunConfig::default();
        if let Some(v) = t.get_str("run", "graph") {
            c.graph = v.to_string();
        }
        if let Some(v) = t.get_int("run", "seed") {
            c.seed = v as u64;
        }
        if let Some(v) = t.get_float("run", "alpha") {
            c.alpha = v as f32;
        }
        if let Some(v) = t.get_int("run", "procs") {
            c.procs = v as usize;
        }
        if let Some(v) = t.get_str("run", "mode") {
            c.mode = match v {
                "sync" | "synchronous" => Mode::Synchronous,
                "async" | "asynchronous" => Mode::Asynchronous,
                other => anyhow::bail!("unknown mode {other:?}"),
            };
        }
        if let Some(v) = t.get_float("run", "tol") {
            c.tol = v as f32;
        }
        if let Some(v) = t.get_int("termination", "pc_max_worker") {
            c.pc_max_worker = v as u32;
        }
        if let Some(v) = t.get_int("termination", "pc_max_monitor") {
            c.pc_max_monitor = v as u32;
        }
        if let Some(v) = t.get_bool("termination", "global_threshold") {
            c.global_threshold = v;
        }
        if let Some(v) = t.get_str("network", "topology") {
            c.topology = Topology::parse(v)
                .ok_or_else(|| anyhow::anyhow!("unknown topology {v:?}"))?;
        }
        if let Some(v) = t.get_float("network", "cancel_window") {
            c.cancel_window = if v <= 0.0 { None } else { Some(v) };
        }
        if let Some(v) = t.get_bool("network", "adaptive") {
            c.adaptive = v;
        }
        if let Some(v) = t.get_bool("runtime", "use_artifact") {
            c.use_artifact = v;
        }
        if let Some(v) = t.get_bool("runtime", "use_push") {
            c.use_push = v;
        }
        // accepted in both sections: it is a run-level layout choice,
        // but users naturally group it with use_push/use_artifact
        if let Some(v) = t
            .get_bool("run", "balanced_partition")
            .or_else(|| t.get_bool("runtime", "balanced_partition"))
        {
            c.balanced_partition = v;
        }
        if let Some(v) = t.get_int("runtime", "ell_width") {
            c.ell_width = v as usize;
        }
        if let Some(v) = t.get_float("network", "bandwidth_scale") {
            c.bandwidth_scale = v;
        }
        c.validate()?;
        Ok(c)
    }

    pub fn validate(&self) -> Result<()> {
        if !(0.0..1.0).contains(&self.alpha) {
            anyhow::bail!("alpha {} out of [0,1)", self.alpha);
        }
        if self.procs == 0 {
            anyhow::bail!("procs must be >= 1");
        }
        if self.tol <= 0.0 {
            anyhow::bail!("tol must be positive");
        }
        if self.pc_max_worker == 0 || self.pc_max_monitor == 0 {
            anyhow::bail!("pcMax must be >= 1");
        }
        if self.ell_width == 0 {
            anyhow::bail!("ell_width must be >= 1");
        }
        if self.use_artifact && self.use_push {
            anyhow::bail!("use_artifact and use_push are mutually exclusive operators");
        }
        if self.bandwidth_scale <= 0.0 {
            anyhow::bail!("bandwidth_scale must be positive");
        }
        if self.mode == Mode::Synchronous && self.topology != Topology::Clique {
            anyhow::bail!("synchronous mode requires clique topology (the paper's scheme)");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        RunConfig::default().validate().unwrap();
    }

    #[test]
    fn parses_full_config() {
        let c = RunConfig::from_toml(
            r#"
# paper table 1 run
[run]
graph = "stanford"
seed = 7
alpha = 0.85
procs = 6
mode = "async"
tol = 1e-6

[termination]
pc_max_worker = 2
pc_max_monitor = 1
global_threshold = false

[network]
topology = "tree"
cancel_window = 2.5
adaptive = true

[runtime]
use_artifact = true
ell_width = 16
"#,
        )
        .unwrap();
        assert_eq!(c.graph, "stanford");
        assert_eq!(c.procs, 6);
        assert_eq!(c.mode, Mode::Asynchronous);
        assert_eq!(c.pc_max_worker, 2);
        assert_eq!(c.topology, Topology::BinaryTree);
        assert_eq!(c.cancel_window, Some(2.5));
        assert!(c.adaptive);
        assert!(c.use_artifact);
    }

    #[test]
    fn rejects_bad_values() {
        assert!(RunConfig::from_toml("[run]\nmode = \"warp\"\n").is_err());
        assert!(RunConfig::from_toml("[run]\nalpha = 1.5\n").is_err());
        assert!(RunConfig::from_toml("[run]\nprocs = 0\n").is_err());
        assert!(
            RunConfig::from_toml("[run]\nmode = \"sync\"\n[network]\ntopology = \"tree\"\n")
                .is_err()
        );
    }

    #[test]
    fn balanced_partition_parses_from_either_section() {
        let c = RunConfig::from_toml("[run]\nbalanced_partition = true\n").unwrap();
        assert!(c.balanced_partition);
        let c = RunConfig::from_toml("[runtime]\nbalanced_partition = true\n").unwrap();
        assert!(c.balanced_partition);
        assert!(!RunConfig::default().balanced_partition);
    }

    #[test]
    fn push_operator_parses_and_excludes_artifact() {
        let c = RunConfig::from_toml("[runtime]\nuse_push = true\n").unwrap();
        assert!(c.use_push);
        assert!(RunConfig::from_toml(
            "[runtime]\nuse_push = true\nuse_artifact = true\n"
        )
        .is_err());
    }

    #[test]
    fn cancel_window_zero_means_none() {
        let c = RunConfig::from_toml("[network]\ncancel_window = 0.0\n").unwrap();
        assert_eq!(c.cancel_window, None);
    }

    #[test]
    fn stop_rule_selection() {
        let mut c = RunConfig::default();
        assert!(matches!(c.stop_rule(), StopRule::LocalProtocol { .. }));
        c.global_threshold = true;
        assert!(matches!(c.stop_rule(), StopRule::GlobalThreshold { .. }));
    }
}
