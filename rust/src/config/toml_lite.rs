//! TOML-subset parser: sections, scalar key = value, comments.

use std::collections::BTreeMap;

use crate::Result;

/// A parsed value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

/// Parsed document: (section, key) -> value. Keys before any `[section]`
/// land in section "".
#[derive(Debug, Default)]
pub struct TomlLite {
    map: BTreeMap<(String, String), Value>,
}

impl TomlLite {
    pub fn parse(text: &str) -> Result<TomlLite> {
        let mut map = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = match raw.find('#') {
                // '#' inside a quoted string must survive
                Some(idx) if !in_string(raw, idx) => &raw[..idx],
                _ => raw,
            }
            .trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = name.trim().to_string();
                if section.is_empty() {
                    anyhow::bail!("line {}: empty section name", lineno + 1);
                }
                continue;
            }
            let (key, val) = line
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("line {}: expected key = value", lineno + 1))?;
            let key = key.trim().to_string();
            if key.is_empty() {
                anyhow::bail!("line {}: empty key", lineno + 1);
            }
            let val = Self::parse_value(val.trim())
                .ok_or_else(|| anyhow::anyhow!("line {}: bad value {:?}", lineno + 1, val.trim()))?;
            map.insert((section.clone(), key), val);
        }
        Ok(TomlLite { map })
    }

    fn parse_value(s: &str) -> Option<Value> {
        if let Some(q) = s.strip_prefix('"').and_then(|t| t.strip_suffix('"')) {
            return Some(Value::Str(q.to_string()));
        }
        match s {
            "true" => return Some(Value::Bool(true)),
            "false" => return Some(Value::Bool(false)),
            _ => {}
        }
        if let Ok(i) = s.parse::<i64>() {
            return Some(Value::Int(i));
        }
        if let Ok(f) = s.parse::<f64>() {
            return Some(Value::Float(f));
        }
        None
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.map.get(&(section.to_string(), key.to_string()))
    }

    pub fn get_str(&self, section: &str, key: &str) -> Option<&str> {
        match self.get(section, key) {
            Some(Value::Str(s)) => Some(s),
            _ => None,
        }
    }

    pub fn get_int(&self, section: &str, key: &str) -> Option<i64> {
        match self.get(section, key) {
            Some(Value::Int(i)) => Some(*i),
            _ => None,
        }
    }

    /// Floats accept integer literals too (tol = 1 is fine).
    pub fn get_float(&self, section: &str, key: &str) -> Option<f64> {
        match self.get(section, key) {
            Some(Value::Float(f)) => Some(*f),
            Some(Value::Int(i)) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn get_bool(&self, section: &str, key: &str) -> Option<bool> {
        match self.get(section, key) {
            Some(Value::Bool(b)) => Some(*b),
            _ => None,
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

/// Is byte index `idx` inside a double-quoted string in `line`?
fn in_string(line: &str, idx: usize) -> bool {
    let mut inside = false;
    for (i, c) in line.char_indices() {
        if i >= idx {
            break;
        }
        if c == '"' {
            inside = !inside;
        }
    }
    inside
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let t = TomlLite::parse(
            "top = 1\n[a]\nx = \"s\"\ny = 2\nz = 3.5\nw = true\n[b]\nx = false\n",
        )
        .unwrap();
        assert_eq!(t.get_int("", "top"), Some(1));
        assert_eq!(t.get_str("a", "x"), Some("s"));
        assert_eq!(t.get_int("a", "y"), Some(2));
        assert_eq!(t.get_float("a", "z"), Some(3.5));
        assert_eq!(t.get_bool("a", "w"), Some(true));
        assert_eq!(t.get_bool("b", "x"), Some(false));
        assert_eq!(t.len(), 6);
    }

    #[test]
    fn comments_and_blank_lines() {
        let t = TomlLite::parse("# header\n\n[s] # trailing\nk = 1 # comment\n").unwrap();
        assert_eq!(t.get_int("s", "k"), Some(1));
    }

    #[test]
    fn hash_inside_string_survives() {
        let t = TomlLite::parse("[s]\nk = \"a#b\"\n").unwrap();
        assert_eq!(t.get_str("s", "k"), Some("a#b"));
    }

    #[test]
    fn scientific_notation_floats() {
        let t = TomlLite::parse("[s]\ntol = 1e-6\n").unwrap();
        assert_eq!(t.get_float("s", "tol"), Some(1e-6));
    }

    #[test]
    fn rejects_malformed() {
        assert!(TomlLite::parse("[]\n").is_err());
        assert!(TomlLite::parse("novalue\n").is_err());
        assert!(TomlLite::parse("k = @@\n").is_err());
        assert!(TomlLite::parse(" = 3\n").is_err());
    }

    #[test]
    fn int_acceptable_as_float_not_vice_versa() {
        let t = TomlLite::parse("[s]\ni = 2\nf = 2.5\n").unwrap();
        assert_eq!(t.get_float("s", "i"), Some(2.0));
        assert_eq!(t.get_int("s", "f"), None);
    }
}
