//! Figure-1 state machines, ported faithfully.
//!
//! ```text
//! computing UE                      | monitor UE
//! ----------------------------------+--------------------------------
//! if(checkConvergence())            | recv(CONVERGE|DIVERGE, all)
//!   if(not converged)               | if(checkConvergence())
//!     converged = true              |   if(not converged)
//!   pc++                            |     converged = true
//!   if(pc = pcMax)                  |   pc++
//!     send(CONVERGE, monitor)       |   if(pc = pcMax)
//!     recv(STOP, monitor)           |     send(STOP, all)
//! else                              | else
//!   if(converged)                   |   if(converged)
//!     converged = false             |     converged = false
//!     send(DIVERGE, monitor)        |   pc = 0
//!   pc = 0                          |
//! ```
//!
//! At the computing UE, `checkConvergence()` is `local_residual < tol`
//! for the current iteration. At the monitor, it is "all computing UEs
//! currently logged CONVERGE". `recv(STOP)` is non-blocking in our
//! port (a blocking read would make the DIVERGE branch unreachable);
//! iteration continues until STOP is actually delivered, which matches
//! the paper's observed behaviour (UEs keep producing messages after
//! local convergence).

/// Messages of the protocol.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TermMsg {
    Converge,
    Diverge,
    Stop,
}

/// Computing-UE side of Figure 1.
#[derive(Debug, Clone)]
pub struct WorkerTermination {
    pc_max: u32,
    pc: u32,
    converged: bool,
    /// CONVERGE already emitted for the current converged streak.
    announced: bool,
}

impl WorkerTermination {
    pub fn new(pc_max: u32) -> Self {
        assert!(pc_max >= 1, "pcMax must be >= 1");
        WorkerTermination { pc_max, pc: 0, converged: false, announced: false }
    }

    /// Feed one local iteration's convergence check; returns the
    /// message to send to the monitor, if any.
    pub fn on_iteration(&mut self, locally_converged: bool) -> Option<TermMsg> {
        if locally_converged {
            if !self.converged {
                self.converged = true;
            }
            self.pc += 1;
            if self.pc == self.pc_max && !self.announced {
                self.announced = true;
                return Some(TermMsg::Converge);
            }
            None
        } else {
            let was = self.converged;
            self.converged = false;
            self.pc = 0;
            let emitted = self.announced;
            self.announced = false;
            if was && emitted {
                // only notify the monitor if it was told we converged
                Some(TermMsg::Diverge)
            } else {
                None
            }
        }
    }

    pub fn is_converged(&self) -> bool {
        self.converged
    }

    pub fn pc(&self) -> u32 {
        self.pc
    }
}

/// Monitor side of Figure 1.
#[derive(Debug, Clone)]
pub struct MonitorTermination {
    pc_max: u32,
    pc: u32,
    converged: bool,
    /// Convergence log, one slot per computing UE.
    log: Vec<bool>,
    stopped: bool,
}

impl MonitorTermination {
    pub fn new(p: usize, pc_max: u32) -> Self {
        assert!(pc_max >= 1, "pcMax must be >= 1");
        MonitorTermination { pc_max, pc: 0, converged: false, log: vec![false; p], stopped: false }
    }

    /// Process one CONVERGE/DIVERGE message from `ue`; returns true if
    /// STOP must be broadcast now.
    pub fn on_message(&mut self, ue: usize, msg: TermMsg) -> bool {
        if self.stopped {
            return false;
        }
        match msg {
            TermMsg::Converge => self.log[ue] = true,
            TermMsg::Diverge => self.log[ue] = false,
            TermMsg::Stop => panic!("monitor does not receive STOP"),
        }
        if self.log.iter().all(|&c| c) {
            if !self.converged {
                self.converged = true;
            }
            self.pc += 1;
            if self.pc >= self.pc_max {
                self.stopped = true;
                return true;
            }
        } else {
            if self.converged {
                self.converged = false;
            }
            self.pc = 0;
        }
        false
    }

    pub fn stopped(&self) -> bool {
        self.stopped
    }

    pub fn converged_count(&self) -> usize {
        self.log.iter().filter(|&&c| c).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn worker_announces_after_pc_max() {
        let mut w = WorkerTermination::new(3);
        assert_eq!(w.on_iteration(true), None); // pc=1
        assert_eq!(w.on_iteration(true), None); // pc=2
        assert_eq!(w.on_iteration(true), Some(TermMsg::Converge)); // pc=3
        assert_eq!(w.on_iteration(true), None); // no re-announce
        assert!(w.is_converged());
    }

    #[test]
    fn worker_diverge_only_after_announce() {
        let mut w = WorkerTermination::new(2);
        assert_eq!(w.on_iteration(true), None); // pc=1
        // diverges before announcing: monitor never knew, no DIVERGE
        assert_eq!(w.on_iteration(false), None);
        assert_eq!(w.pc(), 0);
        // converge fully, then diverge: DIVERGE emitted
        assert_eq!(w.on_iteration(true), None);
        assert_eq!(w.on_iteration(true), Some(TermMsg::Converge));
        assert_eq!(w.on_iteration(false), Some(TermMsg::Diverge));
        // can re-announce after re-converging
        assert_eq!(w.on_iteration(true), None);
        assert_eq!(w.on_iteration(true), Some(TermMsg::Converge));
    }

    #[test]
    #[should_panic(expected = "pcMax")]
    fn worker_rejects_zero_pc_max() {
        WorkerTermination::new(0);
    }

    #[test]
    fn monitor_stops_when_all_converged_pcmax1() {
        let mut m = MonitorTermination::new(3, 1);
        assert!(!m.on_message(0, TermMsg::Converge));
        assert!(!m.on_message(1, TermMsg::Converge));
        assert_eq!(m.converged_count(), 2);
        assert!(m.on_message(2, TermMsg::Converge)); // all -> STOP
        assert!(m.stopped());
        // further messages ignored
        assert!(!m.on_message(0, TermMsg::Diverge));
    }

    #[test]
    fn monitor_persistence_pcmax2() {
        let mut m = MonitorTermination::new(2, 2);
        assert!(!m.on_message(0, TermMsg::Converge));
        assert!(!m.on_message(1, TermMsg::Converge)); // all converged, pc=1
        // a diverge resets persistence
        assert!(!m.on_message(0, TermMsg::Diverge));
        assert!(!m.on_message(0, TermMsg::Converge)); // pc=1 again
        assert!(m.on_message(1, TermMsg::Converge)); // pc=2 -> STOP
    }

    #[test]
    fn monitor_never_stops_while_any_diverged() {
        let mut m = MonitorTermination::new(4, 1);
        let mut rng = Rng::new(13);
        // UE 3 never converges; messages from others arrive in random order
        for _ in 0..200 {
            let ue = rng.range(0, 3);
            let msg = if rng.chance(0.7) { TermMsg::Converge } else { TermMsg::Diverge };
            let stop = m.on_message(ue, msg);
            assert!(!stop, "stopped while UE 3 never converged");
        }
        assert!(!m.stopped());
    }

    /// Property: in any message sequence, STOP implies the last message
    /// from every UE was CONVERGE (safety of the central log).
    #[test]
    fn prop_stop_implies_all_last_converge() {
        let mut rng = Rng::new(14);
        for trial in 0..200 {
            let p = rng.range(1, 6);
            let pc_max = rng.range(1, 4) as u32;
            let mut m = MonitorTermination::new(p, pc_max);
            let mut last: Vec<Option<TermMsg>> = vec![None; p];
            for _ in 0..500 {
                let ue = rng.range(0, p);
                let msg =
                    if rng.chance(0.6) { TermMsg::Converge } else { TermMsg::Diverge };
                let stop = m.on_message(ue, msg);
                last[ue] = Some(msg);
                if stop {
                    for (u, l) in last.iter().enumerate() {
                        assert_eq!(
                            *l,
                            if u == ue { Some(msg) } else { *l },
                        );
                    }
                    assert!(
                        last.iter().all(|l| *l == Some(TermMsg::Converge)),
                        "trial {trial}: STOP though some UE last said DIVERGE: {last:?}"
                    );
                    break;
                }
            }
        }
    }

    /// Property: worker emits alternating CONVERGE/DIVERGE (never two
    /// of the same kind in a row).
    #[test]
    fn prop_worker_messages_alternate() {
        let mut rng = Rng::new(15);
        for _ in 0..100 {
            let mut w = WorkerTermination::new(rng.range(1, 5) as u32);
            let mut lastmsg = None;
            for _ in 0..300 {
                if let Some(m) = w.on_iteration(rng.chance(0.5)) {
                    assert_ne!(Some(m), lastmsg, "repeated {m:?}");
                    lastmsg = Some(m);
                }
            }
        }
    }
}
