//! Termination detection (§4.2) — the paper's Figure-1 protocol.
//!
//! "The termination of asynchronous iterative algorithms is a
//! non-trivial matter since local convergence at an UE does not
//! automatically ensure global convergence." The paper's answer is a
//! centralized protocol with *persistence counters*: computing UEs
//! signal CONVERGE after `pcMax` consecutive locally-converged
//! iterations (and DIVERGE on leaving that state); a monitor UE issues
//! STOP once its own persistence counter — advanced while *all* UEs are
//! logged converged — reaches its `pcMax`.
//!
//! [`WorkerTermination`] and [`MonitorTermination`] are pure state
//! machines (no clock, no IO) driven by the simulation engine and unit/
//! property tested in isolation. [`TermPort`]/[`MonitorPort`] bind them
//! to real channels for the threaded push backend (the DIVERGE-before-
//! acknowledge discipline that makes a STOP imply global convergence
//! lives there). [`GlobalOracle`] is the omniscient checker used by
//! tests and by experiment G1 (the paper's observation that local 1e-6
//! ⇔ global ≈5e-5). [`tree`] is the decentralized detector of the §6
//! outlook (cf. Bahi et al., paper ref [6]).

mod channel;
mod protocol;
pub mod tree;
mod oracle;

pub use channel::{term_channel, MonitorPort, TermPort, TermWire, WireMonitor};
pub use oracle::GlobalOracle;
pub use protocol::{MonitorTermination, TermMsg, WorkerTermination};
