//! Omniscient global-convergence checker.
//!
//! §5.2: "Assembling vector fragments resulting from asynchronous
//! computations at monitor UE and then checking global convergence
//! reveals that a threshold of the order of 5×10⁻⁵ has actually been
//! reached" (against the local threshold 10⁻⁶). The oracle measures
//! exactly that: given the assembled iterate it computes the TRUE
//! global residual ‖Gx − x‖₁ and the distance to a converged reference.

use crate::pagerank::{l1_diff, normalize_l1, PagerankProblem};

/// Global truth for a PageRank instance.
pub struct GlobalOracle<'a> {
    problem: &'a PagerankProblem,
    /// Tightly converged reference vector (L1-normalized).
    reference: Vec<f32>,
    scratch: Vec<f32>,
}

impl<'a> GlobalOracle<'a> {
    /// Build with a reference solved to `ref_tol` (use ≤1e-9 in tests).
    pub fn new(problem: &'a PagerankProblem, ref_tol: f32) -> Self {
        let r = crate::pagerank::power_method(
            problem,
            &crate::pagerank::PowerOptions {
                tol: ref_tol,
                max_iters: 100_000,
                record_residuals: false,
            },
        );
        let mut reference = r.x;
        normalize_l1(&mut reference);
        GlobalOracle { problem, reference, scratch: vec![0.0; problem.n()] }
    }

    /// True global residual ‖Gx − x‖₁ of an assembled iterate.
    pub fn global_residual(&mut self, x: &[f32]) -> f32 {
        self.problem.apply_google(x, &mut self.scratch);
        l1_diff(&self.scratch, x)
    }

    /// L1 error against the converged reference (both L1-normalized,
    /// factoring out the Lubachevsky–Mitra multiplicative constant).
    pub fn error_vs_reference(&self, x: &[f32]) -> f32 {
        let mut xn = x.to_vec();
        normalize_l1(&mut xn);
        l1_diff(&xn, &self.reference)
    }

    /// Kendall-τ of the ranking induced by `x` vs the reference (§5.2's
    /// "what matters is the relative ranking").
    pub fn ranking_tau(&self, x: &[f32]) -> f64 {
        crate::pagerank::kendall_tau(x, &self.reference)
    }

    /// Top-k overlap vs the reference.
    pub fn top_k(&self, x: &[f32], k: usize) -> f64 {
        crate::pagerank::top_k_overlap(x, &self.reference, k)
    }

    pub fn reference(&self) -> &[f32] {
        &self.reference
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generators, Csr};
    use crate::pagerank::{power_method, PowerOptions};

    fn problem() -> PagerankProblem {
        let el = generators::power_law_web(&generators::WebParams::scaled(2_000), 21);
        PagerankProblem::new(Csr::from_edgelist(&el).unwrap(), 0.85)
    }

    #[test]
    fn reference_is_fixed_point() {
        let p = problem();
        let mut o = GlobalOracle::new(&p, 1e-9);
        let xref = o.reference().to_vec();
        assert!(o.global_residual(&xref) < 1e-6);
        assert!(o.error_vs_reference(&xref) < 1e-6);
        assert!((o.ranking_tau(&xref) - 1.0).abs() < 1e-12);
        assert_eq!(o.top_k(&xref, 10), 1.0);
    }

    #[test]
    fn residual_decreases_along_power_iterates() {
        let p = problem();
        let mut o = GlobalOracle::new(&p, 1e-9);
        let mut res = Vec::new();
        for iters in [1usize, 5, 20] {
            let r = power_method(
                &p,
                &PowerOptions { tol: 0.0, max_iters: iters, record_residuals: false },
            );
            res.push(o.global_residual(&r.x));
        }
        assert!(res[0] > res[1] && res[1] > res[2], "{res:?}");
    }

    #[test]
    fn local_tol_implies_coarser_global_band() {
        // the G1 experiment in miniature: stopping at residual 1e-6
        // leaves a true error vs reference in a coarser band
        let p = problem();
        let o = GlobalOracle::new(&p, 1e-10);
        let r = power_method(&p, &PowerOptions::default());
        let err = o.error_vs_reference(&r.x);
        assert!(err > 1e-8, "error unexpectedly tiny: {err}");
        assert!(err < 1e-4, "error unexpectedly large: {err}");
    }
}
