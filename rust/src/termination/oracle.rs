//! Omniscient global-convergence checker.
//!
//! §5.2: "Assembling vector fragments resulting from asynchronous
//! computations at monitor UE and then checking global convergence
//! reveals that a threshold of the order of 5×10⁻⁵ has actually been
//! reached" (against the local threshold 10⁻⁶). The oracle measures
//! exactly that: given the assembled iterate it computes the TRUE
//! global residual ‖Gx − x‖₁ and the distance to a converged reference.

use crate::pagerank::{l1_diff_f64, normalize_l1, PagerankProblem};

/// Global truth for a PageRank instance.
pub struct GlobalOracle<'a> {
    problem: &'a PagerankProblem,
    /// Tightly converged reference vector (L1-normalized).
    reference: Vec<f32>,
    scratch: Vec<f32>,
}

impl<'a> GlobalOracle<'a> {
    /// Build with a reference solved to `ref_tol` (use ≤1e-9 in tests).
    pub fn new(problem: &'a PagerankProblem, ref_tol: f32) -> Self {
        let r = crate::pagerank::power_method(
            problem,
            &crate::pagerank::PowerOptions {
                tol: ref_tol,
                max_iters: 100_000,
                record_residuals: false,
            },
        );
        let mut reference = r.x;
        normalize_l1(&mut reference);
        GlobalOracle { problem, reference, scratch: vec![0.0; problem.n()] }
    }

    /// True global residual ‖Gx − x‖₁ of an assembled iterate.
    ///
    /// The vectors stay f32 (the paper's storage), but the tally is
    /// carried and *returned* in f64: at n ≳ 10⁶ an f32 sum's rounding
    /// error is the same order as the 1e-6..5e-5 thresholds this oracle
    /// certifies, so narrowing the result would destroy the very
    /// digits being measured.
    pub fn global_residual(&mut self, x: &[f32]) -> f64 {
        self.problem.apply_google(x, &mut self.scratch);
        l1_diff_f64(&self.scratch, x)
    }

    /// L1 error against the converged reference (both L1-normalized,
    /// factoring out the Lubachevsky–Mitra multiplicative constant).
    /// f64 tally, same rationale as [`global_residual`](Self::global_residual).
    pub fn error_vs_reference(&self, x: &[f32]) -> f64 {
        let mut xn = x.to_vec();
        normalize_l1(&mut xn);
        l1_diff_f64(&xn, &self.reference)
    }

    /// Kendall-τ of the ranking induced by `x` vs the reference (§5.2's
    /// "what matters is the relative ranking").
    pub fn ranking_tau(&self, x: &[f32]) -> f64 {
        crate::pagerank::kendall_tau(x, &self.reference)
    }

    /// Top-k overlap vs the reference.
    pub fn top_k(&self, x: &[f32], k: usize) -> f64 {
        crate::pagerank::top_k_overlap(x, &self.reference, k)
    }

    pub fn reference(&self) -> &[f32] {
        &self.reference
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generators, Csr};
    use crate::pagerank::{power_method, PowerOptions};

    fn problem() -> PagerankProblem {
        let el = generators::power_law_web(&generators::WebParams::scaled(2_000), 21);
        PagerankProblem::new(Csr::from_edgelist(&el).unwrap(), 0.85)
    }

    #[test]
    fn reference_is_fixed_point() {
        let p = problem();
        let mut o = GlobalOracle::new(&p, 1e-9);
        let xref = o.reference().to_vec();
        assert!(o.global_residual(&xref) < 1e-6);
        assert!(o.error_vs_reference(&xref) < 1e-6);
        assert!((o.ranking_tau(&xref) - 1.0).abs() < 1e-12);
        assert_eq!(o.top_k(&xref, 10), 1.0);
    }

    #[test]
    fn residual_decreases_along_power_iterates() {
        let p = problem();
        let mut o = GlobalOracle::new(&p, 1e-9);
        let mut res = Vec::new();
        for iters in [1usize, 5, 20] {
            let r = power_method(
                &p,
                &PowerOptions { tol: 0.0, max_iters: iters, record_residuals: false },
            );
            res.push(o.global_residual(&r.x));
        }
        assert!(res[0] > res[1] && res[1] > res[2], "{res:?}");
    }

    #[test]
    fn residual_is_pinned_to_an_f64_reference_at_million_scale() {
        // a directed ring's fixed point is exactly uniform, so the
        // oracle's reference build is O(1) power iterations even at
        // n = 10⁶ — the scale where f32 tallies actually break
        use crate::graph::EdgeList;
        let n = 1_000_000usize;
        let el = EdgeList::from_edges(
            n,
            (0..n).map(|i| (i as u32, ((i + 1) % n) as u32)).collect(),
        )
        .unwrap();
        let p = PagerankProblem::new(Csr::from_edgelist(&el).unwrap(), 0.85);
        let mut o = GlobalOracle::new(&p, 1e-6);

        // an alternating perturbation of the fixed point: every entry
        // of |Gx − x| has the same magnitude, which makes sequential
        // f32 summation drift deterministically instead of averaging
        // out
        let u = 1.0f32 / n as f32;
        let x: Vec<f32> =
            (0..n).map(|i| if i % 2 == 0 { u * 1.001 } else { u * 0.999 }).collect();
        let mut gx = vec![0.0f32; n];
        p.apply_google(&x, &mut gx);
        let want = crate::pagerank::l1_diff_f64(&gx, &x);
        assert!(want > 0.0);

        // the oracle's tally must equal the f64 reference exactly...
        assert_eq!(o.global_residual(&x), want, "oracle residual must carry f64 exactly");

        // ...and the narrowed return the oracle used to produce cannot
        // represent it — the digits the old signature threw away are
        // exactly the ones a 1e-6-order threshold certifies against
        let narrowed = (want as f32) as f64;
        assert_ne!(narrowed, want, "f32 narrowing must lose digits at this scale");
        assert!(
            (narrowed - want).abs() / want > f64::EPSILON,
            "narrowing error vanished: {narrowed:e} vs {want:e}"
        );
    }

    #[test]
    fn local_tol_implies_coarser_global_band() {
        // the G1 experiment in miniature: stopping at residual 1e-6
        // leaves a true error vs reference in a coarser band
        let p = problem();
        let o = GlobalOracle::new(&p, 1e-10);
        let r = power_method(&p, &PowerOptions::default());
        let err = o.error_vs_reference(&r.x);
        assert!(err > 1e-8, "error unexpectedly tiny: {err}");
        assert!(err < 1e-4, "error unexpectedly large: {err}");
    }
}
