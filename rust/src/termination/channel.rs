//! Channel-message adapters that carry the Figure-1 protocol between
//! real OS threads.
//!
//! [`WorkerTermination`]/[`MonitorTermination`] are pure state
//! machines; this module is the transport glue the threaded push
//! backend wires them through. Two pieces:
//!
//! * [`TermPort`] — the computing-UE side. Owns a worker's state
//!   machine plus the sending half of the control channel, and turns
//!   round outcomes into on-the-wire CONVERGE/DIVERGE messages.
//! * [`MonitorPort`] — the monitor side. Owns the receiving half and
//!   the central log, and drains whatever accumulated since the last
//!   poll.
//!
//! # Why the control channel is unbounded
//!
//! The soundness of the protocol's STOP decision rests on one ordering
//! guarantee: when a worker receives residual mass, its DIVERGE must be
//! *enqueued before* the sender's in-flight accounting is released
//! (see [`TermPort::on_mass_received`]). A bounded channel could block
//! or drop that DIVERGE, silently breaking the guarantee, so the ports
//! ride a dedicated unbounded [`std::sync::mpsc::channel`] instead of
//! the bounded data channels. Message volume is intrinsically bounded:
//! each worker's messages strictly alternate CONVERGE/DIVERGE (a
//! property test in [`protocol`](super::protocol) pins this down), and
//! a worker only diverges after real residual arrived, so the channel
//! can never hold more than O(messages between polls) entries.

use std::sync::mpsc::{channel, Receiver, Sender};

use super::protocol::{MonitorTermination, TermMsg, WorkerTermination};

/// A protocol message on the wire: which UE said what.
pub type TermWire = (usize, TermMsg);

/// Build the control channel the ports communicate over. Unbounded on
/// purpose — see the module docs.
pub fn term_channel() -> (Sender<TermWire>, Receiver<TermWire>) {
    channel()
}

/// Computing-UE side of the protocol, bound to a control channel.
#[derive(Debug)]
pub struct TermPort {
    ue: usize,
    term: WorkerTermination,
    tx: Sender<TermWire>,
    converge_sent: u64,
    diverge_sent: u64,
}

impl TermPort {
    pub fn new(ue: usize, pc_max: u32, tx: Sender<TermWire>) -> TermPort {
        TermPort { ue, term: WorkerTermination::new(pc_max), tx, converge_sent: 0, diverge_sent: 0 }
    }

    /// Feed one round's local convergence verdict; ships the resulting
    /// protocol message (if any) and returns it for event recording.
    ///
    /// The verdict the threaded backend feeds here is `local residual
    /// estimate < tol/s ∧ no in-flight sends this worker originated`:
    /// the worker may only claim convergence once every fragment it
    /// shipped has been applied by its receiver, so any mass it moved
    /// is covered by the *receiver's* termination state, not lost
    /// between the two.
    pub fn on_round(&mut self, locally_converged: bool) -> Option<TermMsg> {
        let msg = self.term.on_iteration(locally_converged)?;
        match msg {
            TermMsg::Converge => self.converge_sent += 1,
            TermMsg::Diverge => self.diverge_sent += 1,
            TermMsg::Stop => unreachable!("workers never send STOP"),
        }
        // a closed channel means the monitor is gone and the run is
        // already stopping; nothing to do but keep draining
        let _ = self.tx.send((self.ue, msg));
        Some(msg)
    }

    /// Residual mass just arrived in this worker's shard. MUST be
    /// called after applying the mass but BEFORE decrementing the
    /// sender's in-flight counter: the sender cannot announce CONVERGE
    /// until that counter hits zero, and `mpsc` preserves each
    /// producer's enqueue order, so the monitor is guaranteed to
    /// process this DIVERGE before any CONVERGE the sender could emit
    /// as a consequence of the acknowledgement. That ordering is what
    /// makes a protocol STOP imply global residual < tol.
    pub fn on_mass_received(&mut self) -> Option<TermMsg> {
        self.on_round(false)
    }

    /// CONVERGE messages shipped so far.
    pub fn converge_sent(&self) -> u64 {
        self.converge_sent
    }

    /// DIVERGE messages shipped so far.
    pub fn diverge_sent(&self) -> u64 {
        self.diverge_sent
    }

    /// The underlying state machine (inspection/tests).
    pub fn state(&self) -> &WorkerTermination {
        &self.term
    }
}

/// Monitor side of the protocol, bound to the receiving half.
///
/// The monitor's persistence counter only advances when a message
/// arrives, and no messages follow the final CONVERGE of a converged
/// run — a monitor-side `pc_max > 1` would therefore wedge forever
/// waiting for traffic that cannot come. The port pins the monitor's
/// counter at 1 and leaves the protocol's hysteresis entirely to the
/// worker-side `pc_max` (the `--pc-max` knob), which is fed every
/// round whether or not anything is on the wire.
#[derive(Debug)]
pub struct MonitorPort {
    monitor: MonitorTermination,
    rx: Receiver<TermWire>,
    messages_seen: u64,
}

impl MonitorPort {
    pub fn new(p: usize, rx: Receiver<TermWire>) -> MonitorPort {
        MonitorPort { monitor: MonitorTermination::new(p, 1), rx, messages_seen: 0 }
    }

    /// Drain everything queued since the last poll; returns true the
    /// first time the central log justifies STOP. Messages queued
    /// behind the deciding CONVERGE are left in the channel (the run
    /// is stopping; they no longer matter).
    pub fn poll(&mut self) -> bool {
        while let Ok((ue, msg)) = self.rx.try_recv() {
            self.messages_seen += 1;
            if self.monitor.on_message(ue, msg) {
                return true;
            }
        }
        false
    }

    /// Protocol messages processed so far.
    pub fn messages_seen(&self) -> u64 {
        self.messages_seen
    }

    /// The underlying state machine (inspection/tests).
    pub fn state(&self) -> &MonitorTermination {
        &self.monitor
    }
}

/// Monitor side of the protocol for messages that arrived over a
/// *serialized* transport ([`crate::net`]) instead of the in-process
/// control channel. Same central log and the same pinned `pc_max = 1`
/// as [`MonitorPort`], but fed one decoded message at a time by
/// whoever drains the wire, and hardened for the crossing: a CONVERGE
/// whose frame reports nonzero per-origin in-flight counts is
/// internally contradictory (the §4.2 announce predicate requires all
/// of them zero), so it is downgraded to DIVERGE rather than trusted.
#[derive(Debug)]
pub struct WireMonitor {
    monitor: MonitorTermination,
    messages_seen: u64,
    downgraded: u64,
}

impl WireMonitor {
    pub fn new(p: usize) -> WireMonitor {
        WireMonitor { monitor: MonitorTermination::new(p, 1), messages_seen: 0, downgraded: 0 }
    }

    /// Feed one decoded protocol message from UE `ue`;
    /// `inflight_nonzero` is whether the frame carried any nonzero
    /// per-origin in-flight count. Returns true the first time the
    /// central log justifies STOP.
    pub fn on_message(&mut self, ue: usize, msg: TermMsg, inflight_nonzero: bool) -> bool {
        self.messages_seen += 1;
        let msg = if msg == TermMsg::Converge && inflight_nonzero {
            self.downgraded += 1;
            TermMsg::Diverge
        } else {
            msg
        };
        self.monitor.on_message(ue, msg)
    }

    /// Protocol messages processed so far.
    pub fn messages_seen(&self) -> u64 {
        self.messages_seen
    }

    /// CONVERGE frames downgraded for carrying nonzero in-flight counts.
    pub fn downgraded(&self) -> u64 {
        self.downgraded
    }

    /// The underlying state machine (inspection/tests).
    pub fn state(&self) -> &MonitorTermination {
        &self.monitor
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_monitor_downgrades_inflight_converge() {
        let mut mon = WireMonitor::new(2);
        assert!(!mon.on_message(0, TermMsg::Converge, false));
        // UE 1 claims convergence while still reporting in-flight mass:
        // treated as DIVERGE, so no STOP
        assert!(!mon.on_message(1, TermMsg::Converge, true));
        assert_eq!(mon.downgraded(), 1);
        // the honest re-announce stops the run
        assert!(mon.on_message(1, TermMsg::Converge, false));
        assert_eq!(mon.messages_seen(), 3);
    }

    #[test]
    fn port_round_trip_stops_only_after_all_announce() {
        let (tx, rx) = term_channel();
        let mut a = TermPort::new(0, 2, tx.clone());
        let mut b = TermPort::new(1, 2, tx);
        let mut mon = MonitorPort::new(2, rx);

        assert_eq!(a.on_round(true), None); // pc=1
        assert_eq!(a.on_round(true), Some(TermMsg::Converge));
        assert!(!mon.poll(), "one of two announced");
        assert_eq!(b.on_round(true), None);
        assert_eq!(b.on_round(true), Some(TermMsg::Converge));
        assert!(mon.poll(), "all announced -> STOP");
        assert_eq!(mon.messages_seen(), 2);
        assert_eq!(a.converge_sent(), 1);
        assert_eq!(b.converge_sent(), 1);
    }

    #[test]
    fn mass_received_retracts_only_after_announce() {
        let (tx, rx) = term_channel();
        let mut w = TermPort::new(0, 1, tx);
        let mut mon = MonitorPort::new(1, rx);

        // mass before any announce: nothing to retract, no wire traffic
        assert_eq!(w.on_mass_received(), None);
        assert!(!mon.poll());

        assert_eq!(w.on_round(true), Some(TermMsg::Converge));
        // DIVERGE lands before the monitor ever saw the CONVERGE as
        // final: the next poll processes both, in enqueue order
        assert_eq!(w.on_mass_received(), Some(TermMsg::Diverge));
        assert!(!mon.poll(), "CONVERGE then DIVERGE must not stop");
        assert_eq!(w.diverge_sent(), 1);

        // re-converge re-announces and the monitor can now stop
        assert_eq!(w.on_round(true), Some(TermMsg::Converge));
        assert!(mon.poll());
        assert_eq!(w.converge_sent(), 2);
    }

    #[test]
    fn port_survives_disconnected_monitor() {
        let (tx, rx) = term_channel();
        let mut w = TermPort::new(0, 1, tx);
        drop(rx);
        // the send fails silently; the local state machine still runs
        assert_eq!(w.on_round(true), Some(TermMsg::Converge));
        assert_eq!(w.on_mass_received(), Some(TermMsg::Diverge));
        assert_eq!(w.converge_sent(), 1);
        assert_eq!(w.diverge_sent(), 1);
    }
}
