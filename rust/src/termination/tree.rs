//! Decentralized tree-based termination detector (extension).
//!
//! §4.2 notes distributed protocols "are flexible but rather complex to
//! implement. They typically assume a specific underlying communication
//! topology. For example in [6] a leader election protocol is used,
//! which in turn assumes a tree topology." We implement the tree
//! aggregation core of that family: every UE keeps the convergence
//! state of its subtree; state changes propagate upward; the root
//! (playing leader) applies the persistence rule and floods STOP down.
//!
//! The detector is again a pure state machine per node; the engine
//! moves [`TreeMsg`]s between nodes (paying network costs), so the
//! ablation can compare it fairly with the centralized monitor.

/// Messages of the tree protocol.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TreeMsg {
    /// Child -> parent: my whole subtree is (true)/is no longer (false)
    /// locally converged.
    Subtree { converged: bool },
    /// Root -> everyone via the tree: stop.
    Stop,
}

/// One node of the detector, arranged in an implicit binary tree
/// (parent of i is (i-1)/2, matching `Topology::BinaryTree`).
#[derive(Debug, Clone)]
pub struct TreeNode {
    id: usize,
    #[allow(dead_code)] // kept for diagnostics / Debug output
    p: usize,
    /// Local convergence of this UE.
    local: bool,
    /// Last reported state of each child subtree.
    children: Vec<(usize, bool)>,
    /// Last state sent to the parent (to suppress duplicates).
    sent_up: Option<bool>,
    /// Root-only persistence counter.
    pc: u32,
    pc_max: u32,
    stopped: bool,
}

/// Effects the engine must carry out after feeding a node.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct TreeEffects {
    /// (dst, msg) messages to send.
    pub send: Vec<(usize, TreeMsg)>,
    /// Root decided to stop (engine floods Stop to children itself via
    /// `send`; this flag is for run bookkeeping).
    pub stop: bool,
}

impl TreeNode {
    pub fn new(id: usize, p: usize, pc_max: u32) -> Self {
        assert!(pc_max >= 1);
        let children: Vec<(usize, bool)> = [2 * id + 1, 2 * id + 2]
            .into_iter()
            .filter(|&c| c < p)
            .map(|c| (c, false))
            .collect();
        TreeNode { id, p, local: false, children, sent_up: None, pc: 0, pc_max, stopped: false }
    }

    pub fn is_root(&self) -> bool {
        self.id == 0
    }

    fn parent(&self) -> usize {
        (self.id - 1) / 2
    }

    /// Subtree converged = local && all children's subtrees.
    fn subtree_converged(&self) -> bool {
        self.local && self.children.iter().all(|&(_, c)| c)
    }

    fn after_state_change(&mut self) -> TreeEffects {
        let mut fx = TreeEffects::default();
        if self.stopped {
            return fx;
        }
        let agg = self.subtree_converged();
        if self.is_root() {
            if agg {
                self.pc += 1;
                if self.pc >= self.pc_max {
                    self.stopped = true;
                    fx.stop = true;
                    for &(c, _) in &self.children {
                        fx.send.push((c, TreeMsg::Stop));
                    }
                }
            } else {
                self.pc = 0;
            }
        } else if self.sent_up != Some(agg) {
            self.sent_up = Some(agg);
            fx.send.push((self.parent(), TreeMsg::Subtree { converged: agg }));
        }
        fx
    }

    /// Feed this UE's own local-convergence check for an iteration.
    pub fn on_local(&mut self, converged: bool) -> TreeEffects {
        self.local = converged;
        self.after_state_change()
    }

    /// Feed a message from `src`.
    pub fn on_message(&mut self, src: usize, msg: TreeMsg) -> TreeEffects {
        match msg {
            TreeMsg::Subtree { converged } => {
                if let Some(slot) = self.children.iter_mut().find(|(c, _)| *c == src) {
                    slot.1 = converged;
                } else {
                    panic!("UE {} got subtree msg from non-child {}", self.id, src);
                }
                self.after_state_change()
            }
            TreeMsg::Stop => {
                self.stopped = true;
                let mut fx = TreeEffects { stop: true, ..Default::default() };
                for &(c, _) in &self.children {
                    fx.send.push((c, TreeMsg::Stop));
                }
                fx
            }
        }
    }

    pub fn stopped(&self) -> bool {
        self.stopped
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;
    use std::collections::VecDeque;

    /// Drive p nodes with an in-order message pump; returns true if the
    /// system reached global stop.
    fn pump(nodes: &mut [TreeNode], initial: Vec<(usize, TreeEffects)>) -> bool {
        let mut queue: VecDeque<(usize, usize, TreeMsg)> = VecDeque::new();
        for (src, fx) in initial {
            for (dst, m) in fx.send {
                queue.push_back((src, dst, m));
            }
        }
        let mut steps = 0;
        while let Some((src, dst, m)) = queue.pop_front() {
            let fx = nodes[dst].on_message(src, m);
            for (d2, m2) in fx.send {
                queue.push_back((dst, d2, m2));
            }
            steps += 1;
            assert!(steps < 10_000, "message storm");
        }
        nodes.iter().all(|n| n.stopped())
    }

    #[test]
    fn all_converged_leads_to_global_stop() {
        for p in [1usize, 2, 3, 6, 7] {
            let mut nodes: Vec<TreeNode> =
                (0..p).map(|i| TreeNode::new(i, p, 1)).collect();
            let initial: Vec<(usize, TreeEffects)> = (0..p)
                .map(|i| {
                    let fx = nodes[i].on_local(true);
                    (i, fx)
                })
                .collect();
            assert!(pump(&mut nodes, initial), "p={p} did not stop");
        }
    }

    #[test]
    fn one_unconverged_blocks_stop() {
        let p = 6;
        let mut nodes: Vec<TreeNode> = (0..p).map(|i| TreeNode::new(i, p, 1)).collect();
        let initial: Vec<(usize, TreeEffects)> = (0..p)
            .map(|i| {
                let fx = nodes[i].on_local(i != 4);
                (i, fx)
            })
            .collect();
        assert!(!pump(&mut nodes, initial));
        assert!(nodes.iter().all(|n| !n.stopped()));
    }

    #[test]
    fn diverge_after_converge_retracts() {
        let p = 3;
        let mut nodes: Vec<TreeNode> = (0..p).map(|i| TreeNode::new(i, p, 2)).collect();
        // all converge once: root pc=1 < pcMax=2, no stop yet
        let initial: Vec<(usize, TreeEffects)> = (0..p)
            .map(|i| {
                let fx = nodes[i].on_local(true);
                (i, fx)
            })
            .collect();
        assert!(!pump(&mut nodes, initial));
        // leaf 2 diverges then re-converges: root persistence RESETS
        // (pc back to 0, then 1 on the re-converge report)
        let fx = nodes[2].on_local(false);
        assert!(!pump(&mut nodes, vec![(2, fx)]));
        let fx = nodes[2].on_local(true);
        assert!(!pump(&mut nodes, vec![(2, fx)]));
        // persistence accumulates across subsequent all-converged
        // events — the root's own next locally-converged iteration
        // pushes pc to pcMax and floods STOP
        let fx = nodes[0].on_local(true);
        assert!(pump(&mut nodes, vec![(0, fx)]));
    }

    #[test]
    fn duplicate_reports_suppressed() {
        let p = 3;
        let mut n1 = TreeNode::new(1, p, 1);
        let fx1 = n1.on_local(true);
        assert_eq!(fx1.send.len(), 1);
        let fx2 = n1.on_local(true); // no state change -> no resend
        assert!(fx2.send.is_empty());
    }

    /// Safety property: if some node NEVER converges, no amount of
    /// churn elsewhere can stop the system. (The analogue of the
    /// centralized monitor's safety test; a transiently-converged node
    /// CAN legitimately race a STOP — the paper's pcMax persistence
    /// exists exactly to make that window small.)
    #[test]
    fn prop_no_stop_while_one_node_never_converges() {
        let mut rng = Rng::new(31);
        for _ in 0..100 {
            let p = rng.range(2, 8);
            let never = rng.range(0, p);
            let mut nodes: Vec<TreeNode> =
                (0..p).map(|i| TreeNode::new(i, p, 1)).collect();
            let mut pending = Vec::new();
            for _ in 0..40 {
                let ue = rng.range(0, p);
                let conv = if ue == never { false } else { rng.chance(0.7) };
                let fx = nodes[ue].on_local(conv);
                pending.push((ue, fx));
            }
            let stopped = pump(&mut nodes, pending);
            assert!(!stopped, "stopped though UE {never} never converged");
            assert!(nodes.iter().all(|n| !n.stopped()));
        }
    }
}
