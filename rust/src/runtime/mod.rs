//! PJRT runtime: load + execute the AOT artifacts from the rust hot path.
//!
//! `python/compile/aot.py` lowers the L2 model once to HLO text; this
//! module compiles those artifacts on the PJRT CPU client (the `xla`
//! crate) and exposes a typed [`PagerankStepExe::step`] used by worker
//! UEs. Python never runs at request time.
//!
//! The real engine requires the external `xla` bindings, which the
//! offline build environment does not carry; it is compiled only with
//! `--features xla` (after adding the `xla` dependency to Cargo.toml).
//! The default build substitutes `engine_stub.rs`, an API-identical stub
//! whose `Engine::new` fails with a readable error, so every artifact
//! code path type-checks and errors cleanly at runtime instead of at
//! link time.

#[cfg(feature = "xla")]
mod engine;
#[cfg(not(feature = "xla"))]
#[path = "engine_stub.rs"]
mod engine;
pub mod manifest;

pub use engine::{Engine, PagerankStepExe, StepBuffers};
pub use manifest::{ArtifactEntry, Bucket, Manifest};

/// Default artifacts directory relative to the crate root.
pub fn default_artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}
