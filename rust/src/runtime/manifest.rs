//! Artifact manifest: the ABI contract between `python/compile/aot.py`
//! and this runtime.
//!
//! `make artifacts` writes `artifacts/manifest.json` describing every
//! emitted HLO-text artifact: its shape bucket, argument order, shapes
//! and dtypes, and output arity. We validate all of it at load time so
//! shape mismatches fail with a readable error instead of deep inside
//! PJRT execution. Parsed with the crate's own JSON parser
//! ([`crate::util::json`]); the offline build carries no serde.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context};

use crate::util::Json;
use crate::Result;

/// One (N, B, K) shape bucket — mirrors `python/compile/shapes.py`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Bucket {
    /// Human-readable bucket name ("tiny", "stanford", ...).
    pub name: String,
    /// Padded global vector length.
    pub n: usize,
    /// Padded block rows (ELL rows incl. virtual rows).
    pub b: usize,
    /// ELL width (padded slots per row).
    pub k: usize,
}

impl Bucket {
    /// Does a (rows, block_rows, width) problem fit this bucket?
    pub fn fits(&self, n_rows: usize, block_rows: usize, width: usize) -> bool {
        self.n >= n_rows && self.b >= block_rows && self.k >= width
    }

    /// Artifact file stem, matching `shapes.Bucket.artifact_name`.
    pub fn artifact_name(&self, kernel: &str) -> String {
        format!("{kernel}_n{}_b{}_k{}", self.n, self.b, self.k)
    }

    fn from_json(v: &Json) -> Result<Bucket> {
        Ok(Bucket {
            name: v.get("name").and_then(Json::as_str).unwrap_or("").to_string(),
            n: v.get("n").and_then(Json::as_usize).context("bucket.n")?,
            b: v.get("b").and_then(Json::as_usize).context("bucket.b")?,
            k: v.get("k").and_then(Json::as_usize).context("bucket.k")?,
        })
    }
}

/// Shape+dtype of one artifact argument or output.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArgSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl ArgSpec {
    fn from_json(v: &Json) -> Result<ArgSpec> {
        let shape = v
            .get("shape")
            .and_then(Json::as_arr)
            .context("arg.shape")?
            .iter()
            .map(|d| d.as_usize().context("arg.shape dim"))
            .collect::<Result<Vec<_>>>()?;
        Ok(ArgSpec {
            name: v.get("name").and_then(Json::as_str).context("arg.name")?.to_string(),
            shape,
            dtype: v.get("dtype").and_then(Json::as_str).context("arg.dtype")?.to_string(),
        })
    }
}

/// One emitted artifact.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub kernel: String,
    pub bucket: Bucket,
    /// File name relative to the artifacts directory.
    pub path: String,
    pub args: Vec<ArgSpec>,
    pub outputs: Vec<ArgSpec>,
}

/// Parsed `manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub version: usize,
    pub arg_order: Vec<String>,
    pub artifacts: Vec<ArtifactEntry>,
    dir: PathBuf,
}

/// Argument order the runtime hard-codes (must match shapes.ARG_ORDER).
pub const ARG_ORDER: [&str; 7] = ["vals", "cols", "x", "xold", "bias", "dang", "alpha"];

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!("reading {}; run `make artifacts` first", path.display())
        })?;
        let root = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;

        let version = root.get("version").and_then(Json::as_usize).context("version")?;
        let arg_order = root
            .get("arg_order")
            .and_then(Json::as_arr)
            .context("arg_order")?
            .iter()
            .map(|v| v.as_str().context("arg_order entry").map(str::to_string))
            .collect::<Result<Vec<_>>>()?;
        let artifacts = root
            .get("artifacts")
            .and_then(Json::as_arr)
            .context("artifacts")?
            .iter()
            .map(|v| {
                Ok(ArtifactEntry {
                    kernel: v.get("kernel").and_then(Json::as_str).context("kernel")?.to_string(),
                    bucket: Bucket::from_json(v.get("bucket").context("bucket")?)?,
                    path: v.get("path").and_then(Json::as_str).context("path")?.to_string(),
                    args: v
                        .get("args")
                        .and_then(Json::as_arr)
                        .context("args")?
                        .iter()
                        .map(ArgSpec::from_json)
                        .collect::<Result<Vec<_>>>()?,
                    outputs: v
                        .get("outputs")
                        .and_then(Json::as_arr)
                        .context("outputs")?
                        .iter()
                        .map(ArgSpec::from_json)
                        .collect::<Result<Vec<_>>>()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let m = Manifest { version, arg_order, artifacts, dir };
        m.validate()?;
        Ok(m)
    }

    fn validate(&self) -> Result<()> {
        if self.version != 1 {
            bail!("unsupported manifest version {}", self.version);
        }
        if self.arg_order != ARG_ORDER {
            bail!(
                "manifest arg_order {:?} != runtime ABI {:?}; rebuild artifacts",
                self.arg_order,
                ARG_ORDER
            );
        }
        for a in &self.artifacts {
            let names: Vec<&str> = a.args.iter().map(|s| s.name.as_str()).collect();
            if names != ARG_ORDER {
                bail!("artifact {} arg names {:?} mismatch ABI", a.path, names);
            }
            let by: BTreeMap<&str, &ArgSpec> =
                a.args.iter().map(|s| (s.name.as_str(), s)).collect();
            let (n, b, k) = (a.bucket.n, a.bucket.b, a.bucket.k);
            let checks: [(&str, Vec<usize>, &str); 7] = [
                ("vals", vec![b, k], "float32"),
                ("cols", vec![b, k], "int32"),
                ("x", vec![n], "float32"),
                ("xold", vec![b], "float32"),
                ("bias", vec![b], "float32"),
                ("dang", vec![1], "float32"),
                ("alpha", vec![1], "float32"),
            ];
            for (name, shape, dtype) in checks {
                let spec = by[name];
                if spec.shape != shape || spec.dtype != dtype {
                    bail!(
                        "artifact {}: arg {name} is {:?}/{} want {:?}/{dtype}",
                        a.path, spec.shape, spec.dtype, shape
                    );
                }
            }
            if a.outputs.len() != 2
                || a.outputs[0].shape != [b]
                || a.outputs[1].shape != [1]
            {
                bail!("artifact {}: unexpected outputs {:?}", a.path, a.outputs);
            }
            if !self.dir.join(&a.path).exists() {
                bail!("artifact file missing: {}", self.dir.join(&a.path).display());
            }
        }
        Ok(())
    }

    /// Directory the manifest (and artifacts) live in.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Smallest bucket artifact (by N, then B, then K) of `kernel` that
    /// fits the problem, or None if nothing fits.
    pub fn best_fit(
        &self,
        kernel: &str,
        n_rows: usize,
        block_rows: usize,
        width: usize,
    ) -> Option<&ArtifactEntry> {
        self.artifacts
            .iter()
            .filter(|a| a.kernel == kernel && a.bucket.fits(n_rows, block_rows, width))
            .min_by_key(|a| (a.bucket.n, a.bucket.b, a.bucket.k))
    }

    /// Exact-bucket lookup.
    pub fn by_bucket(&self, kernel: &str, n: usize, b: usize, k: usize) -> Option<&ArtifactEntry> {
        self.artifacts
            .iter()
            .find(|a| a.kernel == kernel && (a.bucket.n, a.bucket.b, a.bucket.k) == (n, b, k))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn repo_artifacts() -> Option<Manifest> {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        Manifest::load(dir).ok()
    }

    #[test]
    fn loads_repo_manifest() {
        let m = repo_artifacts().expect("run `make artifacts` before cargo test");
        assert!(!m.artifacts.is_empty());
        assert_eq!(m.arg_order, ARG_ORDER);
    }

    #[test]
    fn best_fit_prefers_smallest() {
        let m = match repo_artifacts() {
            Some(m) => m,
            None => return,
        };
        let a = m.best_fit("pagerank_step", 1000, 500, 8).unwrap();
        assert_eq!(a.bucket.n, 1 << 10);
        let a = m.best_fit("pagerank_step", 300_000, 100_000, 16).unwrap();
        assert_eq!(a.bucket.n, 1 << 19);
        assert!(m.best_fit("pagerank_step", 1 << 30, 1, 1).is_none());
    }

    #[test]
    fn by_bucket_exact() {
        let m = match repo_artifacts() {
            Some(m) => m,
            None => return,
        };
        assert!(m.by_bucket("pagerank_step", 1 << 10, 1 << 9, 8).is_some());
        assert!(m.by_bucket("pagerank_step", 1 << 10, 1 << 9, 9).is_none());
    }

    #[test]
    fn bucket_artifact_name_matches_python() {
        let b = Bucket { name: String::new(), n: 1024, b: 512, k: 8 };
        assert_eq!(b.artifact_name("pagerank_step"), "pagerank_step_n1024_b512_k8");
    }

    #[test]
    fn rejects_bad_manifest() {
        let dir = std::env::temp_dir().join(format!("asyncpr_mtest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), r#"{"version": 2, "arg_order": [], "artifacts": []}"#).unwrap();
        let err = Manifest::load(&dir).unwrap_err().to_string();
        assert!(err.contains("unsupported manifest version"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
