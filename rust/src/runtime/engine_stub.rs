//! Stub PJRT engine for builds without the `xla` feature.
//!
//! Mirrors the public API of `engine.rs` exactly — same types, same
//! signatures — but [`Engine::new`] always fails, and the remaining
//! methods are statically unreachable (the types carry an
//! [`std::convert::Infallible`] witness, so no instance can exist).
//! This keeps `ArtifactBlockOp`, the CLI `--artifact` path, benches and
//! examples compiling in the offline build while the error surfaces at
//! the single entry point with an actionable message.

use crate::Result;

use super::manifest::{Bucket, Manifest};

/// Shared PJRT engine (stub: unconstructible).
#[derive(Clone)]
pub struct Engine {
    never: std::convert::Infallible,
    manifest: Manifest,
}

impl Engine {
    /// Always fails: this build does not carry the PJRT bindings.
    pub fn new(artifacts_dir: impl AsRef<std::path::Path>) -> Result<Self> {
        anyhow::bail!(
            "asyncpr was built without the `xla` feature; the PJRT artifact \
             runtime is unavailable (artifacts dir: {}). Rebuild with \
             `--features xla` (plus the external `xla` dependency and \
             `make artifacts`) or drop `--artifact`/`use_artifact`.",
            artifacts_dir.as_ref().display()
        )
    }

    pub fn manifest(&self) -> &Manifest {
        match self.never {}
    }

    pub fn platform(&self) -> String {
        match self.never {}
    }

    /// Instantiate a step executor (stub: statically unreachable).
    pub fn pagerank_step(
        &self,
        _n_rows: usize,
        _block_rows: usize,
        _width: usize,
    ) -> Result<PagerankStepExe> {
        match self.never {}
    }
}

/// Reusable, padded host-side buffers for one UE's step calls.
///
/// Kept layout-identical to the real engine so caller code that fills
/// `x`/`bias`/`dang`/`alpha` type-checks unchanged.
pub struct StepBuffers {
    pub vals: Vec<f32>,
    pub cols: Vec<i32>,
    pub x: Vec<f32>,
    pub xold: Vec<f32>,
    pub bias: Vec<f32>,
    pub dang: [f32; 1],
    pub alpha: [f32; 1],
}

/// A compiled `pagerank_step` (stub: unconstructible).
pub struct PagerankStepExe {
    never: std::convert::Infallible,
    bucket: Bucket,
}

impl PagerankStepExe {
    pub fn bucket(&self) -> &Bucket {
        match self.never {}
    }

    pub fn buffers(&self) -> StepBuffers {
        match self.never {}
    }

    pub fn load_matrix(&mut self, _buf: &mut StepBuffers, _vals: &[f32], _cols: &[u32]) {
        match self.never {}
    }

    pub fn step(&mut self, _buf: &mut StepBuffers) -> Result<(Vec<f32>, f32)> {
        match self.never {}
    }

    pub fn logical_shape(&self) -> (usize, usize, usize) {
        match self.never {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_engine_errors_with_guidance() {
        let err = Engine::new(super::super::default_artifacts_dir()).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("xla"), "{msg}");
        assert!(msg.contains("--artifact") || msg.contains("use_artifact"), "{msg}");
    }
}
