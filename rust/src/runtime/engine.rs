//! PJRT execution engine: loads HLO-text artifacts, compiles them on
//! the CPU PJRT client, and exposes a typed `pagerank_step` entry point.
//!
//! Wiring follows `/opt/xla-example/load_hlo`: HLO **text** (not a
//! serialized proto — xla_extension 0.5.1 rejects jax≥0.5's 64-bit ids)
//! → `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` → `execute`.
//!
//! Executables are compiled once per bucket and cached; padded inputs
//! are prepared by [`StepBuffers`] so the hot loop reuses allocations.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::Result;

use super::manifest::{ArtifactEntry, Bucket, Manifest};

/// Shared PJRT engine. Cheap to clone (Arc inside).
#[derive(Clone)]
pub struct Engine {
    inner: Arc<EngineInner>,
}

struct EngineInner {
    client: xla::PjRtClient,
    manifest: Manifest,
    /// artifact file name -> compiled executable
    cache: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
}

impl Engine {
    /// Create an engine over an artifacts directory (validates the
    /// manifest eagerly, compiles lazily).
    pub fn new(artifacts_dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("creating PJRT CPU client: {e}"))?;
        Ok(Self {
            inner: Arc::new(EngineInner { client, manifest, cache: Mutex::new(HashMap::new()) }),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.inner.manifest
    }

    pub fn platform(&self) -> String {
        self.inner.client.platform_name()
    }

    /// Compile (or fetch from cache) the artifact for `entry`.
    fn executable(&self, entry: &ArtifactEntry) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        {
            let cache = self.inner.cache.lock().unwrap();
            if let Some(exe) = cache.get(&entry.path) {
                return Ok(exe.clone());
            }
        }
        let path = self.inner.manifest.dir().join(&entry.path);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("parsing HLO text {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .inner
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {}: {e}", path.display()))?;
        let exe = Arc::new(exe);
        self.inner
            .cache
            .lock()
            .unwrap()
            .insert(entry.path.clone(), exe.clone());
        Ok(exe)
    }

    /// Instantiate a step executor for a concrete problem size.
    ///
    /// `n_rows`: logical global vector length; `block_rows`: logical ELL
    /// rows of this UE's block (incl. virtual rows); `width`: ELL width.
    /// Picks the smallest bucket that fits and owns the padding.
    pub fn pagerank_step(
        &self,
        n_rows: usize,
        block_rows: usize,
        width: usize,
    ) -> Result<PagerankStepExe> {
        let entry = self
            .inner
            .manifest
            .best_fit("pagerank_step", n_rows, block_rows, width)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "no artifact bucket fits n={n_rows} b={block_rows} k={width}; \
                     add a bucket to python/compile/shapes.py and re-run `make artifacts`"
                )
            })?
            .clone();
        let exe = self.executable(&entry)?;
        Ok(PagerankStepExe::new(
            exe,
            self.inner.client.clone(),
            entry.bucket,
            n_rows,
            block_rows,
            width,
        ))
    }
}

/// Reusable, padded host-side buffers for one UE's step calls.
///
/// `vals`/`cols`/`bias` are fixed per run (the block's matrix rows and
/// teleport bias); `x`, `xold`, `dang` change every step. The caller
/// writes logical-sized data; padding stays zero (padded ELL slots have
/// val=0 ⇒ no contribution; padded x entries are never referenced).
pub struct StepBuffers {
    pub vals: Vec<f32>,
    pub cols: Vec<i32>,
    pub x: Vec<f32>,
    pub xold: Vec<f32>,
    pub bias: Vec<f32>,
    pub dang: [f32; 1],
    pub alpha: [f32; 1],
}

/// A compiled `pagerank_step` bound to one bucket + logical shape.
pub struct PagerankStepExe {
    exe: Arc<xla::PjRtLoadedExecutable>,
    client: xla::PjRtClient,
    bucket: Bucket,
    n_rows: usize,
    block_rows: usize,
    width: usize,
    /// Device-resident copies of the per-run-constant inputs
    /// (vals, cols, bias); uploading 2×BK f32 every step dominated the
    /// hot path before this cache (EXPERIMENTS.md §Perf).
    static_bufs: Option<(xla::PjRtBuffer, xla::PjRtBuffer, xla::PjRtBuffer)>,
}

impl PagerankStepExe {
    fn new(
        exe: Arc<xla::PjRtLoadedExecutable>,
        client: xla::PjRtClient,
        bucket: Bucket,
        n_rows: usize,
        block_rows: usize,
        width: usize,
    ) -> Self {
        Self { exe, client, bucket, n_rows, block_rows, width, static_bufs: None }
    }

    pub fn bucket(&self) -> &Bucket {
        &self.bucket
    }

    /// Allocate zeroed padded buffers for this executable.
    pub fn buffers(&self) -> StepBuffers {
        let (n, b, k) = (self.bucket.n, self.bucket.b, self.bucket.k);
        StepBuffers {
            vals: vec![0.0; b * k],
            cols: vec![0; b * k],
            x: vec![0.0; n],
            xold: vec![0.0; b],
            bias: vec![0.0; b],
            dang: [0.0],
            alpha: [0.85],
        }
    }

    /// Fill the fixed matrix slots from logical ELL data
    /// (`vals`/`cols` are `block_rows * width`, row-major).
    pub fn load_matrix(&mut self, buf: &mut StepBuffers, vals: &[f32], cols: &[u32]) {
        self.static_bufs = None;
        assert_eq!(vals.len(), self.block_rows * self.width, "ELL vals size");
        assert_eq!(cols.len(), vals.len(), "ELL cols size");
        let k_pad = self.bucket.k;
        for r in 0..self.block_rows {
            let src = r * self.width;
            let dst = r * k_pad;
            buf.vals[dst..dst + self.width]
                .copy_from_slice(&vals[src..src + self.width]);
            for (d, &c) in buf.cols[dst..dst + self.width]
                .iter_mut()
                .zip(&cols[src..src + self.width])
            {
                *d = c as i32;
            }
        }
    }

    /// Execute one fused step. `buf.x[..n_rows]`, `buf.xold[..block_rows]`,
    /// `buf.bias`, `buf.dang`, `buf.alpha` must be current.
    ///
    /// Returns the new block iterate (`block_rows` long, truncating the
    /// padding) and the L1 residual against `xold`.
    ///
    /// Padded rows compute `y = dang` (all-zero ELL slots, zero bias);
    /// to keep them out of the residual we pin `xold` padding to `dang`
    /// before executing, making their |y - xold| exactly zero.
    pub fn step(&mut self, buf: &mut StepBuffers) -> Result<(Vec<f32>, f32)> {
        for v in buf.xold[self.block_rows..].iter_mut() {
            *v = buf.dang[0];
        }
        let (n, b, k) = (self.bucket.n, self.bucket.b, self.bucket.k);
        let mk = |e: xla::Error| anyhow::anyhow!("pjrt: {e}");
        debug_assert_eq!(buf.x.len(), n);
        // per-run-constant inputs live on the device across steps
        if self.static_bufs.is_none() {
            let vals = self
                .client
                .buffer_from_host_buffer(&buf.vals, &[b, k], None)
                .map_err(mk)?;
            let cols = self
                .client
                .buffer_from_host_buffer(&buf.cols, &[b, k], None)
                .map_err(mk)?;
            let bias = self
                .client
                .buffer_from_host_buffer(&buf.bias, &[b], None)
                .map_err(mk)?;
            self.static_bufs = Some((vals, cols, bias));
        }
        // per-step inputs
        let x = self.client.buffer_from_host_buffer(&buf.x, &[n], None).map_err(mk)?;
        let xold =
            self.client.buffer_from_host_buffer(&buf.xold, &[b], None).map_err(mk)?;
        let dang =
            self.client.buffer_from_host_buffer(&buf.dang, &[1], None).map_err(mk)?;
        let alpha =
            self.client.buffer_from_host_buffer(&buf.alpha, &[1], None).map_err(mk)?;
        let (vals, cols, bias) = self.static_bufs.as_ref().unwrap();

        let args: [&xla::PjRtBuffer; 7] = [vals, cols, &x, &xold, bias, &dang, &alpha];
        let result = self
            .exe
            .execute_b::<&xla::PjRtBuffer>(&args)
            .map_err(mk)?[0][0]
            .to_literal_sync()
            .map_err(mk)?;
        let (y_lit, r_lit) = result.to_tuple2().map_err(mk)?;
        let mut y = y_lit.to_vec::<f32>().map_err(mk)?;
        y.truncate(self.block_rows);
        let resid = r_lit.to_vec::<f32>().map_err(mk)?[0];
        Ok((y, resid))
    }

    pub fn logical_shape(&self) -> (usize, usize, usize) {
        (self.n_rows, self.block_rows, self.width)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine() -> Engine {
        Engine::new(super::super::default_artifacts_dir()).expect("make artifacts first")
    }

    #[test]
    fn step_matches_hand_computation() {
        let eng = engine();
        // logical problem: n=8 pages, block = rows 0..4, width 2
        let mut exe = eng.pagerank_step(8, 4, 2).unwrap();
        assert_eq!(exe.bucket().n, 1 << 10);
        let mut buf = exe.buffers();
        // row 0: 0.5*x[1] + 0.5*x[2]; row 1: 1.0*x[0]; rows 2,3: empty
        let vals = [0.5, 0.5, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0];
        let cols = [1u32, 2, 0, 0, 0, 0, 0, 0];
        exe.load_matrix(&mut buf, &vals, &cols);
        for i in 0..8 {
            buf.x[i] = (i + 1) as f32 / 10.0; // 0.1 .. 0.8
        }
        buf.xold[..4].copy_from_slice(&[0.1, 0.2, 0.3, 0.4]);
        for b in buf.bias[..4].iter_mut() {
            *b = 0.15 / 8.0;
        }
        buf.dang = [0.01];
        buf.alpha = [0.85];
        let (y, resid) = exe.step(&mut buf).unwrap();
        assert_eq!(y.len(), 4);
        let expect = |sp: f32| 0.85 * sp + 0.01 + 0.15 / 8.0;
        let want = [
            expect(0.5 * 0.2 + 0.5 * 0.3),
            expect(1.0 * 0.1),
            expect(0.0),
            expect(0.0),
        ];
        let mut resid_want = 0.0f32;
        for i in 0..4 {
            assert!((y[i] - want[i]).abs() < 1e-6, "y[{i}]={} want {}", y[i], want[i]);
            resid_want += (want[i] - buf.xold[i]).abs();
        }
        assert!((resid - resid_want).abs() < 1e-5, "resid {resid} want {resid_want}");
    }

    #[test]
    fn padded_rows_do_not_pollute_residual() {
        let eng = engine();
        let mut exe = eng.pagerank_step(8, 4, 2).unwrap();
        let mut buf = exe.buffers();
        // zero matrix, zero bias, nonzero dang: y = dang everywhere.
        buf.dang = [0.25];
        buf.xold[..4].copy_from_slice(&[0.25; 4]);
        let (y, resid) = exe.step(&mut buf).unwrap();
        assert!(y.iter().all(|&v| (v - 0.25).abs() < 1e-7));
        assert!(resid.abs() < 1e-6, "padding leaked into residual: {resid}");
    }

    #[test]
    fn engine_caches_executables() {
        let eng = engine();
        let a = eng.pagerank_step(8, 4, 2).unwrap();
        let b = eng.pagerank_step(100, 50, 4).unwrap(); // same tiny bucket
        assert_eq!(a.bucket(), b.bucket());
        assert_eq!(eng.inner.cache.lock().unwrap().len(), 1);
    }
}
