//! Vector norms and residuals (the convergence signals of §5.2).

/// ||x||_1 with the full f64 tally exposed (at web scale an f32 sum
/// of 10⁶ terms carries rounding error the same order as the 1e-6
/// thresholds being certified — keep storage f32, accumulate f64).
pub fn l1_norm_f64(x: &[f32]) -> f64 {
    x.iter().map(|v| v.abs() as f64).sum::<f64>()
}

/// ||x||_1, narrowed for f32 call sites.
pub fn l1_norm(x: &[f32]) -> f32 {
    l1_norm_f64(x) as f32
}

/// ||a - b||_1 — the local/global convergence criterion of the paper —
/// with the full f64 tally exposed (see [`l1_norm_f64`]).
pub fn l1_diff_f64(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs() as f64).sum::<f64>()
}

/// ||a - b||_1, narrowed for f32 call sites.
pub fn l1_diff(a: &[f32], b: &[f32]) -> f32 {
    l1_diff_f64(a, b) as f32
}

/// ||a - b||_inf.
pub fn linf_diff(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

/// Normalize x to unit L1 norm in place (the final renormalization the
/// paper notes can be "factored out in the end"; Lubachevsky–Mitra).
pub fn normalize_l1(x: &mut [f32]) {
    let s = l1_norm(x);
    if s > 0.0 {
        for v in x.iter_mut() {
            *v /= s;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn norms_basics() {
        assert_eq!(l1_norm(&[1.0, -2.0, 3.0]), 6.0);
        assert_eq!(l1_diff(&[1.0, 2.0], &[0.0, 4.0]), 3.0);
        assert_eq!(linf_diff(&[1.0, 2.0], &[0.0, 4.0]), 2.0);
    }

    #[test]
    fn normalize_unit_sum() {
        let mut x = vec![1.0, 3.0];
        normalize_l1(&mut x);
        assert_eq!(x, vec![0.25, 0.75]);
        let mut z = vec![0.0, 0.0];
        normalize_l1(&mut z); // no NaN on zero vector
        assert_eq!(z, vec![0.0, 0.0]);
    }
}
