//! PageRank numerics: the paper's formulations (§2–§3) as operators
//! over [`crate::graph::Csr`]/[`crate::graph::Ell`], synchronous
//! baselines, residual/ranking metrics, and an extrapolation
//! accelerator (paper refs [17–19] family) used in ablations.
//!
//! All formulations avoid materializing `S` or `G`: the dense rank-one
//! pieces (`w d^T` dangling redistribution and `(1-α) v e^T` teleport)
//! are applied implicitly, which is what makes the computation feasible
//! at web scale (§1).

mod operators;
mod power;
mod linsys;
mod ranking;
mod residual;
mod extrapolation;

pub use extrapolation::aitken_extrapolate;
pub use linsys::{gauss_seidel, jacobi, LinsysOptions};
pub use operators::PagerankProblem;
pub use power::{power_method, PowerOptions, PowerResult};
pub use ranking::{kendall_tau, rank_of, top_k_ids, top_k_overlap};
pub use residual::{l1_diff, l1_diff_f64, l1_norm, l1_norm_f64, linf_diff, normalize_l1};
