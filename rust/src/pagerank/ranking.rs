//! Ranking metrics.
//!
//! §5.2: "what is important are not the accurate values of the PageRank
//! vector components, but their relative ranking. Therefore, an issue in
//! our present investigations is the effect of a more relaxed global
//! threshold criterion on the computed page ranks." Experiment A4
//! quantifies this with Kendall-τ and top-k overlap between the vector
//! computed at a relaxed threshold and a tight reference.

/// Indices of pages sorted by descending score (ties by index for
/// determinism). Generic over the score type: the static stack ranks
/// f32 iterates, the stream subsystem f64 push states — both share one
/// implementation instead of round-tripping through f32.
pub fn rank_of<T: PartialOrd>(x: &[T]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..x.len()).collect();
    idx.sort_by(|&a, &b| {
        x[b].partial_cmp(&x[a]).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
    });
    idx
}

/// Ids of the top-k entries of a score vector (descending score, ties
/// by index), clamped to `x.len()` — the shared "what would we serve"
/// idiom used by the stream subsystem's certified-head audits.
pub fn top_k_ids<T: PartialOrd>(x: &[T], k: usize) -> Vec<u32> {
    let mut ids = rank_of(x);
    ids.truncate(k.min(x.len()));
    ids.into_iter().map(|i| i as u32).collect()
}

/// Fraction of the top-k sets shared by two score vectors.
pub fn top_k_overlap<T: PartialOrd>(a: &[T], b: &[T], k: usize) -> f64 {
    assert_eq!(a.len(), b.len());
    let k = k.min(a.len());
    if k == 0 {
        return 1.0;
    }
    let ra: std::collections::HashSet<usize> = rank_of(a)[..k].iter().copied().collect();
    let rb: std::collections::HashSet<usize> = rank_of(b)[..k].iter().copied().collect();
    ra.intersection(&rb).count() as f64 / k as f64
}

/// Kendall rank correlation τ-a between two score vectors, computed in
/// O(n log n) with a merge-sort inversion count over b's scores taken
/// in a's rank order.
pub fn kendall_tau<T: PartialOrd>(a: &[T], b: &[T]) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len();
    if n < 2 {
        return 1.0;
    }
    let order = rank_of(a);
    // positions of each item in b's ranking
    let rb = rank_of(b);
    let mut pos_in_b = vec![0usize; n];
    for (pos, &item) in rb.iter().enumerate() {
        pos_in_b[item] = pos;
    }
    let seq: Vec<usize> = order.iter().map(|&i| pos_in_b[i]).collect();
    let inversions = count_inversions(seq);
    let pairs = n * (n - 1) / 2;
    1.0 - 2.0 * inversions as f64 / pairs as f64
}

fn count_inversions(mut xs: Vec<usize>) -> u64 {
    let mut buf = vec![0usize; xs.len()];
    fn rec(xs: &mut [usize], buf: &mut [usize]) -> u64 {
        let n = xs.len();
        if n <= 1 {
            return 0;
        }
        let mid = n / 2;
        let (l, r) = xs.split_at_mut(mid);
        let mut inv = rec(l, &mut buf[..mid]) + rec(r, &mut buf[mid..]);
        // merge
        let (mut i, mut j, mut k) = (0usize, 0usize, 0usize);
        while i < l.len() && j < r.len() {
            if l[i] <= r[j] {
                buf[k] = l[i];
                i += 1;
            } else {
                buf[k] = r[j];
                inv += (l.len() - i) as u64;
                j += 1;
            }
            k += 1;
        }
        while i < l.len() {
            buf[k] = l[i];
            i += 1;
            k += 1;
        }
        while j < r.len() {
            buf[k] = r[j];
            j += 1;
            k += 1;
        }
        xs.copy_from_slice(&buf[..n]);
        inv
    }
    rec(&mut xs, &mut buf)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rank_of_orders_descending() {
        assert_eq!(rank_of(&[0.1, 0.5, 0.3]), vec![1, 2, 0]);
        // ties broken by index
        assert_eq!(rank_of(&[0.5, 0.5]), vec![0, 1]);
    }

    #[test]
    fn tau_identical_is_one() {
        let x = [0.4f32, 0.1, 0.3, 0.2];
        assert!((kendall_tau(&x, &x) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn tau_reversed_is_minus_one() {
        let a = [1.0f32, 2.0, 3.0, 4.0];
        let b = [4.0f32, 3.0, 2.0, 1.0];
        assert!((kendall_tau(&a, &b) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn tau_single_swap() {
        // 4 elements, one adjacent transposition: tau = 1 - 2*1/6
        let a = [4.0f32, 3.0, 2.0, 1.0];
        let b = [4.0f32, 3.0, 1.0, 2.0];
        assert!((kendall_tau(&a, &b) - (1.0 - 2.0 / 6.0)).abs() < 1e-12);
    }

    #[test]
    fn tau_matches_naive_on_random() {
        let mut rng = crate::util::Rng::new(12);
        for _ in 0..20 {
            let n = rng.range(2, 40);
            let a: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
            // naive O(n^2) tau
            let ra = rank_of(&a);
            let rb = rank_of(&b);
            let mut pos_a = vec![0usize; n];
            let mut pos_b = vec![0usize; n];
            for (p, &i) in ra.iter().enumerate() {
                pos_a[i] = p;
            }
            for (p, &i) in rb.iter().enumerate() {
                pos_b[i] = p;
            }
            let mut concordant = 0i64;
            let mut discordant = 0i64;
            for i in 0..n {
                for j in i + 1..n {
                    let s = (pos_a[i] as i64 - pos_a[j] as i64)
                        * (pos_b[i] as i64 - pos_b[j] as i64);
                    if s > 0 {
                        concordant += 1;
                    } else {
                        discordant += 1;
                    }
                }
            }
            let naive =
                (concordant - discordant) as f64 / (n * (n - 1) / 2) as f64;
            let fast = kendall_tau(&a, &b);
            assert!((naive - fast).abs() < 1e-9, "n={n}: {naive} vs {fast}");
        }
    }

    #[test]
    fn rank_metrics_are_float_width_generic() {
        // the stream subsystem is f64 end to end; the rank metrics must
        // not force a lossy round-trip through f32
        let a = [0.4f64, 0.1, 0.3, 0.2];
        assert_eq!(rank_of(&a), vec![0, 2, 3, 1]);
        assert_eq!(top_k_ids(&a, 2), vec![0, 2]);
        // k beyond the vector clamps instead of panicking
        assert_eq!(top_k_ids(&a, 10), vec![0, 2, 3, 1]);
        assert!((kendall_tau(&a, &a) - 1.0).abs() < 1e-12);
        // two f64 scores that collide at f32 precision must still rank
        // (and overlap) by their true order
        let hi = 0.5f64;
        let lo = hi - 1e-12;
        assert_eq!(hi as f32, lo as f32, "gap must be sub-f32");
        let x = [hi, lo, 0.1];
        let y = [lo, hi, 0.1];
        assert_eq!(top_k_overlap(&x, &y, 1), 0.0);
        assert_eq!(top_k_overlap(&x, &y, 2), 1.0);
    }

    #[test]
    fn top_k_overlap_basics() {
        let a = [0.9f32, 0.8, 0.1, 0.05];
        let b = [0.9f32, 0.05, 0.8, 0.1];
        assert_eq!(top_k_overlap(&a, &b, 1), 1.0);
        assert_eq!(top_k_overlap(&a, &b, 2), 0.5);
        assert_eq!(top_k_overlap(&a, &b, 4), 1.0);
        assert_eq!(top_k_overlap(&a, &b, 0), 1.0);
    }
}
