//! Linear-system formulation (eq. 2): `(I - R) x = b`, `R = αS`,
//! `b = (1-α) v` — with Jacobi (identical iteration matrix to the power
//! method, §4) and Gauss–Seidel (the classical sequential accelerator;
//! baseline [16] uses this family) solvers.

use super::operators::PagerankProblem;
use super::power::{PowerOptions, PowerResult};
use super::residual::l1_diff;

/// Options shared by the linsys solvers.
pub type LinsysOptions = PowerOptions;

/// Jacobi iteration `x ← R x + b`. The paper notes this "can be seen to
/// be identical to (4)" — the test below asserts exactly that.
pub fn jacobi(p: &PagerankProblem, opts: &LinsysOptions) -> PowerResult {
    // apply_linsys == apply_google; reuse the power loop.
    let mut x = p.uniform_start();
    let mut y = vec![0.0f32; p.n()];
    let mut trace = Vec::new();
    let mut resid = f32::INFINITY;
    let mut iters = 0;
    while iters < opts.max_iters {
        p.apply_linsys(&x, &mut y);
        resid = l1_diff(&x, &y);
        std::mem::swap(&mut x, &mut y);
        iters += 1;
        if opts.record_residuals {
            trace.push(resid);
        }
        if resid < opts.tol {
            break;
        }
    }
    PowerResult { x, iters, converged: resid < opts.tol, residual: resid, residual_trace: trace }
}

/// Gauss–Seidel: in-place sweep using already-updated components.
/// Converges in fewer iterations than Jacobi on PageRank systems (the
/// classical result the paper's baseline [16] exploits); each sweep
/// costs the same O(nnz + n).
///
/// Implementation note: the dangling rank-one term couples every row
/// to every x_j; freezing it for a whole sweep degrades GS back toward
/// Jacobi. We instead maintain the dangling mass *incrementally* (an
/// O(1) update whenever a dangling page's score changes), which keeps
/// the sweep exact and O(nnz + n).
pub fn gauss_seidel(p: &PagerankProblem, opts: &LinsysOptions) -> PowerResult {
    let n = p.n();
    let mut x = p.uniform_start();
    let mut trace = Vec::new();
    let mut resid = f32::INFINITY;
    let mut iters = 0;
    let one_minus = 1.0 - p.alpha;
    let mut is_dangling = vec![false; n];
    for &d in p.csr.dangling() {
        is_dangling[d as usize] = true;
    }
    let inv_n = 1.0 / n as f64;
    let mut dang_mass: f64 = p.csr.dangling_dot(&x) as f64;
    while iters < opts.max_iters {
        let mut delta = 0.0f64;
        for i in 0..n {
            let (cols, vals) = p.csr.row(i);
            let mut acc = 0.0f32;
            for (c, v) in cols.iter().zip(vals) {
                acc += v * x[*c as usize];
            }
            let new = p.alpha * acc
                + (p.alpha as f64 * dang_mass * inv_n) as f32
                + one_minus * p.v_at(i);
            delta += (new - x[i]).abs() as f64;
            if is_dangling[i] {
                dang_mass += (new - x[i]) as f64;
            }
            x[i] = new;
        }
        resid = delta as f32;
        iters += 1;
        if opts.record_residuals {
            trace.push(resid);
        }
        if resid < opts.tol {
            break;
        }
    }
    PowerResult { x, iters, converged: resid < opts.tol, residual: resid, residual_trace: trace }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generators, Csr};
    use crate::pagerank::power::power_method;
    use crate::pagerank::residual::normalize_l1;

    fn web(n: usize, seed: u64) -> PagerankProblem {
        let el = generators::power_law_web(&generators::WebParams::scaled(n), seed);
        PagerankProblem::new(Csr::from_edgelist(&el).unwrap(), 0.85)
    }

    #[test]
    fn jacobi_identical_to_power_method() {
        let p = web(2_000, 5);
        let opts = LinsysOptions::default();
        let a = power_method(&p, &opts);
        let b = jacobi(&p, &opts);
        assert_eq!(a.iters, b.iters);
        assert_eq!(a.x, b.x, "eq. (4) and eq. (2)+Jacobi must coincide exactly");
    }

    #[test]
    fn gauss_seidel_converges_faster_same_answer() {
        let p = web(2_000, 6);
        let opts = LinsysOptions::default();
        let pm = power_method(&p, &opts);
        let gs = gauss_seidel(&p, &opts);
        assert!(gs.converged);
        assert!(
            gs.iters < pm.iters,
            "GS {} should beat Jacobi/power {}",
            gs.iters,
            pm.iters
        );
        let mut a = pm.x.clone();
        let mut b = gs.x.clone();
        normalize_l1(&mut a);
        normalize_l1(&mut b);
        let diff = super::l1_diff(&a, &b);
        assert!(diff < 5e-5, "solutions differ by {diff}");
    }
}
