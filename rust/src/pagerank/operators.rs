//! The PageRank problem: matrices of §2 as implicit operators.

use crate::graph::Csr;

/// A fully specified PageRank instance: the normalized link structure
/// (`P^T` in CSR), the relaxation parameter α, and the teleportation
/// distribution v (None = uniform w = e/n).
///
/// The Google matrix `G = α(P^T + w d^T) + (1-α) v e^T` is never
/// materialized; [`PagerankProblem::apply_google`] computes `G x` in
/// O(nnz + n) using the identities of §2.
#[derive(Debug, Clone)]
pub struct PagerankProblem {
    pub csr: Csr,
    pub alpha: f32,
    /// Teleport distribution; uniform if None. Must sum to 1.
    pub v: Option<Vec<f32>>,
}

impl PagerankProblem {
    pub fn new(csr: Csr, alpha: f32) -> Self {
        assert!((0.0..1.0).contains(&alpha), "alpha must be in [0,1)");
        PagerankProblem { csr, alpha, v: None }
    }

    pub fn with_teleport(mut self, v: Vec<f32>) -> Self {
        assert_eq!(v.len(), self.csr.n());
        let s: f64 = v.iter().map(|&x| x as f64).sum();
        assert!((s - 1.0).abs() < 1e-4, "teleport vector must sum to 1, got {s}");
        self.v = Some(v);
        self
    }

    pub fn n(&self) -> usize {
        self.csr.n()
    }

    /// Teleport probability of page i: v_i or 1/n.
    #[inline]
    pub fn v_at(&self, i: usize) -> f32 {
        match &self.v {
            Some(v) => v[i],
            None => 1.0 / self.n() as f32,
        }
    }

    /// The teleport bias vector b = (1-α) v of eq. (2), restricted to
    /// [lo, hi). This is the `bias` artifact argument.
    pub fn bias_range(&self, lo: usize, hi: usize) -> Vec<f32> {
        (lo..hi).map(|i| (1.0 - self.alpha) * self.v_at(i)).collect()
    }

    /// α·(d·x)/n — the dangling correction scalar (uniform w = e/n as
    /// in the paper). This is the `dang` artifact argument.
    pub fn dangling_term(&self, x: &[f32]) -> f32 {
        self.alpha * self.csr.dangling_dot(x) / self.n() as f32
    }

    /// y = G x for rows [lo, hi):
    /// `y_i = α (P^T x)_i + α (d·x)/n + (1-α) v_i`.
    pub fn apply_google_range(&self, x: &[f32], lo: usize, hi: usize, y: &mut [f32]) {
        self.csr.spmv_range(x, lo, hi, y);
        let dang = self.dangling_term(x);
        let one_minus = 1.0 - self.alpha;
        for (k, i) in (lo..hi).enumerate() {
            y[k] = self.alpha * y[k] + dang + one_minus * self.v_at(i);
        }
    }

    /// Full y = G x.
    pub fn apply_google(&self, x: &[f32], y: &mut [f32]) {
        self.apply_google_range(x, 0, self.n(), y)
    }

    /// y = R x + b of eq. (2) (`R = α S`, `b = (1-α) v`): identical to
    /// `apply_google` for stochastic x — kept as a distinct entry point
    /// because eq. (7) is the kernel the asynchronous *linear-system*
    /// variant iterates, and tests assert the identity.
    pub fn apply_linsys(&self, x: &[f32], y: &mut [f32]) {
        self.apply_google(x, y)
    }

    /// Uniform starting vector x(0) = e/n.
    pub fn uniform_start(&self) -> Vec<f32> {
        vec![1.0 / self.n() as f32; self.n()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::EdgeList;

    fn toy_problem() -> PagerankProblem {
        let el = EdgeList::from_edges(4, vec![(0, 1), (0, 2), (1, 2), (2, 0)]).unwrap();
        PagerankProblem::new(Csr::from_edgelist(&el).unwrap(), 0.85)
    }

    #[test]
    fn google_apply_preserves_mass() {
        let p = toy_problem();
        let x = p.uniform_start();
        let mut y = vec![0.0; 4];
        p.apply_google(&x, &mut y);
        let sum: f32 = y.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6, "G is stochastic: sum {sum}");
    }

    #[test]
    fn google_matches_dense_construction() {
        let p = toy_problem();
        let n = 4;
        let a = p.alpha;
        // dense G
        let mut pt = [[0.0f32; 4]; 4];
        pt[1][0] = 0.5;
        pt[2][0] = 0.5;
        pt[2][1] = 1.0;
        pt[0][2] = 1.0;
        let d = [0.0, 0.0, 0.0, 1.0f32];
        let mut g = [[0.0f32; 4]; 4];
        for i in 0..n {
            for j in 0..n {
                let s = pt[i][j] + d[j] / n as f32;
                g[i][j] = a * s + (1.0 - a) / n as f32;
            }
        }
        let x = [0.1f32, 0.2, 0.3, 0.4];
        let mut y = vec![0.0f32; n];
        p.apply_google(&x, &mut y);
        for i in 0..n {
            let want: f32 = (0..n).map(|j| g[i][j] * x[j]).sum();
            assert!((y[i] - want).abs() < 1e-6, "row {i}: {} vs {}", y[i], want);
        }
    }

    #[test]
    fn custom_teleport_used() {
        let p = toy_problem().with_teleport(vec![1.0, 0.0, 0.0, 0.0]);
        assert_eq!(p.v_at(0), 1.0);
        assert_eq!(p.v_at(1), 0.0);
        let b = p.bias_range(0, 2);
        assert!((b[0] - 0.15).abs() < 1e-6);
        assert_eq!(b[1], 0.0);
    }

    #[test]
    #[should_panic(expected = "sum to 1")]
    fn bad_teleport_rejected() {
        toy_problem().with_teleport(vec![0.5, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn range_equals_full() {
        let p = toy_problem();
        let x = [0.4f32, 0.1, 0.3, 0.2];
        let mut full = vec![0.0f32; 4];
        p.apply_google(&x, &mut full);
        let mut part = vec![0.0f32; 2];
        p.apply_google_range(&x, 2, 4, &mut part);
        assert_eq!(&full[2..4], &part[..]);
    }

    #[test]
    fn dangling_term_scales_with_mass_on_dangling() {
        let p = toy_problem();
        assert_eq!(p.dangling_term(&[0.0, 0.0, 0.0, 0.0]), 0.0);
        let t = p.dangling_term(&[0.0, 0.0, 0.0, 1.0]);
        assert!((t - 0.85 / 4.0).abs() < 1e-7);
    }
}
