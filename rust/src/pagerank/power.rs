//! Synchronous power method (eq. 4) — the paper's baseline.
//!
//! "This is the well-known power method … except that no per-step
//! normalization needs to be performed" (§3). We iterate
//! `x(t+1) = G x(t)` until `||x(t+1) - x(t)||_1 < tol` and report the
//! iteration count that Table 1's *Synchronous / iters* column shows
//! (44 for the Stanford web at τ = 1e-6, α = 0.85).

use super::operators::PagerankProblem;
use super::residual::l1_diff;

/// Options for [`power_method`].
#[derive(Debug, Clone)]
pub struct PowerOptions {
    /// L1 convergence threshold (paper: 1e-6).
    pub tol: f32,
    /// Hard iteration cap.
    pub max_iters: usize,
    /// Record ||x(t+1)-x(t)||_1 per step (for convergence plots).
    pub record_residuals: bool,
}

impl Default for PowerOptions {
    fn default() -> Self {
        PowerOptions { tol: 1e-6, max_iters: 10_000, record_residuals: false }
    }
}

/// Outcome of a power-method run.
#[derive(Debug, Clone)]
pub struct PowerResult {
    pub x: Vec<f32>,
    pub iters: usize,
    pub converged: bool,
    /// Final ||Δx||_1.
    pub residual: f32,
    /// Per-iteration residuals if requested.
    pub residual_trace: Vec<f32>,
}

/// Run the synchronous power method from x(0) = e/n.
pub fn power_method(p: &PagerankProblem, opts: &PowerOptions) -> PowerResult {
    let mut x = p.uniform_start();
    let mut y = vec![0.0f32; p.n()];
    let mut trace = Vec::new();
    let mut resid = f32::INFINITY;
    let mut iters = 0;
    while iters < opts.max_iters {
        p.apply_google(&x, &mut y);
        resid = l1_diff(&x, &y);
        std::mem::swap(&mut x, &mut y);
        iters += 1;
        if opts.record_residuals {
            trace.push(resid);
        }
        if resid < opts.tol {
            break;
        }
    }
    PowerResult { x, iters, converged: resid < opts.tol, residual: resid, residual_trace: trace }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generators, Csr, EdgeList};

    fn toy_problem() -> PagerankProblem {
        let el = EdgeList::from_edges(4, vec![(0, 1), (0, 2), (1, 2), (2, 0)]).unwrap();
        PagerankProblem::new(Csr::from_edgelist(&el).unwrap(), 0.85)
    }

    #[test]
    fn converges_on_toy() {
        let r = power_method(&toy_problem(), &PowerOptions::default());
        assert!(r.converged);
        assert!(r.iters < 200);
        // fixed point check: x == Gx
        let p = toy_problem();
        let mut y = vec![0.0; 4];
        p.apply_google(&r.x, &mut y);
        assert!(l1_diff(&r.x, &y) < 2e-6);
        // mass preserved
        let s: f32 = r.x.iter().sum();
        assert!((s - 1.0).abs() < 1e-4);
    }

    #[test]
    fn residual_trace_monotonic_ish() {
        let p = toy_problem();
        let r = power_method(
            &p,
            &PowerOptions { record_residuals: true, ..Default::default() },
        );
        assert_eq!(r.residual_trace.len(), r.iters);
        // geometric decay: later residuals below alpha^k envelope
        let first = r.residual_trace[0];
        let last = *r.residual_trace.last().unwrap();
        assert!(last < first);
    }

    #[test]
    fn iteration_count_band_on_web_graph() {
        // The paper reports 44 iterations at tol=1e-6, alpha=0.85 on the
        // Stanford web. The bound is iters ≈ log(tol)/log(alpha) ≈ 85,
        // with real webs converging roughly twice as fast. Check our
        // synthetic web lands in a sane band (30..90).
        let el = generators::power_law_web(&generators::WebParams::scaled(20_000), 3);
        let p = PagerankProblem::new(Csr::from_edgelist(&el).unwrap(), 0.85);
        let r = power_method(&p, &PowerOptions::default());
        assert!(r.converged);
        assert!(
            (30..=90).contains(&r.iters),
            "iters {} outside the plausible band",
            r.iters
        );
    }

    #[test]
    fn respects_max_iters() {
        let p = toy_problem();
        let r = power_method(&p, &PowerOptions { max_iters: 2, ..Default::default() });
        assert_eq!(r.iters, 2);
        assert!(!r.converged);
    }

    #[test]
    fn higher_alpha_slower_convergence() {
        let el = generators::power_law_web(&generators::WebParams::scaled(5_000), 4);
        let g = Csr::from_edgelist(&el).unwrap();
        let fast = power_method(
            &PagerankProblem::new(g.clone(), 0.5),
            &PowerOptions::default(),
        );
        let slow = power_method(&PagerankProblem::new(g, 0.95), &PowerOptions::default());
        assert!(fast.iters < slow.iters);
    }
}
