//! Aitken Δ² extrapolation — the acceleration family of the paper's
//! refs [17–19] (Kamvar et al., "Extrapolation Methods for Accelerating
//! PageRank Computations"). Used by the ablation bench to show the
//! sync baseline can be tightened, and that async speedups survive it.

/// Componentwise Aitken Δ² from three consecutive iterates.
///
/// For each i: `x'_i = x2_i - (Δ2_i)² / ΔΔ_i` with `Δ2 = x2 - x1`,
/// `ΔΔ = x2 - 2 x1 + x0`, falling back to `x2_i` when the denominator
/// underflows (component already converged).
pub fn aitken_extrapolate(x0: &[f32], x1: &[f32], x2: &[f32]) -> Vec<f32> {
    assert_eq!(x0.len(), x1.len());
    assert_eq!(x1.len(), x2.len());
    x0.iter()
        .zip(x1)
        .zip(x2)
        .map(|((&a, &b), &c)| {
            let d2 = c - b;
            let dd = c - 2.0 * b + a;
            if dd.abs() > 1e-12 {
                let e = c - d2 * d2 / dd;
                if e.is_finite() {
                    e
                } else {
                    c
                }
            } else {
                c
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generators, Csr};
    use crate::pagerank::{power_method, PagerankProblem, PowerOptions};
    use crate::pagerank::residual::{l1_diff, normalize_l1};

    #[test]
    fn exact_on_scalar_geometric_sequence() {
        // x_k = x* + c r^k has Aitken limit exactly x*
        let (xs, c, r) = (0.7f32, 0.3f32, 0.5f32);
        let seq: Vec<f32> = (0..3).map(|k| xs + c * r.powi(k)).collect();
        let e = aitken_extrapolate(&[seq[0]], &[seq[1]], &[seq[2]]);
        assert!((e[0] - xs).abs() < 1e-6, "{e:?}");
    }

    #[test]
    fn converged_components_pass_through() {
        let x = [0.5f32, 0.25];
        let e = aitken_extrapolate(&x, &x, &x);
        assert_eq!(e, x.to_vec());
    }

    #[test]
    fn accelerates_pagerank_iterates() {
        // Aitken assumes per-component geometric error decay. PageRank's
        // slow modes (mutual pairs) have eigenvalue −α, so CONSECUTIVE
        // iterates alternate and componentwise Δ² misfires; applying it
        // to STRIDE-2 iterates (x_k, x_{k+2}, x_{k+4}) sees the squared
        // ratio α² > 0 and converges — this is the form the ablation
        // bench uses (cf. Kamvar et al.'s Aᵏ extrapolation).
        let mut params = generators::WebParams::scaled(3_000);
        params.couple_frac = 0.2;
        let el = generators::power_law_web(&params, 9);
        let p = PagerankProblem::new(Csr::from_edgelist(&el).unwrap(), 0.9);
        let mut xstar =
            power_method(&p, &PowerOptions { tol: 1e-9, max_iters: 3000, ..Default::default() }).x;
        normalize_l1(&mut xstar);
        // iterates x_16, x_18, x_20 (dominant mode well separated)
        let n = p.n();
        let mut xs = vec![p.uniform_start()];
        for _ in 0..20 {
            let mut y = vec![0.0; n];
            p.apply_google(xs.last().unwrap(), &mut y);
            xs.push(y);
        }
        let mut plain = xs[20].clone();
        let mut extr = aitken_extrapolate(&xs[16], &xs[18], &xs[20]);
        normalize_l1(&mut plain);
        normalize_l1(&mut extr);
        let e_plain = l1_diff(&plain, &xstar);
        let e_extr = l1_diff(&extr, &xstar);
        assert!(
            e_extr < e_plain * 0.5,
            "stride-2 extrapolation should cut error: {e_extr} vs {e_plain}"
        );
    }
}
