//! Shared-Ethernet transfer model with cancellation windows.
//!
//! The paper's cluster hangs off one 10 Mbps LAN: all transfers share
//! the wire. We model it as a FIFO resource — a transfer enqueued at
//! `t` starts when the wire frees up, takes `bytes/bandwidth`, and is
//! delivered `latency` later. The §6 guard ("we guard against this
//! misfortune by cancelling send()/recv() threads not having completed
//! within a time window") becomes: if the transfer cannot *finish*
//! within `cancel_window` of its enqueue, it is dropped at enqueue time
//! (the sender's thread is cancelled; the paper's Table 2 counts the
//! survivors as "completed imports").

use super::clock::VirtualTime;

/// Outcome of attempting a transfer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SendOutcome {
    /// Transfer accepted; fragment arrives at `deliver_at`.
    Delivered { deliver_at: VirtualTime },
    /// Transfer cancelled (would exceed the cancellation window).
    Cancelled,
}

/// The shared wire.
#[derive(Debug, Clone)]
pub struct SharedMedium {
    /// Bytes per (virtual) second, e.g. 1.25e6 for 10 Mbps.
    bandwidth: f64,
    /// Per-message propagation + protocol latency, seconds.
    latency: f64,
    /// None = never cancel (sync mode); Some(w) = drop transfers that
    /// could not complete within `w` seconds of enqueue.
    cancel_window: Option<f64>,
    /// When the wire next becomes free.
    free_at: VirtualTime,
    /// Counters for §6's buffer-bloat observations.
    pub sent: u64,
    pub cancelled: u64,
    /// Total queue-wait seconds accumulated (buffer pressure metric).
    pub queue_wait: f64,
}

impl SharedMedium {
    pub fn new(bandwidth: f64, latency: f64, cancel_window: Option<f64>) -> Self {
        assert!(bandwidth > 0.0 && latency >= 0.0);
        SharedMedium {
            bandwidth,
            latency,
            cancel_window,
            free_at: VirtualTime::ZERO,
            sent: 0,
            cancelled: 0,
            queue_wait: 0.0,
        }
    }

    /// Queue depth in seconds at time `now` (how far ahead the wire is
    /// booked) — the sender-side buffer pressure of §6.
    pub fn backlog(&self, now: VirtualTime) -> f64 {
        (self.free_at.secs() - now.secs()).max(0.0)
    }

    /// Attempt to transfer `bytes` enqueued at `now`.
    pub fn send(&mut self, now: VirtualTime, bytes: f64) -> SendOutcome {
        let start = self.free_at.max(now);
        let duration = bytes / self.bandwidth;
        let finish = start.after(duration);
        if let Some(w) = self.cancel_window {
            // could this transfer complete within the window?
            if finish.secs() - now.secs() > w {
                self.cancelled += 1;
                return SendOutcome::Cancelled;
            }
        }
        self.queue_wait += start.secs() - now.secs();
        self.free_at = finish;
        self.sent += 1;
        SendOutcome::Delivered { deliver_at: finish.after(self.latency) }
    }

    /// Completed-transfer fraction (Table 2's aggregate view).
    pub fn completion_ratio(&self) -> f64 {
        let total = self.sent + self.cancelled;
        if total == 0 {
            1.0
        } else {
            self.sent as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serializes_transfers_fifo() {
        let mut m = SharedMedium::new(100.0, 0.5, None);
        let a = m.send(VirtualTime(0.0), 100.0); // 1s on the wire
        let b = m.send(VirtualTime(0.0), 100.0); // queued behind a
        match (a, b) {
            (
                SendOutcome::Delivered { deliver_at: da },
                SendOutcome::Delivered { deliver_at: db },
            ) => {
                assert!((da.secs() - 1.5).abs() < 1e-12);
                assert!((db.secs() - 2.5).abs() < 1e-12);
            }
            _ => panic!("unexpected cancel"),
        }
        assert_eq!(m.sent, 2);
        assert!((m.queue_wait - 1.0).abs() < 1e-12);
    }

    #[test]
    fn wire_idles_then_accepts() {
        let mut m = SharedMedium::new(100.0, 0.0, None);
        m.send(VirtualTime(0.0), 100.0);
        // wire free at 1.0; enqueue at 5.0 starts immediately
        match m.send(VirtualTime(5.0), 100.0) {
            SendOutcome::Delivered { deliver_at } => {
                assert!((deliver_at.secs() - 6.0).abs() < 1e-12)
            }
            _ => panic!(),
        }
        assert_eq!(m.backlog(VirtualTime(5.5)), 0.5);
    }

    #[test]
    fn cancels_when_window_exceeded() {
        let mut m = SharedMedium::new(100.0, 0.0, Some(1.5));
        assert!(matches!(m.send(VirtualTime(0.0), 100.0), SendOutcome::Delivered { .. }));
        // second transfer would finish at 2.0 > window 1.5 -> cancelled
        assert_eq!(m.send(VirtualTime(0.0), 100.0), SendOutcome::Cancelled);
        assert_eq!(m.cancelled, 1);
        assert!((m.completion_ratio() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn oversize_single_transfer_cancelled() {
        let mut m = SharedMedium::new(10.0, 0.0, Some(1.0));
        assert_eq!(m.send(VirtualTime(0.0), 100.0), SendOutcome::Cancelled);
    }

    #[test]
    fn no_window_never_cancels() {
        let mut m = SharedMedium::new(1.0, 0.0, None);
        for _ in 0..50 {
            assert!(matches!(m.send(VirtualTime(0.0), 10.0), SendOutcome::Delivered { .. }));
        }
        assert_eq!(m.cancelled, 0);
        assert_eq!(m.completion_ratio(), 1.0);
    }
}
