//! Virtual-time cluster & network simulator (substitution for the
//! paper's Beowulf testbed — DESIGN.md §3).
//!
//! The paper ran on six 900 MHz Pentiums on a **10 Mbps shared
//! Ethernet**; every phenomenon it reports (sync time *growing* with p,
//! 2× async speedup at local threshold, 28–45 % completed imports,
//! sender-side buffer bloat, cancellation windows) is a function of the
//! compute-time / bandwidth / latency ratios. We reproduce those ratios
//! in a deterministic discrete-event simulation:
//!
//! * [`EventQueue`] — stable priority queue over [`VirtualTime`];
//! * [`SharedMedium`] — the shared-Ethernet model: one transfer at a
//!   time, FIFO, serialization delay = bytes/bandwidth, plus per-hop
//!   latency and an optional *cancellation window* (the paper cancels
//!   send/recv threads that don't complete in time, §6);
//! * [`Topology`] — who exchanges fragments with whom (clique as in the
//!   paper; star/tree for the §6 future-work ablation);
//! * [`ClusterProfile`] — calibrated node/network parameters, with
//!   [`ClusterProfile::paper_beowulf`] matching the paper's testbed.

mod clock;
mod medium;
mod profile;
mod topology;

pub use clock::{EventQueue, VirtualTime};
pub use medium::{SendOutcome, SharedMedium};
pub use profile::{ClusterProfile, NodeProfile};
pub use topology::Topology;
