//! Communication topologies.
//!
//! The paper's experiments use the all-to-all ("clique") scheme and §6
//! concludes: "We would thus like to avoid the use of all-to-all
//! communication schemes … Since trees are naturally occurring
//! internetwork topologies we also plan to study the performance of
//! moving a clique-based synchronous iterative method to an
//! asynchronous, tree-based counterpart." Ablation A3 does exactly
//! that: under a tree, fragments still reach every UE, but relayed
//! through intermediate nodes (extra hops, less wire contention per
//! step because each UE emits fewer messages).

/// Who sends fragments directly to whom.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Topology {
    /// Every UE sends to every other UE (the paper's setup).
    Clique,
    /// Star centered at UE 0: leaves exchange through the hub.
    Star,
    /// Balanced binary tree rooted at UE 0: parent/child links only.
    BinaryTree,
}

impl Topology {
    /// Direct neighbors of `ue` among `p` UEs.
    pub fn neighbors(&self, ue: usize, p: usize) -> Vec<usize> {
        assert!(ue < p);
        match self {
            Topology::Clique => (0..p).filter(|&j| j != ue).collect(),
            Topology::Star => {
                if ue == 0 {
                    (1..p).collect()
                } else {
                    vec![0]
                }
            }
            Topology::BinaryTree => {
                let mut out = Vec::new();
                if ue > 0 {
                    out.push((ue - 1) / 2);
                }
                let l = 2 * ue + 1;
                let r = 2 * ue + 2;
                if l < p {
                    out.push(l);
                }
                if r < p {
                    out.push(r);
                }
                out
            }
        }
    }

    /// Number of directed fragment messages per full exchange round.
    pub fn messages_per_round(&self, p: usize) -> usize {
        (0..p).map(|u| self.neighbors(u, p).len()).sum()
    }

    /// Hop count between two UEs (for relayed fragment staleness).
    pub fn hops(&self, a: usize, b: usize, p: usize) -> usize {
        if a == b {
            return 0;
        }
        match self {
            Topology::Clique => 1,
            Topology::Star => {
                if a == 0 || b == 0 {
                    1
                } else {
                    2
                }
            }
            Topology::BinaryTree => {
                // distance in the implicit binary tree
                let (mut x, mut y) = (a, b);
                let depth = |mut v: usize| {
                    let mut d = 0;
                    while v > 0 {
                        v = (v - 1) / 2;
                        d += 1;
                    }
                    d
                };
                let (mut dx, mut dy) = (depth(x), depth(y));
                let mut dist = 0;
                while dx > dy {
                    x = (x - 1) / 2;
                    dx -= 1;
                    dist += 1;
                }
                while dy > dx {
                    y = (y - 1) / 2;
                    dy -= 1;
                    dist += 1;
                }
                while x != y {
                    x = (x - 1) / 2;
                    y = (y - 1) / 2;
                    dist += 2;
                }
                let _ = p;
                dist
            }
        }
    }

    pub fn parse(s: &str) -> Option<Topology> {
        match s {
            "clique" => Some(Topology::Clique),
            "star" => Some(Topology::Star),
            "tree" | "binary-tree" => Some(Topology::BinaryTree),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clique_all_pairs() {
        let t = Topology::Clique;
        assert_eq!(t.neighbors(1, 4), vec![0, 2, 3]);
        assert_eq!(t.messages_per_round(4), 12);
        assert_eq!(t.hops(0, 3, 4), 1);
    }

    #[test]
    fn star_hub_and_leaves() {
        let t = Topology::Star;
        assert_eq!(t.neighbors(0, 4), vec![1, 2, 3]);
        assert_eq!(t.neighbors(2, 4), vec![0]);
        assert_eq!(t.messages_per_round(4), 6);
        assert_eq!(t.hops(1, 2, 4), 2);
        assert_eq!(t.hops(0, 2, 4), 1);
    }

    #[test]
    fn tree_structure() {
        let t = Topology::BinaryTree;
        assert_eq!(t.neighbors(0, 6), vec![1, 2]);
        assert_eq!(t.neighbors(1, 6), vec![0, 3, 4]);
        assert_eq!(t.neighbors(5, 6), vec![2]);
        // fewer messages than clique at p=6
        assert!(t.messages_per_round(6) < Topology::Clique.messages_per_round(6));
        assert_eq!(t.hops(3, 4, 6), 2);
        assert_eq!(t.hops(3, 5, 6), 4);
        assert_eq!(t.hops(1, 1, 6), 0);
    }

    #[test]
    fn all_topologies_symmetric_neighbors() {
        for topo in [Topology::Clique, Topology::Star, Topology::BinaryTree] {
            for p in [2usize, 3, 6, 9] {
                for a in 0..p {
                    for &b in &topo.neighbors(a, p) {
                        assert!(
                            topo.neighbors(b, p).contains(&a),
                            "{topo:?} p={p}: {a}->{b} not symmetric"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn parse_names() {
        assert_eq!(Topology::parse("clique"), Some(Topology::Clique));
        assert_eq!(Topology::parse("tree"), Some(Topology::BinaryTree));
        assert_eq!(Topology::parse("x"), None);
    }
}
