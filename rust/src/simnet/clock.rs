//! Virtual time and the deterministic event queue.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Simulated seconds. Newtype so real `Duration`s can't leak in.
#[derive(Debug, Clone, Copy, PartialEq, PartialOrd, Default)]
pub struct VirtualTime(pub f64);

impl VirtualTime {
    pub const ZERO: VirtualTime = VirtualTime(0.0);

    pub fn secs(self) -> f64 {
        self.0
    }

    #[must_use]
    pub fn after(self, dt: f64) -> VirtualTime {
        debug_assert!(dt >= 0.0, "negative delay {dt}");
        VirtualTime(self.0 + dt)
    }

    pub fn max(self, other: VirtualTime) -> VirtualTime {
        VirtualTime(self.0.max(other.0))
    }
}

struct Entry<E> {
    at: VirtualTime,
    seq: u64,
    ev: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, o: &Self) -> bool {
        self.at == o.at && self.seq == o.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, o: &Self) -> Option<Ordering> {
        Some(self.cmp(o))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, o: &Self) -> Ordering {
        // BinaryHeap is a max-heap: invert for earliest-first; break
        // time ties by insertion order (determinism).
        o.at
            .0
            .partial_cmp(&self.at.0)
            .unwrap_or(Ordering::Equal)
            .then(o.seq.cmp(&self.seq))
    }
}

/// Deterministic discrete-event queue: pops events in (time, insertion
/// order). NaN times are rejected at push.
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    seq: u64,
    now: VirtualTime,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), seq: 0, now: VirtualTime::ZERO }
    }

    /// Current simulation time (time of the last popped event).
    pub fn now(&self) -> VirtualTime {
        self.now
    }

    /// Schedule `ev` at absolute time `at` (must not be in the past).
    pub fn push(&mut self, at: VirtualTime, ev: E) {
        assert!(!at.0.is_nan(), "NaN event time");
        assert!(at.0 >= self.now.0, "scheduling into the past: {} < {}", at.0, self.now.0);
        self.heap.push(Entry { at, seq: self.seq, ev });
        self.seq += 1;
    }

    /// Pop the earliest event, advancing `now`.
    pub fn pop(&mut self) -> Option<(VirtualTime, E)> {
        self.heap.pop().map(|e| {
            self.now = e.at;
            (e.at, e.ev)
        })
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(VirtualTime(3.0), "c");
        q.push(VirtualTime(1.0), "a");
        q.push(VirtualTime(2.0), "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn ties_break_by_insertion() {
        let mut q = EventQueue::new();
        q.push(VirtualTime(1.0), 1);
        q.push(VirtualTime(1.0), 2);
        q.push(VirtualTime(1.0), 3);
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec![1, 2, 3]);
    }

    #[test]
    fn now_advances() {
        let mut q = EventQueue::new();
        q.push(VirtualTime(5.0), ());
        assert_eq!(q.now(), VirtualTime::ZERO);
        q.pop();
        assert_eq!(q.now(), VirtualTime(5.0));
    }

    #[test]
    #[should_panic(expected = "past")]
    fn rejects_past_events() {
        let mut q = EventQueue::new();
        q.push(VirtualTime(5.0), ());
        q.pop();
        q.push(VirtualTime(1.0), ());
    }

    #[test]
    fn virtual_time_arithmetic() {
        let t = VirtualTime(1.5).after(0.5);
        assert_eq!(t, VirtualTime(2.0));
        assert_eq!(t.max(VirtualTime(1.0)), t);
        assert_eq!(t.secs(), 2.0);
    }
}
