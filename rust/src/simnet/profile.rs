//! Calibrated cluster profiles.
//!
//! [`ClusterProfile::paper_beowulf`] reproduces the paper's testbed
//! ratios (§5.2): 900 MHz Pentium III nodes running Java/MTJ sparse
//! matvecs over a 10 Mbps shared Ethernet. Calibration logic
//! (cross-checked against Table 1's synchronous column):
//!
//! * full-matrix SpMV + iteration overhead ≈ 4.0 s (2.31 M nonzeros on
//!   a 900 MHz core through Java ⇒ ~35 cycles/nnz + bookkeeping);
//!   per-UE block compute = 4.0/p.
//! * fragment size = 8 B × ⌈n/p⌉ (Java doubles on the wire);
//! * wire = 10 Mbps ⇒ 1.25e6 B/s, ~1 ms latency.
//!
//! Sanity check against the paper's sync rows, round time ≈
//! compute/p + (p−1)·n·8/BW: p=2 → 2.0+1.8 ≈ 3.8 s/iter (paper 4.07),
//! p=4 → 1.0+5.4 ≈ 6.4 (paper 7.53), p=6 → 0.67+9.0 ≈ 9.7 (paper 9.16).
//! The *shape* — communication-bound growth with p — is what Tables 1–2
//! depend on and is faithfully reproduced.

use super::Topology;

/// Per-node compute characteristics.
#[derive(Debug, Clone)]
pub struct NodeProfile {
    /// Seconds per matrix nonzero in the local block.
    pub secs_per_nnz: f64,
    /// Fixed per-iteration overhead (vector ops, bookkeeping, JVM-ish).
    pub secs_fixed: f64,
    /// Speed multiplier (1.0 = nominal; >1 = slower node). The
    /// heterogeneity example raises this on some UEs.
    pub slowdown: f64,
    /// Multiplicative jitter amplitude j: each iteration's compute time
    /// is scaled by U(1-j, 1+j). Real schedulers are noisy; jitter also
    /// breaks the artificial lockstep a perfectly symmetric DES has.
    pub jitter: f64,
    /// Seconds to deserialize + merge ONE imported fragment (§5.1's
    /// read channels with locks; Java object streams were not cheap).
    /// Raises the async iteration interval to the paper's ~1.5 s at
    /// p=4, which in turn sets Table 2's 28–45 % import ratios.
    pub secs_per_import: f64,
    /// Seconds to serialize + submit ONE outgoing fragment that makes
    /// it onto the wire (the paper wraps each send in a thread object
    /// submitted to a pool — §5.1); cancelled sends cost nothing.
    pub secs_per_send: f64,
}

impl Default for NodeProfile {
    fn default() -> Self {
        // 900 MHz P-III through Java/MTJ: ~1 µs per nonzero (a few
        // hundred cycles incl. JIT'd indirection) + 0.15 s of fixed
        // per-iteration vector work. Calibrated so the paper's async
        // p=2 rate (~1.3 s/iter over 1.16 M nnz) is reproduced.
        NodeProfile {
            secs_per_nnz: 1.0e-6,
            secs_fixed: 0.15,
            slowdown: 1.0,
            jitter: 0.05,
            secs_per_import: 0.25,
            secs_per_send: 0.2,
        }
    }
}

/// Whole-cluster parameters fed to the simulation engine.
#[derive(Debug, Clone)]
pub struct ClusterProfile {
    /// One profile per computing UE (len = p).
    pub nodes: Vec<NodeProfile>,
    /// Shared-wire bandwidth, bytes/s.
    pub bandwidth: f64,
    /// Per-message latency, seconds.
    pub latency: f64,
    /// Bytes per vector element on the wire (paper: Java doubles = 8).
    pub bytes_per_elem: f64,
    /// Size of control messages (CONVERGE/DIVERGE/STOP), bytes.
    pub control_bytes: f64,
    /// Async-mode cancellation window (seconds); None = never cancel.
    pub cancel_window: Option<f64>,
    /// Fragment exchange topology.
    pub topology: Topology,
}

impl ClusterProfile {
    /// The paper's testbed (see module docs), for `p` computing UEs.
    pub fn paper_beowulf(p: usize) -> ClusterProfile {
        ClusterProfile {
            nodes: vec![NodeProfile::default(); p],
            // 10 Mbps nominal; ~6.8 Mbps effective after TCP + Java
            // object-serialization overheads (fits the paper's sync
            // rows at p = 2/4/6 within ~25 %).
            bandwidth: 0.85e6,
            latency: 1e-3,
            bytes_per_elem: 8.0,
            control_bytes: 64.0,
            // one fragment takes ~1.1 s on the wire at p=2; a window of
            // 3 s lets a couple of transfers queue before the sender
            // thread is cancelled (§6) — calibrated against Table 2.
            cancel_window: Some(3.0),
            topology: Topology::Clique,
        }
    }

    /// Bandwidth multiplier that preserves the paper's communication /
    /// computation demand ratio when running a scaled-down graph.
    ///
    /// Fragments shrink linearly with n, but the per-iteration fixed
    /// cost does not, so a naive n-proportional wire leaves small runs
    /// far MORE saturated than the testbed. Demand ratio ∝
    /// fragment_bytes / iteration_time; this returns the scale that
    /// keeps it equal to the full-size Stanford run at the same p.
    pub fn demand_matched_scale(n_scaled: usize, p: usize) -> f64 {
        const N_FULL: f64 = 281_903.0;
        const NNZ_PER_ROW: f64 = 8.2;
        let node = NodeProfile::default();
        let iter_time = |n: f64| node.secs_per_nnz * (n * NNZ_PER_ROW / p as f64) + node.secs_fixed;
        (n_scaled as f64 / N_FULL) * (iter_time(N_FULL) / iter_time(n_scaled as f64))
    }

    /// Fast profile for unit tests (milliseconds instead of seconds).
    pub fn test_profile(p: usize) -> ClusterProfile {
        ClusterProfile {
            nodes: vec![
                NodeProfile {
                    secs_per_nnz: 1e-7,
                    secs_fixed: 1e-3,
                    slowdown: 1.0,
                    jitter: 0.02,
                    secs_per_import: 0.0,
                    secs_per_send: 0.0,
                };
                p
            ],
            bandwidth: 1e8,
            latency: 1e-4,
            bytes_per_elem: 8.0,
            control_bytes: 64.0,
            cancel_window: None,
            topology: Topology::Clique,
        }
    }

    pub fn p(&self) -> usize {
        self.nodes.len()
    }

    /// Compute time of one local iteration for UE `ue` whose block has
    /// `block_nnz` nonzeros (before jitter).
    pub fn compute_time(&self, ue: usize, block_nnz: usize) -> f64 {
        let n = &self.nodes[ue];
        (n.secs_per_nnz * block_nnz as f64 + n.secs_fixed) * n.slowdown
    }

    /// Wire bytes of one fragment of `elems` vector elements.
    pub fn fragment_bytes(&self, elems: usize) -> f64 {
        self.bytes_per_elem * elems as f64
    }

    /// Seconds one `bytes`-byte frame occupies the wire: per-message
    /// latency plus serialization at `bandwidth`. This is the curve
    /// the live loopback transport (`crate::net`) throttles deliveries
    /// with — the same numbers the DES engine uses, applied to real
    /// wall-clock instants instead of virtual time.
    pub fn wire_time(&self, bytes: f64) -> f64 {
        self.latency + bytes / self.bandwidth
    }

    /// Make UE `ue` `factor`× slower (heterogeneity experiments).
    pub fn with_slow_node(mut self, ue: usize, factor: f64) -> ClusterProfile {
        self.nodes[ue].slowdown = factor;
        self
    }

    pub fn with_topology(mut self, t: Topology) -> ClusterProfile {
        self.topology = t;
        self
    }

    pub fn with_cancel_window(mut self, w: Option<f64>) -> ClusterProfile {
        self.cancel_window = w;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_profile_reproduces_sync_round_shape() {
        // round time = compute/p + (p-1) * n * 8 / BW must GROW with p
        // (the paper's communication-bound regime).
        let n = 281_903usize;
        let nnz = 2_312_497usize;
        let mut last = 0.0;
        for p in [2usize, 4, 6] {
            let prof = ClusterProfile::paper_beowulf(p);
            let compute = prof.compute_time(0, nnz / p);
            let comm = (p - 1) as f64 * prof.fragment_bytes(n / p) * (p as f64)
                / prof.bandwidth;
            let round = compute + comm / p as f64 * 1.0 + (p - 1) as f64 * prof.latency;
            // full wire occupancy per round: p*(p-1) fragments
            let wire = p as f64 * (p - 1) as f64 * prof.fragment_bytes(n / p)
                / prof.bandwidth;
            let round_lb = compute.max(wire);
            assert!(round_lb > last, "round time must grow with p");
            last = round_lb;
            let _ = round;
        }
    }

    #[test]
    fn paper_profile_single_iteration_close_to_table1() {
        // paper sync seconds/iter: p=2: 4.07, p=4: 7.53, p=6: 9.16
        let n = 281_903usize;
        let nnz = 2_312_497usize;
        // p=6 is allowed a wider band: the paper's LAN scaled slightly
        // sub-linearly there (partial switching, most likely) while the
        // pure shared-hub model is linear in message count — documented
        // in EXPERIMENTS.md §Deviations.
        let want = [(2usize, 4.07f64, 0.35f64), (4, 7.53, 0.35), (6, 9.16, 0.60)];
        for (p, target, band) in want {
            let prof = ClusterProfile::paper_beowulf(p);
            let compute = prof.compute_time(0, nnz / p);
            let wire =
                p as f64 * (p - 1) as f64 * prof.fragment_bytes(n / p) / prof.bandwidth;
            let round = compute + wire;
            let err = (round - target).abs() / target;
            assert!(
                err < band,
                "p={p}: modeled {round:.2}s vs paper {target:.2}s (err {err:.2})"
            );
        }
    }

    #[test]
    fn demand_matched_scale_sane() {
        // full size => 1.0; smaller => between n-ratio and 1
        let full = ClusterProfile::demand_matched_scale(281_903, 4);
        assert!((full - 1.0).abs() < 1e-9);
        let s = ClusterProfile::demand_matched_scale(28_190, 4);
        assert!(s > 28_190.0 / 281_903.0 && s < 1.0, "{s}");
        let tiny = ClusterProfile::demand_matched_scale(8_000, 4);
        assert!(tiny > 8_000.0 / 281_903.0 && tiny < s, "{tiny}");
    }

    #[test]
    fn builders() {
        let prof = ClusterProfile::paper_beowulf(4)
            .with_slow_node(2, 3.0)
            .with_topology(Topology::Star)
            .with_cancel_window(None);
        assert_eq!(prof.nodes[2].slowdown, 3.0);
        assert_eq!(prof.topology, Topology::Star);
        assert!(prof.cancel_window.is_none());
        assert!(prof.compute_time(2, 1000) > prof.compute_time(1, 1000));
    }
}
