//! Residual-push PageRank with Gauss–Southwell scheduling — the
//! incremental operator of the `stream` subsystem.
//!
//! We solve the linear-system formulation (paper eq. 2)
//! `x = α S x + (1-α) v`, `S = P^T + w d^T`, by maintaining the classic
//! push invariant (D-Iteration / Gauss–Southwell; Hong–Huynh–Mathieu
//! 2015, Berkhin 2006):
//!
//! ```text
//!     x* = p + (I - αS)^{-1} (r + rd·e/n)
//! ```
//!
//! `p` is the estimate, `r` the materialized residual, and `rd` a
//! *pending uniform* residual scalar that stands for `rd/n` mass on
//! every node. The scalar absorbs the two dense rank-one couplings that
//! would otherwise force O(n) work per operation: dangling-page
//! redistribution (`w d^T`, a column `e/n` per dangling page) and the
//! teleport right-hand side `(1-α) e/n` itself. It is flushed into `r`
//! in O(n) only when it accumulates enough mass to matter.
//!
//! One **push** at node `u` moves `r[u]` into `p[u]` and re-emits
//! `α·r[u]` through `u`'s out-links (or into `rd` when `u` dangles).
//! Each push strictly removes `(1-α)·|r[u]|` of residual mass, so
//! greedy largest-first scheduling — approximated by a power-of-two
//! [`BucketQueue`] — converges with pushes proportional to the residual
//! mass, **not** to the graph size. That is what makes warm-starting
//! pay: after a graph delta, [`PushState::apply_batch`] injects exactly
//! the residual the delta created (`α(S' - S)p` plus teleport/size
//! corrections), and the subsequent [`PushState::solve`] does work
//! proportional to the *change*, while a cold solve pays for the whole
//! graph. Negative residuals (edge deletions) push the same way with
//! negative mass.
//!
//! Everything here is f64: epoch-over-epoch accumulation would eat an
//! f32's 24-bit mantissa, and the from-scratch equivalence tests pin
//! incremental vs. cold solves to 1e-8 in L1.

use std::sync::Arc;

use super::delta::{AppliedDelta, DeltaGraph};
use super::pers::Personalization;

/// Approximate-max priority queue over residual magnitudes — shared by
/// [`PushState`] (global solves) and `PushBlockOp` (block-local inner
/// solves).
///
/// Bucket `i` holds nodes whose |r| is in `[2^-(i+1), 2^-i)`; popping
/// scans from the hottest bucket. Entries are lazy: a node is pushed
/// whenever its bucket changes and validated against `cur` on pop, so
/// updates are O(1) and stale entries cost one skip each.
#[derive(Debug, Clone)]
pub(crate) struct BucketQueue {
    buckets: Vec<Vec<u32>>,
    /// Current bucket per node (`NONE` = not queued).
    cur: Vec<u16>,
    /// Lowest possibly non-empty bucket.
    hint: usize,
}

const NB: usize = 96; // 2^-96 ≈ 1e-29, far below any tolerance in use
const NONE: u16 = u16::MAX;

impl BucketQueue {
    pub(crate) fn new(n: usize) -> Self {
        BucketQueue { buckets: vec![Vec::new(); NB], cur: vec![NONE; n], hint: NB }
    }

    pub(crate) fn grow(&mut self, n: usize) {
        debug_assert!(n >= self.cur.len());
        self.cur.resize(n, NONE);
    }

    #[inline]
    fn bucket_of(vabs: f64) -> Option<usize> {
        // A NaN magnitude would slip past `<= 0.0`, land in bucket 0
        // (`-NaN.log2() as usize` is 0) and loop forever: pushing a NaN
        // residual re-emits NaN, so the queue never drains. Residuals
        // can only go non-finite through a poisoned input (a degenerate
        // personalization vector, an inf weight), so fail loudly in
        // debug builds and refuse to queue the node in release — the
        // exact recompute before convergence still surfaces the damage.
        debug_assert!(
            vabs.is_finite(),
            "non-finite residual magnitude {vabs} reached the bucket queue"
        );
        if !vabs.is_finite() || vabs <= 0.0 {
            return None;
        }
        let e = -vabs.log2();
        let i = if e < 0.0 { 0 } else { e as usize };
        Some(i.min(NB - 1))
    }

    /// Record that node `t` now has residual magnitude `vabs`.
    #[inline]
    pub(crate) fn update(&mut self, t: usize, vabs: f64) {
        match Self::bucket_of(vabs) {
            None => self.cur[t] = NONE,
            Some(b) => {
                if self.cur[t] != b as u16 {
                    self.cur[t] = b as u16;
                    self.buckets[b].push(t as u32);
                    if b < self.hint {
                        self.hint = b;
                    }
                }
            }
        }
    }

    /// Build a queue seeded from a residual slice, returning it with
    /// the slice's Σ|r| — the shared rebuild step after a wholesale
    /// state swap (scatter, gather-adopt, shard-bounds migration).
    pub(crate) fn seeded_from(r: &[f64]) -> (BucketQueue, f64) {
        let mut q = BucketQueue::new(r.len());
        let mut l1 = 0.0f64;
        for (t, v) in r.iter().enumerate() {
            l1 += v.abs();
            q.update(t, v.abs());
        }
        (q, l1)
    }

    /// Pop the node in the hottest bucket (approximate argmax |r|).
    pub(crate) fn pop(&mut self) -> Option<usize> {
        while self.hint < NB {
            while let Some(&t) = self.buckets[self.hint].last() {
                self.buckets[self.hint].pop();
                if self.cur[t as usize] == self.hint as u16 {
                    self.cur[t as usize] = NONE;
                    return Some(t as usize);
                }
                // stale entry: the node moved buckets since
            }
            self.hint += 1;
        }
        None
    }
}

/// Outcome of one [`PushState::solve`] call.
#[derive(Debug, Clone, Copy)]
pub struct SolveStats {
    /// Pushes performed by this solve.
    pub pushes: u64,
    /// Flushes of the pending scalars (O(n) uniform, O(nnz(v))
    /// personalized).
    pub flushes: u64,
    /// Distinct nodes whose state changed since `begin_epoch`
    /// (delta injection included).
    pub touched: usize,
    /// Residual mass `‖r‖₁ + |rd| + |rv|` at exit.
    pub residual: f64,
    /// Whether the tolerance was reached (vs. the push budget).
    pub converged: bool,
}

/// Persistent push-solver state: survives across epochs so each solve
/// warm-starts from the previous fixed point.
///
/// The right-hand side defaults to the uniform teleport `e/n`; a state
/// built with [`new_personalized`](Self::new_personalized) solves the
/// personalized system `x = αSx + (1−α)v` instead. The sparse `v` is
/// materialized into `r` at construction, and a second pending scalar
/// `rv` (standing for `rv·v_t/Σv` mass on each support node) absorbs
/// dangling redistribution when the vector routes it through `v` —
/// flushed in `O(nnz(v))`, the personalized analogue of the `O(n)`
/// uniform flush.
///
/// Two contracts every consumer leans on:
///
/// * **Mass conservation** — with `R = Σr + rd + rv` the signed
///   residual, `Σp + R/(1−α) = Σv` holds after every push, flush, and
///   [`apply_batch`](Self::apply_batch) (each push at mass `m` settles
///   `m` and re-emits exactly `α·m`); `Σv = 1` for the uniform default.
///   [`residual_l1`](Self::residual_l1)
///   upper-bounds the rank error by `residual/(1−α)` in L1, which is
///   what makes any intermediate state servable.
/// * **Head-generation invalidation** — an attached
///   [`TopKTracker`](super::TopKTracker) follows this state through
///   the `add_r` hit stream alone. Any *wholesale* state move that
///   bypasses `add_r` (the sharded gather's `adopt_parts`, growth on
///   node arrivals) bumps an internal generation stamp, which forces
///   the tracker's next check to rebuild its candidate pools instead
///   of trusting stale hits. If you add a new way to move state, bump
///   the stamp or the serving path will certify against fiction.
#[derive(Debug, Clone)]
pub struct PushState {
    alpha: f64,
    /// Rank estimate (converges to the PageRank vector, ‖·‖₁ = 1).
    pub(crate) p: Vec<f64>,
    /// Materialized residual.
    pub(crate) r: Vec<f64>,
    /// Pending uniform residual: stands for `rd/n` on every node.
    pub(crate) rd: f64,
    /// Pending personalization residual: stands for `rv·v_t/Σv` on
    /// each support node of `pers` (always 0 on the uniform path).
    pub(crate) rv: f64,
    /// Personalization vector (`None` = the uniform teleport `e/n`).
    pub(crate) pers: Option<Arc<Personalization>>,
    /// Maintained Σ|r| (re-verified exactly before declaring
    /// convergence, so incremental drift cannot cause early exit).
    pub(crate) r_l1: f64,
    /// Maintained signed Σr — together with `r_l1` it splits the
    /// residual into its positive/negative halves in O(1), which is
    /// what the top-k certifier's one-sided error intervals read.
    pub(crate) r_sum: f64,
    queue: BucketQueue,
    /// Head-tracking hook: when `p[t] + r[t]` rises to (or above) this
    /// floor inside `add_r`, `t` is appended to `head_hits`. `+INF`
    /// disables collection (the default — no tracker attached).
    pub(crate) head_floor: f64,
    /// Nodes that crossed `head_floor` since the tracker last drained
    /// the list (may hold duplicates; drained with sort+dedup).
    pub(crate) head_hits: Vec<u32>,
    /// Bumped on every wholesale state swap (`adopt_parts`) that
    /// bypasses `add_r` — tells an attached [`TopKTracker`] its
    /// incremental candidate pools are stale and a full rescan is due.
    ///
    /// [`TopKTracker`]: super::TopKTracker
    pub(crate) head_gen: u64,
    /// Touched-node stamping (per epoch).
    stamp: Vec<u64>,
    cur_stamp: u64,
    touched: usize,
    /// Lifetime push counter.
    total_pushes: u64,
}

impl PushState {
    /// Cold state for an `n`-node graph: `p = 0` and the entire
    /// right-hand side `(1-α)·e/n` pending in the uniform scalar.
    pub fn new(n: usize, alpha: f64) -> Self {
        assert!(n > 0, "empty graph");
        assert!((0.0..1.0).contains(&alpha), "alpha must be in [0,1)");
        PushState {
            alpha,
            p: vec![0.0; n],
            r: vec![0.0; n],
            rd: 1.0 - alpha,
            rv: 0.0,
            pers: None,
            r_l1: 0.0,
            r_sum: 0.0,
            queue: BucketQueue::new(n),
            head_floor: f64::INFINITY,
            head_hits: Vec::new(),
            head_gen: super::next_head_gen(),
            stamp: vec![0; n],
            cur_stamp: 0,
            touched: 0,
            total_pushes: 0,
        }
    }

    /// Cold personalized state: `p = 0`, the sparse right-hand side
    /// `(1-α)·v` materialized directly into `r` (only `nnz(v)` rows —
    /// a PPR query's cold start costs `O(nnz(v))`, not `O(n)`).
    pub fn new_personalized(n: usize, alpha: f64, pers: Arc<Personalization>) -> Self {
        let mut st = Self::new(n, alpha);
        assert!(
            (pers.max_node() as usize) < n,
            "personalization entry {} out of bounds for n={n}",
            pers.max_node()
        );
        st.rd = 0.0;
        for &(t, w) in pers.entries() {
            st.add_r(t as usize, (1.0 - alpha) * w);
        }
        st.pers = Some(pers);
        st
    }

    pub fn n(&self) -> usize {
        self.p.len()
    }

    pub fn alpha(&self) -> f64 {
        self.alpha
    }

    /// Current rank estimate.
    pub fn ranks(&self) -> &[f64] {
        &self.p
    }

    /// Residual mass `‖r‖₁ + |rd| + |rv|` (upper-bounds the rank error
    /// by `residual/(1-α)` in L1).
    pub fn residual_l1(&self) -> f64 {
        self.r_l1 + self.rd.abs() + self.rv.abs()
    }

    /// The personalization vector this state solves against (`None` =
    /// uniform teleport).
    pub fn personalization(&self) -> Option<&Arc<Personalization>> {
        self.pers.as_ref()
    }

    /// `Σv` — what `Σp + R/(1−α)` converges to (1 on the uniform path).
    pub fn target_mass(&self) -> f64 {
        self.pers.as_ref().map_or(1.0, |p| p.total())
    }

    pub fn total_pushes(&self) -> u64 {
        self.total_pushes
    }

    /// Distinct nodes whose state changed since [`begin_epoch`]
    /// (mirrors [`ShardedPush::touched`]).
    ///
    /// [`begin_epoch`]: Self::begin_epoch
    /// [`ShardedPush::touched`]: super::ShardedPush::touched
    pub fn touched(&self) -> usize {
        self.touched
    }

    /// Materialized residual vector (scatter hook for the sharded
    /// engine; the pending-uniform scalar rides separately).
    pub(crate) fn residual(&self) -> &[f64] {
        &self.r
    }

    /// Pending uniform residual scalar.
    pub(crate) fn pending_uniform(&self) -> f64 {
        self.rd
    }

    /// Pending personalization residual scalar.
    pub(crate) fn pending_v(&self) -> f64 {
        self.rv
    }

    /// Credit pushes performed outside this state (a sharded parallel
    /// drain) to the lifetime counter.
    pub(crate) fn add_pushes(&mut self, k: u64) {
        self.total_pushes += k;
    }

    /// Replace the solver state wholesale — the gather hook after a
    /// sharded parallel drain. Keeps the epoch stamps and lifetime
    /// counters; rebuilds the queue and the residual tally from `r`.
    /// The node count must be unchanged (deltas are applied on the
    /// global state *before* scattering).
    pub(crate) fn adopt_parts(&mut self, p: Vec<f64>, r: Vec<f64>, rd: f64, rv: f64) {
        assert_eq!(p.len(), self.p.len(), "adopt_parts must not resize");
        assert_eq!(r.len(), self.p.len(), "adopt_parts must not resize");
        // stamp every node the sharded phase changed, so the epoch's
        // touched-node accounting survives the scatter/gather round-trip
        for t in 0..p.len() {
            if p[t] != self.p[t] || r[t] != self.r[t] {
                self.touch(t);
            }
        }
        self.p = p;
        self.r = r;
        self.rd = rd;
        self.rv = rv;
        let (queue, l1) = BucketQueue::seeded_from(&self.r);
        self.queue = queue;
        self.r_l1 = l1;
        self.r_sum = self.r.iter().sum();
        // wholesale swap bypassed add_r: any attached top-k tracker
        // must rebuild its candidate pools from scratch
        self.head_gen = super::next_head_gen();
    }

    /// Start a new epoch's touched-node accounting.
    pub fn begin_epoch(&mut self) {
        self.cur_stamp += 1;
        self.touched = 0;
    }

    #[inline]
    fn touch(&mut self, t: usize) {
        if self.stamp[t] != self.cur_stamp {
            self.stamp[t] = self.cur_stamp;
            self.touched += 1;
        }
    }

    #[inline]
    fn add_r(&mut self, t: usize, w: f64) {
        if w == 0.0 {
            return;
        }
        let old = self.r[t];
        let new = old + w;
        self.r_l1 += new.abs() - old.abs();
        self.r_sum += w;
        self.r[t] = new;
        if self.p[t] + new >= self.head_floor {
            self.head_hits.push(t as u32);
        }
        self.queue.update(t, new.abs());
        self.touch(t);
    }

    /// Distribute the pending uniform scalar into `r` (O(n)).
    fn flush(&mut self) {
        let n = self.n();
        let add = self.rd / n as f64;
        self.rd = 0.0;
        if add == 0.0 {
            return;
        }
        for t in 0..n {
            self.add_r(t, add);
        }
    }

    /// Distribute the pending personalization scalar into `r` over the
    /// support of `v` — `O(nnz(v))`, the cheap flush that keeps a PPR
    /// query's work proportional to its locality.
    fn flush_v(&mut self) {
        let m = self.rv;
        self.rv = 0.0;
        if m == 0.0 {
            return;
        }
        let pers = self.pers.clone().expect("rv is only fed on personalized states");
        let scale = m / pers.total();
        for &(t, w) in pers.entries() {
            self.add_r(t as usize, scale * w);
        }
    }

    /// Exact recomputation of Σ|r| and Σr (guards the incremental
    /// tallies; the signed sum re-tallies in the same pass so the
    /// certifier's residual split stays honest too).
    pub(crate) fn recompute_r_l1(&mut self) {
        let (mut l1, mut s) = (0.0f64, 0.0f64);
        for &v in &self.r {
            l1 += v.abs();
            s += v;
        }
        self.r_l1 = l1;
        self.r_sum = s;
    }

    /// One push at `u`: settle `r[u]` into the estimate and re-emit
    /// `α·r[u]` through the out-links (or into `rd` when dangling).
    fn push_node(&mut self, g: &DeltaGraph, u: usize) {
        let m = self.r[u];
        if m == 0.0 {
            return;
        }
        self.r_l1 -= m.abs();
        self.r_sum -= m;
        self.r[u] = 0.0;
        // p + r is invariant under the settle, so no head-hit check:
        // the node's certified center does not move here
        self.p[u] += m;
        self.touch(u);
        let d = g.outdeg(u);
        if d == 0 {
            // dangling mass follows the personalization vector when it
            // asks for it, the uniform e/n otherwise
            if self.pers.as_ref().is_some_and(|p| p.dangling_to_v()) {
                self.rv += self.alpha * m;
            } else {
                self.rd += self.alpha * m;
            }
        } else {
            let w = self.alpha * m / d as f64;
            for &t in g.out(u) {
                self.add_r(t as usize, w);
            }
        }
        self.total_pushes += 1;
    }

    /// Inject the residual a graph delta creates, so the next
    /// [`solve`](Self::solve) warm-starts instead of recomputing.
    ///
    /// `g` must be the graph *after* `delta` was applied; `self` must be
    /// sized to `delta.old_n`. Cost: O(n) when the node count changed
    /// (teleport renormalization), plus O(|changed out-lists|).
    pub fn apply_batch(&mut self, g: &DeltaGraph, delta: &AppliedDelta) {
        assert_eq!(self.n(), delta.old_n, "state vs delta old_n");
        assert_eq!(g.n(), delta.new_n, "graph vs delta new_n");
        let (n0, n1) = (delta.old_n, delta.new_n);
        let alpha = self.alpha;

        let dangling_to_v = self.pers.as_ref().is_some_and(|p| p.dangling_to_v());
        if n1 != n0 {
            // The pending uniform stands for rd/n0 per old node; make it
            // explicit before the node count changes its meaning. (The
            // pending-v scalar's shape is the fixed support of v — it
            // does not depend on n, so it needs no flush here.)
            self.flush();
            self.p.resize(n1, 0.0);
            self.r.resize(n1, 0.0);
            self.stamp.resize(n1, 0);
            self.queue.grow(n1);

            // Whatever part of the right-hand side is uniform e/n gets
            // rescaled by the growth: the teleport column only on the
            // uniform path (a personalized v is n-independent), and the
            // dangling-redistribution columns only when dangling mass
            // goes uniform. Both scale with the same uniform shape. The
            // OLD dangling set is what p was converged against: changed
            // sources report their old lists, everyone else kept
            // today's.
            let mut uniform_mass = if self.pers.is_none() { 1.0 - alpha } else { 0.0 };
            if !dangling_to_v {
                let mut old_dangling_mass = 0.0f64;
                // changed_sources is sorted by source id (BTreeMap order)
                let mut changed_iter = delta.changed_sources.iter().peekable();
                for u in 0..n0 {
                    let old_deg = if changed_iter
                        .peek()
                        .map_or(false, |(s, _)| *s as usize == u)
                    {
                        changed_iter.next().unwrap().1.len()
                    } else {
                        g.outdeg(u)
                    };
                    if old_deg == 0 {
                        old_dangling_mass += self.p[u];
                    }
                }
                uniform_mass += alpha * old_dangling_mass;
            }
            if uniform_mass != 0.0 {
                let shift_old = uniform_mass * (1.0 / n1 as f64 - 1.0 / n0 as f64);
                let add_new = uniform_mass / n1 as f64;
                for t in 0..n0 {
                    self.add_r(t, shift_old);
                }
                for t in n0..n1 {
                    self.add_r(t, add_new);
                }
            }
        }

        // Invariant now holds for the mid-graph (old edges, new size).
        // Swap each changed source's old column of αS for its new one:
        // r += α(S' - S) p, column by column. Dangling columns go
        // through whichever pending scalar the redistribution uses.
        for (s, old_out) in &delta.changed_sources {
            let u = *s as usize;
            let q = alpha * self.p[u];
            if q == 0.0 {
                continue;
            }
            if old_out.is_empty() {
                if dangling_to_v {
                    self.rv -= q;
                } else {
                    self.rd -= q;
                }
            } else {
                let w = q / old_out.len() as f64;
                for &t in old_out {
                    self.add_r(t as usize, -w);
                }
            }
            let new_out = g.out(u);
            if new_out.is_empty() {
                if dangling_to_v {
                    self.rv += q;
                } else {
                    self.rd += q;
                }
            } else {
                let w = q / new_out.len() as f64;
                for &t in new_out {
                    self.add_r(t as usize, w);
                }
            }
        }
    }

    /// Run Gauss–Southwell pushes until `‖r‖₁ + |rd| + |rv| < tol` or
    /// the push budget is exhausted.
    pub fn solve(&mut self, g: &DeltaGraph, tol: f64, max_pushes: u64) -> SolveStats {
        assert_eq!(self.n(), g.n(), "state sized to a different graph");
        assert!(tol > 0.0, "tol must be positive");
        let mut pushes = 0u64;
        let mut flushes = 0u64;
        let converged = loop {
            if self.residual_l1() < tol {
                // confirm against an exact tally before declaring victory
                self.recompute_r_l1();
                if self.residual_l1() < tol {
                    break true;
                }
            }
            if pushes >= max_pushes {
                break false;
            }
            // When a pending scalar dominates what is materialized,
            // spread it — otherwise we would grind through ever-smaller
            // entries while the real mass hides in the scalar. The
            // personalized flush is O(nnz(v)), the uniform one O(n).
            if self.rv.abs() >= self.r_l1.max(0.5 * tol) {
                self.flush_v();
                flushes += 1;
                continue;
            }
            if self.rd.abs() >= self.r_l1.max(0.5 * tol) {
                self.flush();
                flushes += 1;
                continue;
            }
            match self.queue.pop() {
                Some(u) => {
                    self.push_node(g, u);
                    pushes += 1;
                }
                None => {
                    // queue drained: all r[u] == 0, only the pending
                    // scalars (or drift) left
                    if self.rv != 0.0 {
                        self.flush_v();
                        flushes += 1;
                    } else if self.rd != 0.0 {
                        self.flush();
                        flushes += 1;
                    } else {
                        self.recompute_r_l1();
                        break self.residual_l1() < tol;
                    }
                }
            }
        };
        SolveStats {
            pushes,
            flushes,
            touched: self.touched,
            residual: self.residual_l1(),
            converged,
        }
    }

    /// Pop the (approximately) hottest queued node — the batched serve
    /// engine's scheduling hook. The popped node's residual stays in
    /// `r` until [`push_at`](Self::push_at) settles it.
    pub(crate) fn pop_hottest(&mut self) -> Option<usize> {
        self.queue.pop()
    }

    /// Materialized residual at one node.
    #[inline]
    pub(crate) fn residual_at(&self, u: usize) -> f64 {
        self.r[u]
    }

    /// Settle node `u` for this state, reusing a graph row the batch
    /// engine already has hot. Safe to call whether or not `u` is
    /// queued (a stale queue entry costs one no-op pop later).
    pub(crate) fn push_at(&mut self, g: &DeltaGraph, u: usize) {
        self.push_node(g, u);
    }

    /// Flush any pending scalar mass into `r` — the serve tier calls
    /// this before certification so every row's center is exact.
    pub(crate) fn settle_pending(&mut self) {
        if self.rv != 0.0 {
            self.flush_v();
        }
    }
}

/// Reference f64 power iteration over the forward adjacency — the
/// "from-scratch" gold standard the epoch driver compares against.
/// Returns the iterate and the iteration count; stops when the L1
/// step difference drops below `tol`.
pub fn power_method_f64(
    g: &DeltaGraph,
    alpha: f64,
    tol: f64,
    max_iters: usize,
) -> (Vec<f64>, usize) {
    let n = g.n();
    assert!(n > 0, "empty graph");
    let mut x = vec![1.0 / n as f64; n];
    let mut y = vec![0.0f64; n];
    let mut iters = 0;
    while iters < max_iters {
        y.iter_mut().for_each(|v| *v = 0.0);
        let mut dang = 0.0f64;
        for u in 0..n {
            let d = g.outdeg(u);
            if d == 0 {
                dang += x[u];
            } else {
                let w = x[u] / d as f64;
                for &t in g.out(u) {
                    y[t as usize] += w;
                }
            }
        }
        let base = alpha * dang / n as f64 + (1.0 - alpha) / n as f64;
        let mut resid = 0.0f64;
        for (yi, xi) in y.iter_mut().zip(&x) {
            *yi = alpha * *yi + base;
            resid += (*yi - *xi).abs();
        }
        std::mem::swap(&mut x, &mut y);
        iters += 1;
        if resid < tol {
            break;
        }
    }
    (x, iters)
}

/// Personalized reference iteration `x ← αP^T x + α·dang·w + (1−α)v`
/// with `w` the dangling-redistribution vector (`v/Σv` or `e/n` per
/// the vector's policy) — [`power_method_f64`]'s PPR twin, the gold
/// standard the serve tier and the equivalence proptests compare
/// against. Converges to the fixed point with `Σx = Σv`.
pub fn power_method_pers(
    g: &DeltaGraph,
    alpha: f64,
    pers: &Personalization,
    tol: f64,
    max_iters: usize,
) -> (Vec<f64>, usize) {
    let n = g.n();
    assert!(n > 0, "empty graph");
    assert!((pers.max_node() as usize) < n, "personalization out of bounds");
    let total = pers.total();
    let mut x = vec![0.0f64; n];
    for &(t, w) in pers.entries() {
        x[t as usize] = w;
    }
    let mut y = vec![0.0f64; n];
    let mut iters = 0;
    while iters < max_iters {
        y.iter_mut().for_each(|v| *v = 0.0);
        let mut dang = 0.0f64;
        for u in 0..n {
            let d = g.outdeg(u);
            if d == 0 {
                dang += x[u];
            } else {
                let w = x[u] / d as f64;
                for &t in g.out(u) {
                    y[t as usize] += w;
                }
            }
        }
        let base = if pers.dangling_to_v() { 0.0 } else { alpha * dang / n as f64 };
        for yi in y.iter_mut() {
            *yi = alpha * *yi + base;
        }
        for &(t, w) in pers.entries() {
            let mut add = (1.0 - alpha) * w;
            if pers.dangling_to_v() {
                add += alpha * dang * w / total;
            }
            y[t as usize] += add;
        }
        let mut resid = 0.0f64;
        for (yi, xi) in y.iter().zip(&x) {
            resid += (yi - xi).abs();
        }
        std::mem::swap(&mut x, &mut y);
        iters += 1;
        if resid < tol {
            break;
        }
    }
    (x, iters)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generators, EdgeList};
    use crate::stream::UpdateBatch;
    use crate::util::Rng;

    fn l1(a: &[f64], b: &[f64]) -> f64 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum()
    }

    fn web(n: usize, seed: u64) -> DeltaGraph {
        let el = generators::power_law_web(&generators::WebParams::scaled(n), seed);
        DeltaGraph::from_edgelist(&el)
    }

    #[test]
    fn cold_solve_matches_f64_power_method() {
        let g = web(2_000, 11);
        let mut s = PushState::new(g.n(), 0.85);
        s.begin_epoch();
        let stats = s.solve(&g, 1e-11, u64::MAX);
        assert!(stats.converged, "residual {}", stats.residual);
        let (xref, it) = power_method_f64(&g, 0.85, 1e-12, 10_000);
        assert!(it < 10_000);
        let d = l1(s.ranks(), &xref);
        assert!(d < 1e-9, "push vs power drift {d}");
        // PageRank mass
        let mass: f64 = s.ranks().iter().sum();
        assert!((mass - 1.0).abs() < 1e-9, "mass {mass}");
    }

    #[test]
    fn push_count_scales_with_mass_not_tolerance_cliff() {
        let g = web(2_000, 12);
        let mut a = PushState::new(g.n(), 0.85);
        a.begin_epoch();
        let loose = a.solve(&g, 1e-6, u64::MAX);
        let mut b = PushState::new(g.n(), 0.85);
        b.begin_epoch();
        let tight = b.solve(&g, 1e-10, u64::MAX);
        assert!(loose.pushes < tight.pushes);
        // refining an already-converged state is nearly free
        let refine = a.solve(&g, 1e-10, u64::MAX);
        assert!(refine.pushes < tight.pushes / 2, "{} vs {}", refine.pushes, tight.pushes);
    }

    #[test]
    fn chain_and_star_and_empty_graphs() {
        for el in [
            generators::chain(50),
            generators::star(50),
            EdgeList::new(7), // all dangling
        ] {
            let g = DeltaGraph::from_edgelist(&el);
            let mut s = PushState::new(g.n(), 0.85);
            s.begin_epoch();
            let st = s.solve(&g, 1e-12, u64::MAX);
            assert!(st.converged);
            let (xref, _) = power_method_f64(&g, 0.85, 1e-13, 100_000);
            assert!(l1(s.ranks(), &xref) < 1e-10);
        }
    }

    #[test]
    fn warm_start_matches_cold_after_batch() {
        let mut g = web(1_500, 13);
        let mut inc = PushState::new(g.n(), 0.85);
        inc.begin_epoch();
        inc.solve(&g, 1e-11, u64::MAX);

        let mut rng = Rng::new(99);
        for round in 0..4 {
            // random churn incl. arrivals
            let n = g.n();
            let mut batch = UpdateBatch { new_nodes: 3, ..Default::default() };
            for _ in 0..40 {
                batch
                    .insert
                    .push((rng.range(0, n + 3) as u32, rng.range(0, n) as u32));
            }
            let mut edges = Vec::new();
            g.for_each_edge(|s, d| edges.push((s, d)));
            for _ in 0..25 {
                batch.remove.push(edges[rng.range(0, edges.len())]);
            }
            let delta = g.apply(&batch).unwrap();
            inc.begin_epoch();
            inc.apply_batch(&g, &delta);
            let stats = inc.solve(&g, 1e-11, u64::MAX);
            assert!(stats.converged, "round {round}");

            let mut cold = PushState::new(g.n(), 0.85);
            cold.begin_epoch();
            let cold_stats = cold.solve(&g, 1e-11, u64::MAX);
            let d = l1(inc.ranks(), cold.ranks());
            assert!(d < 1e-8, "round {round}: inc vs cold drift {d}");
            assert!(
                stats.pushes < cold_stats.pushes,
                "round {round}: warm {} >= cold {}",
                stats.pushes,
                cold_stats.pushes
            );
        }
    }

    #[test]
    fn dangling_flip_handled_exactly() {
        // node 1 loses its only out-link (becomes dangling), node 3
        // gains one (stops dangling) — both swap a sparse column for a
        // uniform one; the warm start must stay exact.
        let el = EdgeList::from_edges(4, vec![(0, 1), (0, 2), (1, 2), (2, 0)]).unwrap();
        let mut g = DeltaGraph::from_edgelist(&el);
        let mut inc = PushState::new(4, 0.85);
        inc.begin_epoch();
        inc.solve(&g, 1e-13, u64::MAX);
        let delta = g
            .apply(&UpdateBatch {
                new_nodes: 0,
                insert: vec![(3, 0)],
                remove: vec![(1, 2)],
            })
            .unwrap();
        inc.begin_epoch();
        inc.apply_batch(&g, &delta);
        inc.solve(&g, 1e-13, u64::MAX);
        let (xref, _) = power_method_f64(&g, 0.85, 1e-14, 100_000);
        assert!(l1(inc.ranks(), &xref) < 1e-11);
    }

    #[test]
    fn single_edge_delta_costs_a_fraction_of_a_cold_solve() {
        let mut g = web(4_000, 14);
        let mut inc = PushState::new(g.n(), 0.85);
        inc.begin_epoch();
        inc.solve(&g, 1e-10, u64::MAX);
        // a single inserted edge between two existing pages: the
        // injected residual mass is O(alpha * p[17]), so the warm solve
        // must be a small fraction of recomputing from scratch
        let delta = g
            .apply(&UpdateBatch {
                new_nodes: 0,
                insert: vec![(17, 4_000 - 1)],
                remove: vec![],
            })
            .unwrap();
        inc.begin_epoch();
        inc.apply_batch(&g, &delta);
        let stats = inc.solve(&g, 1e-10, u64::MAX);
        assert!(stats.converged);
        let mut cold = PushState::new(g.n(), 0.85);
        cold.begin_epoch();
        let cold_stats = cold.solve(&g, 1e-10, u64::MAX);
        assert!(
            stats.pushes < cold_stats.pushes / 10,
            "one-edge warm solve took {} pushes vs cold {}",
            stats.pushes,
            cold_stats.pushes
        );
    }

    #[test]
    fn budget_cap_reports_unconverged() {
        let g = web(2_000, 15);
        let mut s = PushState::new(g.n(), 0.85);
        s.begin_epoch();
        let st = s.solve(&g, 1e-12, 50);
        assert!(!st.converged);
        assert!(st.pushes <= 50);
        assert!(st.residual > 1e-12);
        // and the state remains usable: finishing the solve still lands
        // on the right vector
        let st2 = s.solve(&g, 1e-11, u64::MAX);
        assert!(st2.converged);
        let (xref, _) = power_method_f64(&g, 0.85, 1e-12, 10_000);
        assert!(l1(s.ranks(), &xref) < 1e-9);
    }

    #[test]
    fn bucket_queue_orders_roughly_by_magnitude() {
        let mut q = BucketQueue::new(8);
        q.update(0, 0.5);
        q.update(1, 1e-4);
        q.update(2, 0.9);
        q.update(3, 1e-9);
        let first = q.pop().unwrap();
        assert!(first == 0 || first == 2, "hot bucket first, got {first}");
        let second = q.pop().unwrap();
        assert!(second == 0 || second == 2);
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(3));
        assert_eq!(q.pop(), None);
        // re-queue after pop works
        q.update(3, 0.25);
        assert_eq!(q.pop(), Some(3));
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "non-finite residual magnitude")]
    fn bucket_queue_rejects_nan_magnitude() {
        let mut q = BucketQueue::new(4);
        q.update(1, f64::NAN);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "non-finite residual magnitude")]
    fn bucket_queue_rejects_infinite_magnitude() {
        let mut q = BucketQueue::new(4);
        q.update(2, f64::INFINITY);
    }

    /// The release-mode contract behind the debug assert: a non-finite
    /// magnitude must never enqueue (bucket 0 would loop forever).
    #[test]
    fn bucket_of_refuses_non_finite() {
        assert_eq!(BucketQueue::bucket_of(f64::NAN), None);
        assert_eq!(BucketQueue::bucket_of(f64::INFINITY), None);
        assert_eq!(BucketQueue::bucket_of(f64::NEG_INFINITY), None);
        assert_eq!(BucketQueue::bucket_of(0.0), None);
        assert_eq!(BucketQueue::bucket_of(0.5), Some(0));
    }

    fn pers_mass(s: &PushState) -> f64 {
        let r: f64 = s.r.iter().sum();
        let p: f64 = s.p.iter().sum();
        p + (r + s.rd + s.rv) / (1.0 - s.alpha())
    }

    #[test]
    fn single_source_ppr_matches_personalized_power_method() {
        let g = web(2_000, 21);
        for dangling_to_v in [true, false] {
            let pers = Personalization::from_entries(vec![(17, 1.0)], dangling_to_v).unwrap();
            let pers = Arc::new(pers);
            let mut s = PushState::new_personalized(g.n(), 0.85, Arc::clone(&pers));
            s.begin_epoch();
            let st = s.solve(&g, 1e-11, u64::MAX);
            assert!(st.converged, "residual {}", st.residual);
            let (xref, it) = power_method_pers(&g, 0.85, &pers, 1e-12, 10_000);
            assert!(it < 10_000);
            let d = l1(s.ranks(), &xref);
            assert!(d < 1e-9, "dangling_to_v={dangling_to_v}: push vs power drift {d}");
            assert!((pers_mass(&s) - 1.0).abs() < 1e-9, "mass {}", pers_mass(&s));
        }
    }

    #[test]
    fn weighted_multi_source_ppr_conserves_sigma_v() {
        let g = web(1_200, 22);
        let pers = Arc::new(
            Personalization::from_entries(vec![(3, 0.5), (100, 1.25), (777, 0.25)], true)
                .unwrap(),
        );
        let mut s = PushState::new_personalized(g.n(), 0.85, Arc::clone(&pers));
        s.begin_epoch();
        assert!((pers_mass(&s) - 2.0).abs() < 1e-12, "cold mass {}", pers_mass(&s));
        let st = s.solve(&g, 1e-11, u64::MAX);
        assert!(st.converged);
        assert!((pers_mass(&s) - 2.0).abs() < 1e-9, "mass {}", pers_mass(&s));
        let rank_mass: f64 = s.ranks().iter().sum();
        assert!((rank_mass - 2.0).abs() < 1e-9, "Σp {rank_mass}");
        let (xref, _) = power_method_pers(&g, 0.85, &pers, 1e-12, 10_000);
        assert!(l1(s.ranks(), &xref) < 1e-9);
    }

    #[test]
    fn ppr_warm_start_tracks_churn_in_both_dangling_modes() {
        for dangling_to_v in [true, false] {
            let mut g = web(1_200, 23);
            let pers = Arc::new(
                Personalization::from_entries(vec![(5, 0.7), (42, 0.3)], dangling_to_v).unwrap(),
            );
            let mut inc = PushState::new_personalized(g.n(), 0.85, Arc::clone(&pers));
            inc.begin_epoch();
            inc.solve(&g, 1e-11, u64::MAX);
            let mut rng = Rng::new(91);
            for round in 0..3 {
                let n = g.n();
                let mut batch = UpdateBatch { new_nodes: 2, ..Default::default() };
                for _ in 0..30 {
                    batch
                        .insert
                        .push((rng.range(0, n + 2) as u32, rng.range(0, n) as u32));
                }
                let mut edges = Vec::new();
                g.for_each_edge(|s, d| edges.push((s, d)));
                for _ in 0..20 {
                    batch.remove.push(edges[rng.range(0, edges.len())]);
                }
                let delta = g.apply(&batch).unwrap();
                inc.begin_epoch();
                inc.apply_batch(&g, &delta);
                let stats = inc.solve(&g, 1e-11, u64::MAX);
                assert!(stats.converged, "round {round}");
                let (xref, _) = power_method_pers(&g, 0.85, &pers, 1e-13, 100_000);
                let d = l1(inc.ranks(), &xref);
                assert!(
                    d < 1e-8,
                    "dangling_to_v={dangling_to_v} round {round}: warm vs power drift {d}"
                );
                assert!(
                    (pers_mass(&inc) - 1.0).abs() < 1e-9,
                    "round {round}: mass {}",
                    pers_mass(&inc)
                );
            }
        }
    }

    #[test]
    fn ppr_cold_start_is_local_for_tight_sources() {
        // a PPR query must not pay for the whole graph: solving from
        // one source at a loose tol touches far fewer rows than n on a
        // graph where most mass never leaves the source's neighborhood
        let g = web(20_000, 24);
        let pers = Arc::new(Personalization::single_source(123));
        let mut s = PushState::new_personalized(g.n(), 0.85, pers);
        s.begin_epoch();
        let st = s.solve(&g, 1e-4, u64::MAX);
        assert!(st.converged);
        assert!(
            st.touched < g.n() / 4,
            "single-source push touched {} of {} rows",
            st.touched,
            g.n()
        );
    }

    #[test]
    fn deterministic_across_runs() {
        let g = web(1_000, 16);
        let run = || {
            let mut s = PushState::new(g.n(), 0.85);
            s.begin_epoch();
            let st = s.solve(&g, 1e-10, u64::MAX);
            (st.pushes, s.ranks().to_vec())
        };
        let (pa, xa) = run();
        let (pb, xb) = run();
        assert_eq!(pa, pb);
        assert_eq!(xa, xb);
    }
}
