//! `PushBlockOp` — the push diffusion as a [`BlockOperator`], so the
//! existing [`crate::asynciter::SimEngine`] runs it asynchronously
//! across UEs exactly like the paper's power-kernel operators.
//!
//! Each UE owns rows `[lo, hi)` and repeatedly solves its *block
//! subsystem* `x_B = α S_BB x_B + c(x_ext)` with the Gauss–Southwell
//! push loop, where the boundary vector `c` collects the (stale)
//! external fragments: `c_i = α Σ_{u∉B} S_iu x_u + α·dang_ext/n +
//! (1-α) v_i`. Between engine calls the block's `(p, r)` pair persists,
//! so an update whose boundary barely moved costs a handful of pushes —
//! the free-steered block-relaxation version of eq. (5), with the inner
//! work scheduled by residual instead of sweeping the whole block.

use std::sync::Arc;

use super::push::BucketQueue;
use crate::asynciter::BlockOperator;
use crate::pagerank::PagerankProblem;

/// Tunables for the per-update inner solve.
#[derive(Debug, Clone)]
pub struct PushBlockOptions {
    /// Absolute floor for the inner residual target.
    pub inner_floor: f64,
    /// Relative factor: solve to `max(inner_floor, rel * r0)` where
    /// `r0` is the block residual right after boundary injection.
    pub inner_rel: f64,
    /// Per-update push budget as a multiple of block rows.
    pub budget_per_row: usize,
}

impl Default for PushBlockOptions {
    fn default() -> Self {
        PushBlockOptions { inner_floor: 1e-9, inner_rel: 0.02, budget_per_row: 64 }
    }
}

/// Push-based block operator over a [`PagerankProblem`] snapshot.
pub struct PushBlockOp {
    problem: Arc<PagerankProblem>,
    lo: usize,
    hi: usize,
    /// In-nonzeros of the block (drives simulated compute time, same
    /// convention as the other operators).
    nnz: usize,
    alpha: f64,
    /// Forward adjacency restricted to the block: for local source `k`,
    /// the local targets it links to (plus its GLOBAL out-degree for
    /// the weight — out-links leaving the block still dilute the push).
    out_block: Vec<Vec<u32>>,
    global_outdeg: Vec<u32>,
    /// Global ids of dangling pages outside the block (their stale
    /// scores feed the boundary's uniform term).
    ext_dangling: Vec<u32>,
    // --- persistent inner solver state (all f64, block-local) ---
    p: Vec<f64>,
    r: Vec<f64>,
    rd: f64,
    r_l1: f64,
    /// Hot-first scheduling over block-local indices (shared
    /// [`BucketQueue`] implementation).
    queue: BucketQueue,
    /// Boundary vector of the previous update.
    c: Vec<f64>,
    first: bool,
    opts: PushBlockOptions,
    pushes: u64,
}

impl PushBlockOp {
    pub fn new(problem: Arc<PagerankProblem>, lo: usize, hi: usize) -> Self {
        Self::with_options(problem, lo, hi, PushBlockOptions::default())
    }

    pub fn with_options(
        problem: Arc<PagerankProblem>,
        lo: usize,
        hi: usize,
        opts: PushBlockOptions,
    ) -> Self {
        assert!(lo < hi && hi <= problem.n());
        let bs = hi - lo;
        let csr = &problem.csr;
        let nnz = (lo..hi).map(|i| csr.row_len(i)).sum();
        // invert the block's in-rows into block-local forward adjacency
        let mut out_block: Vec<Vec<u32>> = vec![Vec::new(); bs];
        for i in lo..hi {
            let (cols, _) = csr.row(i);
            for &u in cols {
                let u = u as usize;
                if (lo..hi).contains(&u) {
                    out_block[u - lo].push((i - lo) as u32);
                }
            }
        }
        let global_outdeg: Vec<u32> = csr.outdeg()[lo..hi].to_vec();
        let ext_dangling: Vec<u32> = csr
            .dangling()
            .iter()
            .copied()
            .filter(|&u| !(lo..hi).contains(&(u as usize)))
            .collect();
        let alpha = problem.alpha as f64;
        PushBlockOp {
            problem,
            lo,
            hi,
            nnz,
            alpha,
            out_block,
            global_outdeg,
            ext_dangling,
            p: vec![0.0; bs],
            r: vec![0.0; bs],
            rd: 0.0,
            r_l1: 0.0,
            queue: BucketQueue::new(bs),
            c: vec![0.0; bs],
            first: true,
            opts,
            pushes: 0,
        }
    }

    /// Pushes performed over the operator's lifetime.
    pub fn pushes(&self) -> u64 {
        self.pushes
    }

    #[inline]
    fn add_r(&mut self, t: usize, w: f64) {
        if w == 0.0 {
            return;
        }
        let old = self.r[t];
        let new = old + w;
        self.r_l1 += new.abs() - old.abs();
        self.r[t] = new;
        self.queue.update(t, new.abs());
    }

    /// Spread the pending in-block uniform mass: a dangling page inside
    /// the block emits `rd·e/n` globally; only the `bs/n` slice lands on
    /// rows we own, the rest exits through the other UEs' boundaries.
    fn flush(&mut self) {
        let n = self.problem.n();
        let add = self.rd / n as f64;
        self.rd = 0.0;
        if add == 0.0 {
            return;
        }
        for t in 0..self.hi - self.lo {
            self.add_r(t, add);
        }
    }

    /// Boundary vector from the stale global view.
    fn boundary(&self, x: &[f32]) -> Vec<f64> {
        let (lo, hi) = (self.lo, self.hi);
        let csr = &self.problem.csr;
        let dang_ext: f64 = self
            .ext_dangling
            .iter()
            .map(|&u| x[u as usize] as f64)
            .sum();
        let n = self.problem.n() as f64;
        let base = self.alpha * dang_ext / n;
        let one_minus = 1.0 - self.alpha;
        let mut c = vec![0.0f64; hi - lo];
        for i in lo..hi {
            let (cols, vals) = csr.row(i);
            let mut acc = 0.0f64;
            for (&u, &w) in cols.iter().zip(vals) {
                let u = u as usize;
                if !(lo..hi).contains(&u) {
                    acc += w as f64 * x[u] as f64;
                }
            }
            c[i - lo] = self.alpha * acc + base + one_minus * self.problem.v_at(i) as f64;
        }
        c
    }

    /// Exact block residual `r = c + α S_BB p − p` (used once, to seed
    /// the state from the engine's initial iterate).
    fn seed_from(&mut self, x: &[f32], c: &[f64]) {
        let bs = self.hi - self.lo;
        for k in 0..bs {
            self.p[k] = x[self.lo + k] as f64;
        }
        let n = self.problem.n() as f64;
        let mut r = c.to_vec();
        let mut dang_local = 0.0f64;
        for k in 0..bs {
            let pk = self.p[k];
            if pk == 0.0 {
                continue;
            }
            let d = self.global_outdeg[k];
            if d == 0 {
                dang_local += pk;
            } else {
                let w = self.alpha * pk / d as f64;
                for &t in &self.out_block[k] {
                    r[t as usize] += w;
                }
            }
        }
        let uni = self.alpha * dang_local / n;
        for k in 0..bs {
            r[k] += uni - self.p[k];
        }
        self.rd = 0.0;
        self.r_l1 = 0.0;
        for (k, &v) in r.iter().enumerate() {
            self.r[k] = v;
            self.r_l1 += v.abs();
            self.queue.update(k, v.abs());
        }
    }

    fn push_local(&mut self, k: usize) {
        let m = self.r[k];
        if m == 0.0 {
            return;
        }
        self.r_l1 -= m.abs();
        self.r[k] = 0.0;
        self.p[k] += m;
        let d = self.global_outdeg[k];
        if d == 0 {
            self.rd += self.alpha * m;
        } else {
            let w = self.alpha * m / d as f64;
            // indexed loop: iterating `&self.out_block[k]` would hold an
            // immutable borrow of self across the `add_r(&mut self)` call
            for idx in 0..self.out_block[k].len() {
                let t = self.out_block[k][idx] as usize;
                self.add_r(t, w);
            }
        }
        self.pushes += 1;
    }
}

impl BlockOperator for PushBlockOp {
    fn rows(&self) -> (usize, usize) {
        (self.lo, self.hi)
    }

    fn block_nnz(&self) -> usize {
        self.nnz
    }

    fn update(&mut self, x: &[f32], out: &mut [f32]) -> f32 {
        let bs = self.hi - self.lo;
        debug_assert_eq!(out.len(), bs);
        let c_new = self.boundary(x);
        if self.first {
            self.seed_from(x, &c_new);
            self.first = false;
        } else {
            for k in 0..bs {
                let dc = c_new[k] - self.c[k];
                self.add_r(k, dc);
            }
        }
        self.c = c_new;

        // inner Gauss–Southwell loop to a target proportional to the
        // injected residual (absolute floor keeps the fixed point tight)
        let bs_over_n = bs as f64 / self.problem.n() as f64;
        let r0 = self.r_l1 + self.rd.abs() * bs_over_n;
        let target = self.opts.inner_floor.max(self.opts.inner_rel * r0);
        let budget = (self.opts.budget_per_row as u64) * (bs as u64).max(1);
        let mut spent = 0u64;
        while self.r_l1 + self.rd.abs() * bs_over_n >= target && spent < budget {
            if self.rd.abs() * bs_over_n >= self.r_l1.max(0.5 * target) {
                self.flush();
                continue;
            }
            match self.queue.pop() {
                Some(k) => {
                    self.push_local(k);
                    spent += 1;
                }
                None => {
                    if self.rd != 0.0 {
                        self.flush();
                    } else {
                        // queue drained with nothing pending: every r is
                        // zero, so re-tally (clears incremental drift)
                        // and stop
                        self.r_l1 = self.r.iter().map(|v| v.abs()).sum();
                        break;
                    }
                }
            }
        }

        let mut delta = 0.0f64;
        for k in 0..bs {
            let v = self.p[k] as f32;
            delta += (v as f64 - x[self.lo + k] as f64).abs();
            out[k] = v;
        }
        delta as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::asynciter::{Mode, RunSpec, SimEngine};
    use crate::coordinator::Partitioner;
    use crate::graph::{generators, Csr};
    use crate::pagerank::{kendall_tau, l1_diff, power_method, PowerOptions};
    use crate::simnet::ClusterProfile;

    fn problem(n: usize, seed: u64) -> Arc<PagerankProblem> {
        let el = generators::power_law_web(&generators::WebParams::scaled(n), seed);
        Arc::new(PagerankProblem::new(Csr::from_edgelist(&el).unwrap(), 0.85))
    }

    #[test]
    fn single_block_update_converges_to_power_fixed_point() {
        let p = problem(800, 21);
        let n = p.n();
        let mut op = PushBlockOp::new(p.clone(), 0, n);
        assert_eq!(op.rows(), (0, n));
        assert!(op.block_nnz() > 0);
        let x = p.uniform_start();
        let mut out = vec![0.0f32; n];
        // a few self-iterations: feed the output back as the new view
        let mut view = x;
        for _ in 0..6 {
            op.update(&view, &mut out);
            view.copy_from_slice(&out);
        }
        let pm = power_method(
            &p,
            &PowerOptions { tol: 1e-10, max_iters: 10_000, record_residuals: false },
        );
        let d = l1_diff(&view, &pm.x);
        assert!(d < 1e-4, "push block vs power method drift {d}");
        assert!(op.pushes() > 0);
    }

    #[test]
    fn async_sim_with_push_ops_matches_ranking() {
        let p = problem(1_500, 22);
        let procs = 3;
        let profile = ClusterProfile::test_profile(procs);
        let mut ops: Vec<Box<dyn BlockOperator>> = Partitioner::consecutive(p.n(), procs)
            .blocks()
            .into_iter()
            .map(|(lo, hi)| {
                Box::new(PushBlockOp::new(p.clone(), lo, hi)) as Box<dyn BlockOperator>
            })
            .collect();
        let m = SimEngine::new(&profile, &p).run(&mut ops, &RunSpec::paper_table1(Mode::Asynchronous));
        assert!(
            m.final_global_residual < 1e-3,
            "resid {}",
            m.final_global_residual
        );
        let pm = power_method(
            &p,
            &PowerOptions { tol: 1e-9, max_iters: 10_000, record_residuals: false },
        );
        let tau = kendall_tau(&m.x, &pm.x);
        assert!(tau > 0.99, "tau {tau}");
    }

    #[test]
    fn deterministic_in_the_sim() {
        let p = problem(900, 23);
        let procs = 2;
        let run = || {
            let profile = ClusterProfile::test_profile(procs);
            let mut ops: Vec<Box<dyn BlockOperator>> = Partitioner::consecutive(p.n(), procs)
                .blocks()
                .into_iter()
                .map(|(lo, hi)| {
                    Box::new(PushBlockOp::new(p.clone(), lo, hi)) as Box<dyn BlockOperator>
                })
                .collect();
            SimEngine::new(&profile, &p).run(&mut ops, &RunSpec::paper_table1(Mode::Asynchronous))
        };
        let a = run();
        let b = run();
        assert_eq!(a.iters, b.iters);
        assert_eq!(a.x, b.x);
    }

    #[test]
    fn balanced_sharded_ops_deterministic_under_virtual_time() {
        // the sharded operator layout (balanced-nnz blocks of push ops)
        // under the DES engine's virtual clock: two runs with the same
        // seed must be BIT-identical — all the nondeterminism of the
        // parallel push path lives in the real-thread backend, none of
        // it in the simulator
        let p = problem(1_200, 24);
        let procs = 4;
        let part = Partitioner::balanced_nnz(&p.csr, procs);
        let run = || {
            let profile = ClusterProfile::test_profile(procs);
            let mut ops: Vec<Box<dyn BlockOperator>> = part
                .blocks()
                .into_iter()
                .map(|(lo, hi)| {
                    Box::new(PushBlockOp::new(p.clone(), lo, hi)) as Box<dyn BlockOperator>
                })
                .collect();
            SimEngine::new(&profile, &p).run(&mut ops, &RunSpec::paper_table1(Mode::Asynchronous))
        };
        let a = run();
        let b = run();
        assert_eq!(a.iters, b.iters, "virtual-time schedule must be reproducible");
        assert_eq!(a.x, b.x, "ranks must be bit-identical across runs");
        assert_eq!(a.total_time, b.total_time);
        // and the sharded layout still converges to the right ranking
        let pm = power_method(
            &p,
            &PowerOptions { tol: 1e-9, max_iters: 10_000, record_residuals: false },
        );
        let tau = kendall_tau(&a.x, &pm.x);
        assert!(tau > 0.99, "tau {tau}");
    }
}
