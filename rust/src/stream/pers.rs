//! Sparse personalization vectors — the `v` in `x = αSx + (1−α)v`.
//!
//! The classic (global) PageRank takes `v = e/n`; personalized PageRank
//! (PPR) replaces it with an arbitrary nonnegative vector, usually
//! supported on a handful of source nodes (Berkhin's survey lineage).
//! The push machinery only ever needs `v` through three views, all
//! cheap for a sparse vector:
//!
//! * its **entries** `(node, weight)` — the `O(nnz(v))` flush targets
//!   of the pending-`v` scalar (see [`PushState`]'s `rv`);
//! * its **total mass** `Σv` — the fixed point satisfies
//!   `Σp + R/(1−α) = Σv`, so every mass-conservation check compares
//!   against `total()` instead of `1`;
//! * per-shard **`v`-mass shares** `Σ_{i∈shard} v_i` — how the sharded
//!   engine weighs the replicated pending-`v` scalar, exactly like
//!   `|B_s|/n` weighs the pending-uniform one.
//!
//! Dangling redistribution is a separate policy choice:
//! [`dangling_to_v`](Personalization::dangling_to_v) routes dangling
//! mass back through `v` (the standard PPR random surfer, and the
//! choice that keeps a query's residual *localized* around its
//! sources), while `false` keeps the global solver's uniform `e/n`
//! redistribution. With a uniform `v` the two are identical.
//!
//! Weights must be finite and strictly positive — a NaN here is how a
//! "degenerate personalization vector" would poison the bucket queue
//! (see `BucketQueue::bucket_of`), so it is rejected at construction.
//!
//! [`PushState`]: super::PushState

use crate::Result;

/// A validated sparse personalization vector: entries sorted by node
/// id, duplicate ids merged, every weight finite and `> 0`.
#[derive(Debug, Clone, PartialEq)]
pub struct Personalization {
    /// `(node, weight)` sorted by node id, deduplicated.
    entries: Vec<(u32, f64)>,
    /// `Σ weights` — the target mass of the fixed point.
    total: f64,
    /// `max weight` — bounds any single row's `v`-share (the top-k
    /// rest-bound needs it).
    vmax: f64,
    /// Route dangling mass through `v` instead of `e/n`.
    dangling_to_v: bool,
}

impl Personalization {
    /// Build from raw `(node, weight)` pairs. Duplicates are merged by
    /// summing; non-finite or non-positive weights are rejected.
    pub fn from_entries(entries: Vec<(u32, f64)>, dangling_to_v: bool) -> Result<Self> {
        anyhow::ensure!(!entries.is_empty(), "personalization vector needs at least one entry");
        let mut entries = entries;
        entries.sort_unstable_by_key(|&(t, _)| t);
        let mut merged: Vec<(u32, f64)> = Vec::with_capacity(entries.len());
        for (t, w) in entries {
            anyhow::ensure!(
                w.is_finite() && w > 0.0,
                "personalization weight for node {t} must be finite and > 0, got {w}"
            );
            match merged.last_mut() {
                Some(last) if last.0 == t => last.1 += w,
                _ => merged.push((t, w)),
            }
        }
        let total: f64 = merged.iter().map(|&(_, w)| w).sum();
        anyhow::ensure!(total.is_finite() && total > 0.0, "personalization mass must be finite");
        let vmax = merged.iter().map(|&(_, w)| w).fold(0.0f64, f64::max);
        Ok(Personalization { entries: merged, total, vmax, dangling_to_v })
    }

    /// The canonical single-source PPR query: all teleport mass on one
    /// node, dangling mass following it.
    pub fn single_source(u: u32) -> Self {
        Personalization { entries: vec![(u, 1.0)], total: 1.0, vmax: 1.0, dangling_to_v: true }
    }

    /// Uniform over a set of source nodes (total mass 1), dangling mass
    /// following the set.
    pub fn sources(ids: &[u32]) -> Result<Self> {
        anyhow::ensure!(!ids.is_empty(), "source set must be non-empty");
        let w = 1.0 / ids.len() as f64;
        Self::from_entries(ids.iter().map(|&u| (u, w)).collect(), true)
    }

    /// The explicit uniform vector over `n` nodes — only used by the
    /// equivalence tests (the global path keeps its implicit `e/n`).
    pub fn uniform(n: usize, dangling_to_v: bool) -> Self {
        let w = 1.0 / n as f64;
        Personalization {
            entries: (0..n as u32).map(|t| (t, w)).collect(),
            total: 1.0,
            vmax: w,
            dangling_to_v,
        }
    }

    /// Sorted, deduplicated `(node, weight)` pairs.
    pub fn entries(&self) -> &[(u32, f64)] {
        &self.entries
    }

    /// `Σv` — what `Σp + R/(1−α)` conserves.
    pub fn total(&self) -> f64 {
        self.total
    }

    /// Largest single weight.
    pub(crate) fn vmax(&self) -> f64 {
        self.vmax
    }

    /// Whether dangling mass redistributes along `v` (vs. uniform).
    pub fn dangling_to_v(&self) -> bool {
        self.dangling_to_v
    }

    /// Largest node id carrying weight (the state must be at least this
    /// big).
    pub fn max_node(&self) -> u32 {
        self.entries.last().map(|&(t, _)| t).unwrap_or(0)
    }

    /// `v_t` (0 for nodes outside the support). Binary search —
    /// intended for small per-check lookups (top-k centers), not hot
    /// loops.
    pub(crate) fn weight_of(&self, t: u32) -> f64 {
        match self.entries.binary_search_by_key(&t, |&(id, _)| id) {
            Ok(i) => self.entries[i].1,
            Err(_) => 0.0,
        }
    }

    /// `Σ v_t` over `lo <= t < hi` — a shard's `v`-mass share.
    pub(crate) fn share_of_range(&self, lo: usize, hi: usize) -> f64 {
        let a = self.entries.partition_point(|&(t, _)| (t as usize) < lo);
        let b = self.entries.partition_point(|&(t, _)| (t as usize) < hi);
        self.entries[a..b].iter().map(|&(_, w)| w).sum()
    }

    /// The `(local-index, weight)` entries falling in `[lo, hi)` — a
    /// shard's local flush targets.
    pub(crate) fn entries_in_range(&self, lo: usize, hi: usize) -> Vec<(u32, f64)> {
        let a = self.entries.partition_point(|&(t, _)| (t as usize) < lo);
        let b = self.entries.partition_point(|&(t, _)| (t as usize) < hi);
        self.entries[a..b].iter().map(|&(t, w)| (t - lo as u32, w)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merges_sorts_and_totals() {
        let p = Personalization::from_entries(vec![(7, 0.5), (2, 1.0), (7, 0.25)], true).unwrap();
        assert_eq!(p.entries(), &[(2, 1.0), (7, 0.75)]);
        assert!((p.total() - 1.75).abs() < 1e-15);
        assert_eq!(p.vmax(), 1.0);
        assert_eq!(p.max_node(), 7);
        assert_eq!(p.weight_of(7), 0.75);
        assert_eq!(p.weight_of(3), 0.0);
    }

    #[test]
    fn rejects_degenerate_weights() {
        assert!(Personalization::from_entries(vec![], true).is_err());
        assert!(Personalization::from_entries(vec![(0, f64::NAN)], true).is_err());
        assert!(Personalization::from_entries(vec![(0, f64::INFINITY)], true).is_err());
        assert!(Personalization::from_entries(vec![(0, 0.0)], true).is_err());
        assert!(Personalization::from_entries(vec![(0, -1.0)], true).is_err());
    }

    #[test]
    fn range_views_partition_the_mass() {
        let p = Personalization::from_entries(
            vec![(1, 0.1), (4, 0.2), (5, 0.3), (9, 0.4)],
            false,
        )
        .unwrap();
        let s: f64 = [(0usize, 5usize), (5, 8), (8, 12)]
            .iter()
            .map(|&(lo, hi)| p.share_of_range(lo, hi))
            .sum();
        assert!((s - p.total()).abs() < 1e-15);
        assert_eq!(p.entries_in_range(5, 8), vec![(0, 0.3)]);
        assert_eq!(p.entries_in_range(8, 12), vec![(1, 0.4)]);
    }
}
